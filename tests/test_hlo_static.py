"""Trip-count-aware HLO static analyzer (launch/hlo_static.py): validated against
programs with analytically known FLOP counts — including the nested-scan case where
XLA's own cost_analysis undercounts by the trip product."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_static import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


class TestFlops:
    def test_single_dot(self):
        a = jnp.ones((32, 64))
        b = jnp.ones((64, 16))
        res = analyze_hlo(_compile(lambda a, b: a @ b, a, b))
        assert res["flops_fp"] == 2 * 32 * 64 * 16
        assert res["unresolved_dots"] == 0

    def test_scan_multiplies_by_trip(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out
        x = jnp.ones((64, 128))
        w = jnp.ones((128, 128))
        res = analyze_hlo(_compile(f, x, w))
        assert res["flops_fp"] == 7 * 2 * 64 * 128 * 128

    def test_nested_scans(self):
        def g(x, w):
            def inner(c, _):
                return jnp.tanh(c @ w), None
            def outer(c, _):
                c2, _ = jax.lax.scan(inner, c, None, length=5)
                return c2, None
            out, _ = jax.lax.scan(outer, x, None, length=3)
            return out
        x = jnp.ones((64, 128))
        w = jnp.ones((128, 128))
        res = analyze_hlo(_compile(g, x, w))
        assert res["flops_fp"] == 15 * 2 * 64 * 128 * 128

    def test_int8_dot_counted_separately(self):
        def h(a, b):
            return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.int32)
        a = jnp.ones((32, 64), jnp.int8)
        b = jnp.ones((64, 16), jnp.int8)
        res = analyze_hlo(_compile(h, a, b))
        assert res["flops_int8"] == 2 * 32 * 64 * 16
        assert res["flops_fp"] == 0

    def test_grad_counts_forward_and_backward(self):
        def loss(w, x):
            return jnp.sum(jnp.tanh(x @ w))
        w = jnp.ones((128, 64))
        x = jnp.ones((32, 128))
        res = analyze_hlo(_compile(jax.grad(loss), w, x))
        # forward dot + one backward dot for dw (dx not needed for arg 0 only...
        # jax.grad(loss) w.r.t. w: forward (32,128)@(128,64) + backward x^T@g
        want = 2 * (2 * 32 * 128 * 64)
        assert res["flops_fp"] == want


class TestBytes:
    def test_hbm_bytes_scale_with_trip(self):
        def f(x, w, n):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        x = jnp.ones((64, 128))
        w = jnp.ones((128, 128))
        r2 = analyze_hlo(_compile(lambda x, w: f(x, w, 2), x, w))
        r8 = analyze_hlo(_compile(lambda x, w: f(x, w, 8), x, w))
        ratio = r8["hbm_bytes"] / r2["hbm_bytes"]
        assert 2.5 < ratio < 4.5, ratio     # ~4x body traffic, constant prologue
