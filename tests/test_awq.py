"""AWQ baseline (core/awq.py): salient-channel protection + exactness properties."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import awq, qlinear as ql
from repro.data.synthetic import OPT_LIKE, outlier_activations


class TestAWQ:
    def test_protects_salient_channels(self, key):
        """Channels with large activations must get LOWER weight quantization error
        than under plain group quantization (AWQ's defining property)."""
        d_in, d_out = 256, 64
        w = jax.random.normal(key, (d_in, d_out)) * 0.1
        cmax = jnp.ones((d_in,)).at[:8].set(100.0)       # 8 salient channels
        wq_awq = awq.awq_weight(w, cmax, bits=4, group=128)
        wq_plain = awq._fake_group_cols(w, 4, 128)
        err_awq = float(jnp.linalg.norm((w - wq_awq)[:8]))
        err_plain = float(jnp.linalg.norm((w - wq_plain)[:8]))
        assert err_awq < err_plain, (err_awq, err_plain)

    def test_uniform_activations_degenerate_to_plain(self, key):
        """With flat cmax, the alpha search lands on s = 1 (plain group quant)."""
        w = jax.random.normal(key, (128, 32)) * 0.1
        cmax = jnp.ones((128,))
        wq_awq = awq.awq_weight(w, cmax, bits=4, group=128)
        wq_plain = awq._fake_group_cols(w, 4, 128)
        np.testing.assert_allclose(np.asarray(wq_awq), np.asarray(wq_plain),
                                   atol=1e-6)

    def test_qlinear_awq_mode_runs_and_beats_plain_w4(self, key):
        x = jnp.asarray(outlier_activations(64, 256, OPT_LIKE, seed=0))
        p = ql.init(key, 256, 64)
        y_fp = ql.apply(p, x, ql.FP)
        y_awq = ql.apply(p, x, ql.W4A8_G128_AWQ)
        y_plain = ql.apply(p, x, ql.W4A8_G128_PER_TOKEN)
        err_awq = float(jnp.linalg.norm(y_awq - y_fp))
        err_plain = float(jnp.linalg.norm(y_plain - y_fp))
        assert err_awq <= err_plain * 1.01, (err_awq, err_plain)

    def test_crossquant_plus_awq_combination(self, key):
        """The paper's Table 2 combination must run and track fp closely."""
        x = jnp.asarray(outlier_activations(64, 256, OPT_LIKE, seed=1))
        p = ql.init(key, 256, 64)
        y_fp = ql.apply(p, x, ql.FP)
        y = ql.apply(p, x, ql.W4A8_G128_CQ_AWQ)
        rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
        assert rel < 0.2, rel
