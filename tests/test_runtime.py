"""Runtime fault tolerance: supervisor restart determinism, straggler deadline
barrier, elastic mesh planning."""
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import DeadlineBarrier, FailureInjector, Supervisor, WorkerFailure
from repro.runtime.elastic import plan_mesh_shape, usable_dp


def _deterministic_step(state, step):
    v = np.float32((step * 2654435761) % 97)
    return {"x": state["x"] + v}, {"v": float(v)}


class TestSupervisor:
    def test_restart_bitwise_determinism(self, tmp_path):
        """A run with injected failures ends bitwise-identical to a clean run —
        the checkpoint/restart contract at cluster scale."""
        def run(fail, sub):
            cm = CheckpointManager(str(tmp_path / sub), keep_n=10)
            sup = Supervisor(cm, ckpt_every=4)
            inj = FailureInjector(fail_at_steps=fail) if fail else None
            return sup.run({"x": np.zeros(4, np.float32)}, _deterministic_step, 21,
                           injector=inj)
        clean = run((), "clean")
        faulty = run((3, 10, 17), "faulty")
        np.testing.assert_array_equal(clean.state["x"], faulty.state["x"])
        assert faulty.restarts == 3
        assert clean.restarts == 0

    def test_restart_budget_exhausted(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_n=10)
        sup = Supervisor(cm, ckpt_every=100, max_restarts=2)

        class AlwaysFail:
            def check(self, step):
                if step == 1:
                    raise WorkerFailure("flaky node")
        with pytest.raises(RuntimeError, match="restart budget"):
            sup.run({"x": np.zeros(1, np.float32)}, _deterministic_step, 5,
                    injector=AlwaysFail())

    def test_rebuild_hook_called_on_restart(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_n=10)
        sup = Supervisor(cm, ckpt_every=2)
        calls = []

        def rebuild(state):
            calls.append(1)
            return state
        inj = FailureInjector(fail_at_steps=(3,))
        sup.run({"x": np.zeros(1, np.float32)}, _deterministic_step, 6,
                injector=inj, rebuild=rebuild)
        assert calls == [1]

    def test_history_truncated_at_restore(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_n=10)
        sup = Supervisor(cm, ckpt_every=4)
        inj = FailureInjector(fail_at_steps=(6,))
        res = sup.run({"x": np.zeros(1, np.float32)}, _deterministic_step, 9,
                      injector=inj)
        steps = [h["step"] for h in res.metrics_history]
        assert steps == sorted(set(steps)) == list(range(9))


class TestStraggler:
    def test_no_eviction_during_warmup(self):
        b = DeadlineBarrier(n_hosts=4, min_history=16)
        out = b.step([1.0, 1.0, 1.0, 50.0])
        assert out["deadline"] is None and out["evict"] == []

    def test_persistent_straggler_evicted(self):
        b = DeadlineBarrier(n_hosts=4, quantile=0.9, slack=1.5, evict_after=3)
        for _ in range(6):
            b.step([1.0, 1.0, 1.0, 1.05])
        evictions = []
        for _ in range(5):
            out = b.step([1.0, 1.0, 1.0, 10.0])
            evictions += out["evict"]
        assert 3 in evictions

    def test_transient_spike_not_evicted(self):
        b = DeadlineBarrier(n_hosts=4, evict_after=3)
        for _ in range(6):
            b.step([1.0, 1.0, 1.0, 1.0])
        out = b.step([1.0, 1.0, 1.0, 10.0])     # one bad step
        assert out["evict"] == []
        out = b.step([1.0, 1.0, 1.0, 1.0])      # recovers
        assert 3 not in out["suspect"]


class TestElastic:
    def test_usable_dp_divides_batch(self):
        assert usable_dp(16, 256) == 16
        assert usable_dp(15, 256) == 8     # largest divisor of 256 <= 15
        assert usable_dp(7, 256) == 4

    def test_plan_holds_tp_fixed(self):
        assert plan_mesh_shape(256, 16) == (16, 16)
        assert plan_mesh_shape(240, 16, global_batch=256) == (8, 16)
        with pytest.raises(ValueError):
            plan_mesh_shape(8, 16)
