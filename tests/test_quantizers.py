"""Unit + property tests for the paper's core numerics (core/quantizers.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import kernel_analysis as KA
from repro.core import packing
from repro.core import quantizers as Q
from repro.data.synthetic import OPT_LIKE, LLAMA_LIKE, outlier_activations

SET = dict(max_examples=25, deadline=None)


def _mats(min_rows=2, max_rows=24, min_cols=2, max_cols=48):
    return hnp.arrays(
        np.float32,
        st.tuples(st.integers(min_rows, max_rows), st.integers(min_cols, max_cols)),
        elements=st.floats(-100, 100, width=32),
    )


# ======================================================================================
# Eq. (1)/(5): scale construction and degeneracies
# ======================================================================================

class TestScales:
    def test_per_token_scale_is_rowmax_over_qmax(self):
        x = jnp.asarray([[1.0, -4.0, 2.0], [0.5, 0.25, -0.125]])
        s = Q.per_token_scale(x, bits=8)
        np.testing.assert_allclose(np.asarray(s).ravel(), [4 / 127, 0.5 / 127],
                                   rtol=1e-6)

    @settings(**SET)
    @given(_mats())
    def test_alpha_one_degenerates_to_per_token(self, x):
        """Paper Table 1: alpha = 1 'is actually Per-token quantization'."""
        x = jnp.asarray(x)
        s_cq = Q.crossquant_scale(x, 8, alpha=1.0)
        s_pt = Q.per_token_scale(x, 8)
        np.testing.assert_allclose(np.asarray(jnp.broadcast_to(s_cq, x.shape)),
                                   np.asarray(jnp.broadcast_to(s_pt, x.shape)),
                                   rtol=1e-5)

    @settings(**SET)
    @given(_mats())
    def test_alpha_zero_is_per_column(self, x):
        x = jnp.asarray(x)
        s_cq = Q.crossquant_scale(x, 8, alpha=0.0)
        c = jnp.maximum(jnp.max(jnp.abs(x), axis=0, keepdims=True), Q.EPS) / 127
        np.testing.assert_allclose(np.asarray(jnp.broadcast_to(s_cq, x.shape)),
                                   np.asarray(jnp.broadcast_to(c, x.shape)), rtol=1e-5)

    @settings(**SET)
    @given(_mats(), st.floats(0.0, 1.0))
    def test_crossquant_scale_is_geometric_mix(self, x, alpha):
        """Δ̃ = t^α c^(1-α) / qmax lies between the row and column scales."""
        x = jnp.asarray(x)
        t = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), Q.EPS)
        c = jnp.maximum(jnp.max(jnp.abs(x), axis=0, keepdims=True), Q.EPS)
        s = Q.crossquant_scale(x, 8, alpha=alpha) * 127
        lo = jnp.minimum(jnp.broadcast_to(t, x.shape), jnp.broadcast_to(c, x.shape))
        hi = jnp.maximum(jnp.broadcast_to(t, x.shape), jnp.broadcast_to(c, x.shape))
        assert bool(jnp.all(s >= lo * (1 - 1e-5)))
        assert bool(jnp.all(s <= hi * (1 + 1e-5)))


# ======================================================================================
# Quantization round-trip properties
# ======================================================================================

class TestQuantizers:
    @settings(**SET)
    @given(_mats(), st.sampled_from([4, 8]))
    def test_dequant_error_bounded_by_half_scale(self, x, bits):
        x = jnp.asarray(x)
        qr = Q.per_token_quant(x, bits)
        err = jnp.abs(qr.dequant() - x)
        # |round(x/s)*s - x| <= s/2 wherever no clipping occurred (symmetric grid
        # covers the full range by construction of the absmax scale).
        bound = jnp.broadcast_to(qr.scale / 2, x.shape) + 1e-6
        assert bool(jnp.all(err <= bound))

    @settings(**SET)
    @given(_mats(), st.sampled_from([0.15, 0.45, 0.75]))
    def test_crossquant_codes_within_grid(self, x, alpha):
        x = jnp.asarray(x)
        qr = Q.crossquant(x, 8, alpha)
        assert qr.codes.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(qr.codes))) <= 127

    def test_group_quant_roundtrip_shape(self):
        w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 32)), jnp.float32)
        qr = Q.group_quant(w, bits=4, group_size=16)
        assert qr.codes.shape == w.shape
        deq = Q.group_dequant(qr, group_size=16)
        assert deq.shape == w.shape
        err = jnp.abs(deq - w)
        grouped_scale = jnp.repeat(qr.scale.reshape(-1), 16).reshape(w.shape)
        assert bool(jnp.all(err <= grouped_scale / 2 + 1e-6))

    def test_fake_quant_matches_quant_dequant(self):
        x = jnp.asarray(outlier_activations(64, 128, seed=3))
        np.testing.assert_allclose(
            np.asarray(Q.fake_crossquant(x, 8, 0.15)),
            np.asarray(Q.crossquant(x, 8, 0.15).dequant()), rtol=1e-6)

    def test_static_c_override(self):
        x = jnp.asarray(outlier_activations(32, 64, seed=4))
        cmax = jnp.max(jnp.abs(x), axis=0)
        dyn = Q.crossquant_scale(x, 8, 0.15)
        stat = Q.crossquant_scale(x, 8, 0.15, col_max=cmax)
        np.testing.assert_allclose(np.asarray(dyn), np.asarray(stat), rtol=1e-6)


# ======================================================================================
# Definition 1: the quantization kernel
# ======================================================================================

class TestKernel:
    @settings(**SET)
    @given(_mats())
    def test_definition1_equivalence(self, x):
        """Q(x)=0  ⇔  |x| < 0.5·Δ (eq. 4)."""
        x = jnp.asarray(x)
        scale = Q.per_token_scale(x, 8)
        qr = Q.per_token_quant(x, 8)
        # jnp.round is round-half-even; the boundary |x| == 0.5Δ rounds to 0 — the
        # strict-inequality form of eq. (4) holds off the measure-zero boundary.
        boundary = jnp.isclose(jnp.abs(x), 0.5 * jnp.broadcast_to(scale, x.shape),
                               rtol=1e-5)
        mask_def = jnp.abs(x) < 0.5 * jnp.broadcast_to(scale, x.shape)
        mask_q = qr.codes == 0
        agree = (mask_def == mask_q) | boundary
        assert bool(jnp.all(agree))

    def test_crossquant_kernel_smaller_on_outlier_data(self):
        """The paper's central claim: K(CQ) << K(Q) on outlier-heavy activations."""
        for spec, name in [(OPT_LIKE, "opt"), (LLAMA_LIKE, "llama")]:
            x = jnp.asarray(outlier_activations(512, 1024, spec, seed=0))
            k_pt = float(KA.per_token_kernel_fraction(x, 8))
            k_cq = float(KA.crossquant_kernel_fraction(x, 8, 0.15))
            assert k_cq < k_pt, (name, k_cq, k_pt)

    def test_kernel_fractions_match_paper_regimes(self):
        """OPT-like: per-token kernel ~40-60%, CrossQuant much lower (paper Fig. 4:
        43.4% -> 16%); LLaMA-like: per-token ~10%, CrossQuant <2%."""
        x_opt = jnp.asarray(outlier_activations(1024, 2048, OPT_LIKE, seed=1))
        k_pt = float(KA.per_token_kernel_fraction(x_opt, 8))
        k_cq = float(KA.crossquant_kernel_fraction(x_opt, 8, 0.15))
        assert 0.30 < k_pt < 0.75, k_pt
        assert k_cq < 0.5 * k_pt, (k_cq, k_pt)
        x_ll = jnp.asarray(outlier_activations(1024, 2048, LLAMA_LIKE, seed=1))
        k_pt_l = float(KA.per_token_kernel_fraction(x_ll, 8))
        k_cq_l = float(KA.crossquant_kernel_fraction(x_ll, 8, 0.15))
        assert k_pt_l < 0.35, k_pt_l
        assert k_cq_l < 0.05, k_cq_l

    def test_remove_kernel_zeroes_exactly_the_kernel(self):
        x = jnp.asarray(outlier_activations(64, 128, seed=2))
        scale = Q.per_token_scale(x, 8)
        removed = KA.remove_kernel(x, scale)
        mask = KA.kernel_mask(x, scale, count_exact_zeros=True)
        assert bool(jnp.all(jnp.where(mask, removed == 0, removed == x)))

    def test_remove_kernel_fraction_removes_that_fraction(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(128, 128)), jnp.float32)
        for frac in (0.1, 0.4, 0.8):
            out = KA.remove_kernel_fraction(x, frac)
            got = float(jnp.mean(out == 0))
            assert abs(got - frac) < 0.02, (frac, got)

    def test_table1_stats_fields(self):
        x = jnp.asarray(outlier_activations(256, 512, OPT_LIKE, seed=5))
        s = KA.table1_stats(x, 8, 0.15)
        assert 0 <= float(s["c_ge_t"]) <= 1
        # Table 1 row 2: the vast majority of positions have a *shrunken* zero bound.
        assert float(s["bcq_lt_bpt"]) > 0.9
        assert float(s["kernel_crossquant"]) < float(s["kernel_per_token"])


# ======================================================================================
# int4 packing
# ======================================================================================

class TestPacking:
    @settings(**SET)
    @given(hnp.arrays(np.int8, st.tuples(st.integers(1, 8), st.integers(1, 16)),
                      elements=st.integers(-8, 7)))
    def test_pack_unpack_roundtrip(self, codes):
        if codes.shape[-1] % 2:
            codes = np.concatenate([codes, np.zeros_like(codes[..., :1])], -1)
        packed = packing.pack_int4(jnp.asarray(codes))
        assert packed.shape[-1] == codes.shape[-1] // 2
        out = packing.unpack_int4(packed)
        np.testing.assert_array_equal(np.asarray(out), codes)
