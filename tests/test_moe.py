"""MoE layer invariants: routing determinism, capacity handling, gate normalization,
EP-shardable dispatch layout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.models import moe
from repro.models.layers import QuantContext


@pytest.fixture
def cfg():
    return get("granite-moe-3b-a800m", smoke=True)


@pytest.fixture
def setup(cfg, key):
    params = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    return params, x


class TestMoE:
    def test_output_shape_and_aux(self, cfg, setup):
        params, x = setup
        ctx = QuantContext(ql.FP)
        y, aux = moe.moe_apply(params, x, cfg, ctx)
        assert y.shape == x.shape
        assert float(aux) > 0          # load-balance loss is E·Σ m_e·c_e ≥ 1 at optimum

    def test_deterministic(self, cfg, setup):
        params, x = setup
        ctx = QuantContext(ql.FP)
        y1, _ = moe.moe_apply(params, x, cfg, ctx)
        y2, _ = moe.moe_apply(params, x, cfg, ctx)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_capacity_rounding(self, cfg):
        c = moe.capacity(100, cfg)
        assert c % 8 == 0 and c >= 8

    def test_high_capacity_matches_dense_computation(self, cfg, setup):
        """With capacity >> needed, every token reaches all its top-k experts; the
        output must equal an explicit dense gather-and-mix reference."""
        params, x = setup
        cfg_hi = dataclasses.replace(cfg, capacity_factor=16.0)
        ctx = QuantContext(ql.FP)
        y, _ = moe.moe_apply(params, x, cfg_hi, ctx)

        N, d = x.shape[0] * x.shape[1], x.shape[2]
        xf = x.reshape(N, d)
        logits = xf.astype(jnp.float32) @ params["router"]["w"]
        probs = jax.nn.softmax(logits, -1)
        gw, gi = jax.lax.top_k(probs, cfg.top_k)
        gw = gw / jnp.maximum(gw.sum(-1, keepdims=True), 1e-9)

        def expert_out(e, xs):
            up = xs @ params["up"]["w"][e]
            h = jax.nn.silu(xs @ params["gate"]["w"][e]) * up \
                if "gate" in params else jax.nn.gelu(up)
            return h @ params["down"]["w"][e]

        want = jnp.zeros_like(xf)
        for n_ in range(N):
            acc = jnp.zeros((d,), xf.dtype)
            for k_ in range(cfg.top_k):
                acc += gw[n_, k_] * expert_out(gi[n_, k_], xf[n_][None])[0]
            want = want.at[n_].set(acc)
        np.testing.assert_allclose(np.asarray(y.reshape(N, d)), np.asarray(want),
                                   rtol=5e-2, atol=5e-4)

    def test_capacity_one_drops_tokens(self, cfg, setup):
        """Tiny capacity must not crash; dropped tokens contribute zero."""
        params, x = setup
        cfg_lo = dataclasses.replace(cfg, capacity_factor=0.01)
        y, _ = moe.moe_apply(params, x, cfg_lo, QuantContext(ql.FP))
        assert not bool(jnp.any(jnp.isnan(y)))

    def test_quantized_experts_run(self, cfg, setup):
        params, x = setup
        y, _ = moe.moe_apply(params, x, cfg, QuantContext(ql.W8A8_CROSSQUANT))
        assert not bool(jnp.any(jnp.isnan(y)))
