"""Mamba2 / SSD tests: the chunked scan against a naive per-token recurrence oracle,
decode-step parity, and chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _naive_ssd(x, dt, A, Bm, Cm):
    """Per-token recurrence oracle: S_t = S_{t-1}·exp(dt_t·A) + dt_t·x_t⊗B_t."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    state = np.zeros((Bsz, H, P, N), np.float64)
    ys = np.zeros((Bsz, S, H, P), np.float64)
    x, dt, A, Bm, Cm = (np.asarray(v, np.float64) for v in (x, dt, A, Bm, Cm))
    for t in range(S):
        dA = np.exp(dt[:, t] * A)                                   # (B, H)
        upd = np.einsum("bhp,bn->bhpn", x[:, t] * dt[:, t][..., None], Bm[:, t])
        state = state * dA[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, Cm[:, t])
    return ys, state


def _rand_inputs(key, Bsz=2, S=32, H=3, P=4, N=8):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bsz, S, N))
    Cm = jax.random.normal(ks[4], (Bsz, S, N))
    return x, dt, A, Bm, Cm


class TestSSDScan:
    @pytest.mark.parametrize("chunk", [4, 8, 16, 32])
    def test_matches_naive_recurrence(self, key, chunk):
        x, dt, A, Bm, Cm = _rand_inputs(key)
        y, state = ssm.ssd_scan(x, dt, A, Bm, Cm, chunk)
        y_ref, state_ref = _naive_ssd(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(state), state_ref, rtol=1e-4, atol=1e-4)

    def test_chunk_size_invariance(self, key):
        x, dt, A, Bm, Cm = _rand_inputs(key)
        y4, s4 = ssm.ssd_scan(x, dt, A, Bm, Cm, 4)
        y16, s16 = ssm.ssd_scan(x, dt, A, Bm, Cm, 16)
        np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(s4), np.asarray(s16), rtol=1e-4,
                                   atol=1e-5)

    def test_non_divisible_length_padding(self, key):
        """S not divisible by chunk must give identical results (the pad is masked)."""
        x, dt, A, Bm, Cm = _rand_inputs(key, S=29)
        y, state = ssm.ssd_scan(x, dt, A, Bm, Cm, chunk=8)
        y_ref, state_ref = _naive_ssd(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(state), state_ref, rtol=1e-4, atol=1e-4)

    def test_init_state_carried(self, key):
        """Splitting a sequence across two scans == one scan (prefill continuation)."""
        x, dt, A, Bm, Cm = _rand_inputs(key, S=32)
        y_full, s_full = ssm.ssd_scan(x, dt, A, Bm, Cm, 8)
        y1, s1 = ssm.ssd_scan(x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], 8)
        y2, s2 = ssm.ssd_scan(x[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:], 8,
                              init_state=s1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-4,
                                   atol=1e-5)


class TestSSDDecode:
    def test_decode_steps_match_scan(self, key):
        x, dt, A, Bm, Cm = _rand_inputs(key, S=16)
        y_scan, s_scan = ssm.ssd_scan(x, dt, A, Bm, Cm, 8)
        state = jnp.zeros_like(s_scan)
        ys = []
        for t in range(16):
            state, y = ssm.ssd_decode_step(state, x[:, t], dt[:, t], A, Bm[:, t],
                                           Cm[:, t])
            ys.append(y)
        y_dec = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_scan),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(state), np.asarray(s_scan),
                                   rtol=1e-4, atol=1e-4)
