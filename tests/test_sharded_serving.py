"""TP-sharded serving (DESIGN.md §3.7): token-exact parity vs single-device.

One subprocess with a forced 8-device CPU host platform serves a mixed-length
continuous-batching workload through ``ServeEngine(mesh=...)`` at tp=2 (tier
tp_full for the smoke config) and tp=4 (tier tp_kv_rep: 4 q heads divide, 2 kv
heads degrade to replication) across the full path × KV-cache matrix —
fake / dequant-fp / fused-int8 × fp / int8 — and asserts the emitted tokens are
identical to the single-device engine, per request. A 2:4-sparsified tree
(DESIGN.md §3.12) then serves fused-int8 at tp=2: the packed mask leaves shard
alongside their qw and the sparse tokens must equal single-device sparse. The same matrix then runs
the paged cache layout (DESIGN.md §3.8) at tp=2 on a shared-prefix workload:
paged@tp2 with radix prefix hits must equal dense single-device, token-exact.
One speculative case (DESIGN.md §3.9) then serves speculate=4 draft windows
through the sharded paged fused-int8 path and must equal single-device
non-speculative decode. Expert-parallel MoE serving (DESIGN.md §3.13) then
serves granite-moe / llama4-scout fused-int8 under an ``expert`` mesh axis —
pure ep=2 and composed tp2×ep2 — where the stacked ``(E, ...)`` expert trees
shard over whole experts, the router stays replicated, and the emitted tokens
must equal single-device. The same subprocess pins the row-parallel
int32-accumulator ordering (qlinear ref path bitwise vs single-device: the
cross-shard reduction must happen on integer values before the f32 dequant
multiply — hints.constrain_gemm_acc).

The CI ``sharded-serving`` job runs this file; it also runs under tier-1 by
default (the top-level pytest process stays on the real single CPU device —
only the subprocess forces 8). The tier-1 CI matrix sets
``REPRO_SKIP_SHARDED=1`` to skip it there: the dedicated job already runs it,
and the ~2-minute 8-device subprocess × the python-version matrix buys no extra
coverage.
"""
import os
import subprocess
import sys
import textwrap

import pytest


CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import dataclasses
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get
    from repro.core import qlinear as ql
    from repro.models import model as M
    from repro.models.quantize import quantize_tree
    from repro.serving import engine as E
    from repro.sharding import hints
    from repro.launch.mesh import make_debug_mesh

    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    rng = np.random.default_rng(0)
    LENS = [4, 7, 12, 9]
    MAX_NEW = [4, 3, 5, 2]
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32) for n in LENS]
    COMBOS = [("fake", "fp"), ("fake", "int8"),
              ("dequant-fp", "fp"), ("dequant-fp", "int8"),
              ("fused-int8", "fp"), ("fused-int8", "int8")]

    def serve(mesh, path, kv):
        p, quant = ((params, ql.W8A8_CROSSQUANT) if path == "fake"
                    else (qparams, ql.W8A8_INT8))
        eng = E.ServeEngine(cfg, p, batch_size=2, max_len=32, quant=quant,
                            path=path, kv_cache=kv, mesh=mesh)
        eng.submit([x.copy() for x in prompts], max_new=list(MAX_NEW))
        done = eng.run()
        assert eng.counters["mid_decode_admissions"] > 0   # 4 requests, 2 slots
        return {r.rid: r.out for r in done}

    fails = []
    base = {c: serve(None, *c) for c in COMBOS}
    for tp in (2, 4):
        mesh = make_debug_mesh(8 // tp, tp)
        for c in COMBOS:
            got = serve(mesh, *c)
            ok = got == base[c]
            print(f"tp={tp} path={c[0]} kv={c[1]}: "
                  f"{'OK' if ok else 'MISMATCH ' + repr((got, base[c]))}",
                  flush=True)
            if not ok:
                fails.append((tp, c))

    # N:M structured sparsity (DESIGN.md §3.12) at tp=2: the packed mask leaves
    # shard like their qw (column-parallel masks split d_out; row-parallel masks
    # split the packed axis at byte granularity), and the sparse fused-int8
    # engine must emit exactly the single-device sparse tokens.
    from repro.models import quantize as MQ
    sparams = MQ.sparsify_tree(qparams, MQ.SparsityPlan(nm=(2, 4)))

    def serve_sparse(mesh):
        eng = E.ServeEngine(cfg, sparams, batch_size=2, max_len=32,
                            quant=ql.W8A8_INT8, path="fused-int8",
                            kv_cache="int8", mesh=mesh)
        eng.submit([x.copy() for x in prompts], max_new=list(MAX_NEW))
        return {r.rid: r.out for r in eng.run()}

    sp_base = serve_sparse(None)
    sp_got = serve_sparse(make_debug_mesh(4, 2))
    ok = sp_got == sp_base
    print(f"sparse 2:4 tp=2 fused-int8/int8: "
          f"{'OK' if ok else 'MISMATCH ' + repr((sp_got, sp_base))}", flush=True)
    if not ok:
        fails.append(("sparse-tp2",))

    # Paged layout (DESIGN.md §3.8) at tp=2: the page pool + radix prefix reuse
    # must emit exactly the single-device *dense* tokens on a workload with
    # shared-prefix admissions (warm suffix prefill, page-table-routed decode,
    # pool sharded kv-heads-over-model / pages-over-data).
    sharedp = rng.integers(1, cfg.vocab, size=16).astype(np.int32)
    pprompts = prompts[:2] + [
        np.concatenate([sharedp,
                        rng.integers(1, cfg.vocab, size=4 + i).astype(np.int32)])
        for i in range(2)]
    PMAX_NEW = [4, 3, 5, 4]

    def serve_paged(mesh, path, kv, layout):
        p, quant = ((params, ql.W8A8_CROSSQUANT) if path == "fake"
                    else (qparams, ql.W8A8_INT8))
        eng = E.ServeEngine(cfg, p, batch_size=2, max_len=32, quant=quant,
                            path=path, kv_cache=kv, mesh=mesh,
                            cache_layout=layout, page_size=8)
        eng.submit([x.copy() for x in pprompts], max_new=list(PMAX_NEW))
        done = eng.run()
        return {r.rid: r.out for r in done}, eng

    mesh2 = make_debug_mesh(4, 2)
    for c in COMBOS:
        dense_base, _ = serve_paged(None, *c, "dense")
        got, eng = serve_paged(mesh2, *c, "paged")
        ok = got == dense_base and eng.counters["prefix_hits"] > 0
        print(f"paged tp=2 path={c[0]} kv={c[1]} "
              f"hits={eng.counters['prefix_hits']}: "
              f"{'OK' if ok else 'MISMATCH ' + repr((got, dense_base))}",
              flush=True)
        if not ok:
            fails.append(("paged", c))

    # Speculative decoding (DESIGN.md §3.9) at tp=2 through the paged int8
    # path: speculate=4 draft windows verified by the sharded multi-token
    # kernel must emit exactly the single-device non-speculative tokens. One
    # case — the headline fused-int8 + int8-KV combo; the full speculative
    # matrix runs single-device in tier-1 (tests/test_speculative.py).
    motif = rng.integers(1, cfg.vocab, size=4).astype(np.int32)
    sprompts = [np.tile(motif, 3), pprompts[1], np.tile(motif[:3], 2)]
    # budgets long enough for the greedy streams to settle into the repeated
    # continuations the prompt-lookup drafter can ride — short budgets decode
    # the whole workload before any draft is accepted, and the acceptance
    # assertion below would then vacuously test nothing but overhead
    SMAX_NEW = [16, 12, 20]

    def serve_spec(mesh, speculate):
        eng = E.ServeEngine(cfg, qparams, batch_size=2, max_len=32,
                            quant=ql.W8A8_INT8, path="fused-int8",
                            kv_cache="int8", mesh=mesh, cache_layout="paged",
                            page_size=8, speculate=speculate)
        eng.submit([x.copy() for x in sprompts], max_new=list(SMAX_NEW))
        done = eng.run()
        return {r.rid: r.out for r in done}, eng

    spec_base, _ = serve_spec(None, 1)
    spec_got, eng = serve_spec(mesh2, 4)
    ok = spec_got == spec_base and eng.counters["spec_accepted"] > 0
    print(f"spec tp=2 fused-int8/int8 paged accept={eng.accept_rate():.2f}: "
          f"{'OK' if ok else 'MISMATCH ' + repr((spec_got, spec_base))}",
          flush=True)
    if not ok:
        fails.append(("speculative-tp2",))

    # Chunked mixed-budget scheduling (DESIGN.md §3.10) at tp=2: the packed
    # ragged launch (decode rows + prefill chunks in one forward) under a
    # TP-sharded plan must emit exactly the single-device *unchunked* paged
    # tokens. token_budget=10 forces multi-chunk prompts on this workload.
    def serve_chunked(mesh, **kw):
        eng = E.ServeEngine(cfg, qparams, batch_size=2, max_len=32,
                            quant=ql.W8A8_INT8, path="dequant-fp",
                            kv_cache="fp", mesh=mesh, cache_layout="paged",
                            page_size=8, **kw)
        eng.submit([x.copy() for x in pprompts], max_new=list(PMAX_NEW))
        done = eng.run()
        return {r.rid: r.out for r in done}, eng

    chunk_base, _ = serve_chunked(None)
    chunk_got, eng = serve_chunked(mesh2, chunked=True, token_budget=10)
    ok = chunk_got == chunk_base and eng.counters["chunk_prefill_rows"] > 0
    print(f"chunked tp=2 dequant-fp/fp paged "
          f"chunk_steps={eng.counters['chunk_steps']}: "
          f"{'OK' if ok else 'MISMATCH ' + repr((chunk_got, chunk_base))}",
          flush=True)
    if not ok:
        fails.append(("chunked-tp2",))

    # Expert-parallel MoE serving (DESIGN.md §3.13): a mesh with an "expert"
    # axis shards the stacked (E, ...) int8 expert trees over whole experts
    # (planner moe_mode "expert_axis") with the router replicated, so every
    # expert's int32 GEMM stays shard-local and EP fused-int8 serving is
    # token-exact vs single-device — at pure ep=2 and composed tp=2 x ep=2.
    for moe_name in ("granite-moe-3b-a800m", "llama4-scout-17b-a16e"):
        mcfg = dataclasses.replace(get(moe_name, smoke=True), dtype="float32")
        mparams = M.init_params(jax.random.PRNGKey(1), mcfg)
        mq = quantize_tree(mparams, ql.W8A8_INT8)
        mprompts = [rng.integers(1, mcfg.vocab, size=n).astype(np.int32)
                    for n in LENS]

        def serve_moe(mesh):
            eng = E.ServeEngine(mcfg, mq, batch_size=2, max_len=32,
                                quant=ql.W8A8_INT8, path="fused-int8",
                                kv_cache="int8", mesh=mesh)
            if mesh is not None:
                assert eng.plan.moe_mode == "expert_axis", eng.plan
                assert eng.plan.ep == 2
            eng.submit([x.copy() for x in mprompts], max_new=list(MAX_NEW))
            done = eng.run()
            assert eng.counters["mid_decode_admissions"] > 0
            return {r.rid: r.out for r in done}

        moe_base = serve_moe(None)
        for tag, mesh in (("ep2", make_debug_mesh(4, 1, 2)),
                          ("tp2xep2", make_debug_mesh(2, 2, 2))):
            got = serve_moe(mesh)
            ok = got == moe_base
            print(f"moe {moe_name} {tag} fused-int8/int8: "
                  f"{'OK' if ok else 'MISMATCH ' + repr((got, moe_base))}",
                  flush=True)
            if not ok:
                fails.append(("moe", moe_name, tag))

    # row-parallel int32-accumulator ordering (ref backend, bitwise)
    mesh = make_debug_mesh(4, 2)
    node = jax.tree_util.tree_map(lambda a: a[0], qparams["blocks"][0])["mlp"]["down"]
    x = jnp.asarray(rng.standard_normal((16, node["qw"].shape[0])), jnp.float32)
    repl = NamedSharding(mesh, P())
    sh = {"qw": NamedSharding(mesh, P("model", None)), "sw": repl,
          "bcol": NamedSharding(mesh, P("model")), "qalpha": repl}

    def row_parallel(p, x):
        with hints.sharding_hints(dp_axes=("data",), tp_axis="model", mesh=mesh):
            return ql.apply(p, x, ql.W8A8_INT8, int_exec="ref")

    y_sharded = jax.jit(row_parallel, in_shardings=(sh, repl),
                        out_shardings=repl)(jax.device_put(node, sh), x)
    y_single = jax.jit(
        lambda p, x: ql.apply(p, x, ql.W8A8_INT8, int_exec="ref"))(node, x)
    bitwise = bool((np.asarray(y_sharded) == np.asarray(y_single)).all())
    print(f"row-parallel ref int8 bitwise: {bitwise}", flush=True)
    if not bitwise:
        fails.append(("row-parallel-bitwise",))

    print("FAILURES: " + repr(fails) if fails else "ALL-PARITY-OK", flush=True)
""")


@pytest.mark.skipif(os.environ.get("REPRO_SKIP_SHARDED") == "1",
                    reason="sharded-serving CI job runs this file")
def test_sharded_serving_matrix_subprocess():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=1800,
                       env={**os.environ, "PYTHONPATH": src})
    assert "ALL-PARITY-OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])
