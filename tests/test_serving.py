"""Serving engine: continuous batcher correctness against step-by-step greedy
decoding, plus quantized-tree serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def small():
    cfg = get("starcoder2-7b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(cfg, params, prompt, max_new):
    """Decode greedily via repeated full forward passes (no cache)."""
    toks = list(prompt)
    out = []
    for _ in range(max_new):
        logits, _ = M.apply(params, {"tokens": jnp.asarray([toks], jnp.int32)},
                            cfg, mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        if nxt == 0:
            break
        toks.append(nxt)
    return out


class TestServeEngine:
    def test_matches_full_forward_greedy(self, small):
        cfg, params = small
        engine = ServeEngine(cfg, params, batch_size=2, max_len=48, eos_id=0)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab, size=8).astype(np.int32)
                   for _ in range(2)]
        engine.submit(prompts, max_new=6)
        done = engine.run()
        for r in done:
            want = _greedy_reference(cfg, params, r.prompt.tolist(), 6)
            # bf16 cache vs fp32 full-forward can diverge after the first token if
            # two logits are near-equal; require the first tokens to match.
            assert r.out[0] == want[0], (r.out, want)

    def test_groups_by_prompt_length(self, small):
        cfg, params = small
        engine = ServeEngine(cfg, params, batch_size=4, max_len=32, eos_id=-1)
        rng = np.random.default_rng(1)
        prompts = ([rng.integers(1, cfg.vocab, size=4).astype(np.int32)] * 3
                   + [rng.integers(1, cfg.vocab, size=9).astype(np.int32)] * 2)
        engine.submit(prompts, max_new=2)
        done = engine.run()
        assert len(done) == 5
        assert all(len(r.out) >= 1 for r in done)

    def test_serves_prepared_int8_tree(self, small):
        cfg, params = small
        qparams = quantize_tree(params, ql.W8A8_INT8)
        engine = ServeEngine(cfg, qparams, batch_size=2, max_len=32,
                             quant=ql.W8A8_INT8, eos_id=-1)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(1, cfg.vocab, size=6).astype(np.int32)
                   for _ in range(2)]
        engine.submit(prompts, max_new=3)
        done = engine.run()
        assert all(len(r.out) == 3 for r in done)

    def test_max_len_respected(self, small):
        cfg, params = small
        engine = ServeEngine(cfg, params, batch_size=1, max_len=12, eos_id=-1)
        prompts = [np.arange(1, 9, dtype=np.int32)]
        engine.submit(prompts, max_new=100)
        done = engine.run()
        assert len(done[0].out) <= 12 - 8 + 1
