"""Serving engine: continuous batcher correctness against step-by-step greedy
decoding, quantized-tree serving, and the §3.13 state-pool occupancy split."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def small():
    cfg = get("starcoder2-7b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(cfg, params, prompt, max_new):
    """Decode greedily via repeated full forward passes (no cache)."""
    toks = list(prompt)
    out = []
    for _ in range(max_new):
        logits, _ = M.apply(params, {"tokens": jnp.asarray([toks], jnp.int32)},
                            cfg, mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        if nxt == 0:
            break
        toks.append(nxt)
    return out


class TestServeEngine:
    def test_matches_full_forward_greedy(self, small):
        cfg, params = small
        engine = ServeEngine(cfg, params, batch_size=2, max_len=48, eos_id=0)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab, size=8).astype(np.int32)
                   for _ in range(2)]
        engine.submit(prompts, max_new=6)
        done = engine.run()
        for r in done:
            want = _greedy_reference(cfg, params, r.prompt.tolist(), 6)
            # bf16 cache vs fp32 full-forward can diverge after the first token if
            # two logits are near-equal; require the first tokens to match.
            assert r.out[0] == want[0], (r.out, want)

    def test_groups_by_prompt_length(self, small):
        cfg, params = small
        engine = ServeEngine(cfg, params, batch_size=4, max_len=32, eos_id=-1)
        rng = np.random.default_rng(1)
        prompts = ([rng.integers(1, cfg.vocab, size=4).astype(np.int32)] * 3
                   + [rng.integers(1, cfg.vocab, size=9).astype(np.int32)] * 2)
        engine.submit(prompts, max_new=2)
        done = engine.run()
        assert len(done) == 5
        assert all(len(r.out) >= 1 for r in done)

    def test_serves_prepared_int8_tree(self, small):
        cfg, params = small
        qparams = quantize_tree(params, ql.W8A8_INT8)
        engine = ServeEngine(cfg, qparams, batch_size=2, max_len=32,
                             quant=ql.W8A8_INT8, eos_id=-1)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(1, cfg.vocab, size=6).astype(np.int32)
                   for _ in range(2)]
        engine.submit(prompts, max_new=3)
        done = engine.run()
        assert all(len(r.out) == 3 for r in done)

    def test_max_len_respected(self, small):
        cfg, params = small
        engine = ServeEngine(cfg, params, batch_size=1, max_len=12, eos_id=-1)
        prompts = [np.arange(1, 9, dtype=np.int32)]
        engine.submit(prompts, max_new=100)
        done = engine.run()
        assert len(done[0].out) <= 12 - 8 + 1


class TestStatePoolOccupancy:
    """§3.13: the shared page pool's occupancy splits into attention-KV pages
    vs SSM state-checkpoint pages, exposed through ``stats().to_dict()``."""

    def _serve(self, name, **kw):
        cfg = dataclasses.replace(get(name, smoke=True), dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, config=EngineConfig(
            batch_size=2, max_len=32, cache_layout="paged", **kw))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
                   for n in (5, 9, 6)]
        eng.submit(prompts, max_new=3)
        eng.run()
        return eng

    def test_attention_family_is_all_kv(self, small):
        eng = self._serve("starcoder2-7b")
        d = eng.stats().to_dict()
        assert d["peak_kv_pages_in_use"] > 0
        assert d["state_pages_in_use"] == d["peak_state_pages_in_use"] == 0
        # drained engine: only radix-cached prefixes may still hold pages
        assert d["kv_pages_in_use"] == eng.pool.used_count

    def test_ssm_family_is_all_state(self):
        eng = self._serve("mamba2-130m", prefix_reuse=False)
        d = eng.stats().to_dict()
        # one checkpoint page per concurrently resident slot, zero KV
        assert d["peak_state_pages_in_use"] == 2
        assert d["peak_kv_pages_in_use"] == 0
        # every retirement returned its checkpoint page to the pool
        assert d["state_pages_in_use"] == 0 and eng.pool.used_count == 0

    def test_hybrid_family_holds_both_kinds(self):
        eng = self._serve("zamba2-1.2b", prefix_reuse=False)
        d = eng.stats().to_dict()
        assert d["peak_state_pages_in_use"] == 2
        assert d["peak_kv_pages_in_use"] > 0
        assert d["peak_pages_in_use"] >= max(d["peak_kv_pages_in_use"],
                                             d["peak_state_pages_in_use"])
        assert d["state_pages_in_use"] == 0 and eng.pool.used_count == 0
