"""Fused int8 serving path (DESIGN.md §3.3).

Parity chain pinned here, all in interpreter mode on CPU:

  act_quantize kernel + qgemm kernel  ==  fake_crossquant + fp GEMM   (layer level)
  fused-int8 model logits             ==  fake-quant twin logits      (model level)
  ref / dequant-fp / pallas           ==  each other                  (exec modes)

plus the int8 KV cache and the continuous batcher running end-to-end on the fused
path. No hypothesis dependency: this module must run on minimal installs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import calibration, qlinear as ql
from repro.core import quantizers as Q
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.models.quantize import dequantize_tree, quantize_tree
from repro.serving import engine as E


# ======================================================================================
# Layer-level pipeline parity
# ======================================================================================

class TestPipelineParity:
    def test_w8a8_pipeline_matches_fake_crossquant_fp_gemm(self):
        """act_quantize -> qgemm_w8a8 (interpret mode) == fake_crossquant + fp GEMM
        on the dequantized prepared weight: the two paths share one quantization
        grid, so they agree to f32 ulp — far inside int8 tolerance."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        d_in, d_out, T = 256, 128, 64
        w = jax.random.normal(k1, (d_in, d_out)) * 0.1
        x = jax.random.normal(k2, (T, d_in)) * 2
        cmax = jnp.max(jnp.abs(x), axis=0)
        cfg = ql.W8A8_INT8
        prep = ql.prepare_int8({"w": w}, cfg, cmax=cmax)
        y_fused = ql.apply(prep, x, cfg, use_pallas=True)
        w_fq = (prep["qw"].astype(jnp.float32) * prep["sw"]) / prep["bcol"][:, None]
        y_fake = Q.fake_crossquant(x, 8, cfg.alpha, col_max=cmax) @ w_fq
        np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_fake),
                                   rtol=1e-4, atol=1e-2)

    @pytest.mark.parametrize("shape", [(64, 256, 128), (1, 384, 256)])
    def test_w8a8_exec_modes_agree(self, shape):
        """ref (int32 einsum), dequant (fp GEMM) and pallas (fused kernels) are three
        executions of the same function."""
        T, d_in, d_out = shape
        k1, k2 = jax.random.split(jax.random.PRNGKey(T))
        prep = ql.prepare_int8({"w": jax.random.normal(k1, (d_in, d_out)) * 0.1},
                               ql.W8A8_INT8)
        x = jax.random.normal(k2, (T, d_in)) * 2
        y_ref = ql.apply(prep, x, ql.W8A8_INT8)
        y_dq = ql.apply(prep, x, ql.W8A8_INT8, int_exec="dequant")
        y_pl = ql.apply(prep, x, ql.W8A8_INT8, int_exec="pallas")
        np.testing.assert_allclose(np.asarray(y_dq), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3)

    def test_w4a8_exec_modes_agree(self):
        cfg = dataclasses.replace(ql.W4A8_G128, mode="int8")
        k1, k2 = jax.random.split(jax.random.PRNGKey(9))
        d_in, d_out, T = 256, 128, 48
        prep = ql.prepare_int4({"w": jax.random.normal(k1, (d_in, d_out)) * 0.1}, cfg)
        x = jax.random.normal(k2, (T, d_in))
        y_ref = ql.apply(prep, x, cfg)
        y_dq = ql.apply(prep, x, cfg, int_exec="dequant")
        y_pl = ql.apply(prep, x, cfg, int_exec="pallas")
        np.testing.assert_allclose(np.asarray(y_dq), np.asarray(y_ref),
                                   rtol=2e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                                   rtol=2e-4, atol=1e-3)

    def test_batched_activations_flatten_to_gemm(self):
        """(B, S, d) activations route through the 2-D kernels via token flattening."""
        prep = ql.prepare_int8(
            {"w": jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 0.1},
            ql.W8A8_INT8)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 128))
        y_ref = ql.apply(prep, x, ql.W8A8_INT8)
        y_pl = ql.apply(prep, x, ql.W8A8_INT8, int_exec="pallas")
        assert y_pl.shape == (2, 16, 64)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3)


# ======================================================================================
# Model-level parity (the acceptance gate: fused-int8 vs fake-quant, atol=1e-2)
# ======================================================================================

@pytest.fixture(scope="module")
def calibrated():
    """f32 smoke model + calibrated int8 tree + its fake-quant twin."""
    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    obs = calibration.Observer()
    M.apply(params, {"tokens": toks}, cfg,
            ctx=QuantContext(ql.W8A8_CROSSQUANT, observer=obs),
            mode="train", unroll=True)
    tables = calibration.stack_tables(obs.tables())
    qtree = quantize_tree(params, ql.W8A8_INT8, tables=tables)
    fq_tree = dequantize_tree(qtree, ql.W8A8_INT8)
    return cfg, toks, qtree, fq_tree


class TestModelParity:
    def test_fused_int8_logits_match_fake_quant(self, calibrated):
        cfg, toks, qtree, fq_tree = calibrated
        fake_cfg = dataclasses.replace(ql.W8A8_CROSSQUANT, static_c=True,
                                       w_prequantized=True)
        logits_fused, _ = M.apply(qtree, {"tokens": toks}, cfg,
                                  ctx=QuantContext(ql.W8A8_INT8, use_pallas=True),
                                  mode="train")
        logits_fake, _ = M.apply(fq_tree, {"tokens": toks}, cfg,
                                 ctx=QuantContext(fake_cfg), mode="train")
        np.testing.assert_allclose(np.asarray(logits_fused), np.asarray(logits_fake),
                                   atol=1e-2)

    def test_serving_prefill_paths_agree(self, calibrated):
        """make_prefill_step on {dequant-fp, fused-int8} matches the ref backend."""
        cfg, toks, qtree, _ = calibrated
        caches = M.init_cache(cfg, toks.shape[0], 48, dtype=jnp.float32)
        ref_step = E.make_prefill_step(cfg, ql.W8A8_INT8)
        logits_ref, _ = ref_step(qtree, {"tokens": toks}, caches)
        for path in ("dequant-fp", "fused-int8"):
            step = E.make_prefill_step(cfg, ql.W8A8_INT8, path=path)
            logits, _ = step(qtree, {"tokens": toks}, caches)
            np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                                       atol=1e-2, err_msg=path)


# ======================================================================================
# int8 KV cache
# ======================================================================================

class TestInt8KVCache:
    def test_cache_layout(self):
        cfg = get("starcoder2-7b", smoke=True)
        caches = M.init_cache(cfg, 2, 32, kv_int8=True)
        blk = caches["blocks"][0]
        assert blk["k"].dtype == jnp.int8 and blk["v"].dtype == jnp.int8
        assert blk["k_scale"].dtype == jnp.float32
        assert blk["k_scale"].shape == blk["k"].shape[:-1] + (1,)

    def test_decode_close_to_fp_cache(self):
        """Prefill + a few decode steps with the int8 KV cache track the fp-cache
        logits within int8 rounding of K/V."""
        cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
        prefill = E.make_prefill_step(cfg)
        decode = E.make_decode_step(cfg)

        outs = {}
        for kv_int8 in (False, True):
            caches = M.init_cache(cfg, 2, 24, dtype=jnp.float32, kv_int8=kv_int8)
            logits, caches = prefill(params, {"tokens": toks}, caches)
            steps = [logits]
            cur = toks.shape[1]
            for _ in range(3):
                nxt = jnp.argmax(steps[-1][:, -1], axis=-1).astype(jnp.int32)
                cur += 1
                logits, caches = decode(params, nxt[:, None], caches,
                                        jnp.asarray(cur, jnp.int32))
                steps.append(logits)
            outs[kv_int8] = jnp.concatenate(steps, axis=1)
        err = float(jnp.max(jnp.abs(outs[True] - outs[False])))
        scale = float(jnp.max(jnp.abs(outs[False]))) + 1e-9
        assert err / scale < 0.05, (err, scale)


# ======================================================================================
# Continuous batcher on the fused path
# ======================================================================================

class TestServeEngineFused:
    @pytest.fixture(scope="class")
    def smoke(self):
        cfg = get("starcoder2-7b", smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        return cfg, params, quantize_tree(params, ql.W8A8_INT8)

    def _prompts(self, cfg, n=2, seed=2):
        rng = np.random.default_rng(seed)
        return [rng.integers(1, cfg.vocab, size=6).astype(np.int32)
                for _ in range(n)]

    @pytest.mark.parametrize("path,kv", [("dequant-fp", "fp"),
                                         ("fused-int8", "fp"),
                                         ("fused-int8", "int8")])
    def test_paths_serve_to_completion(self, smoke, path, kv):
        cfg, _, qtree = smoke
        eng = E.ServeEngine(cfg, qtree, batch_size=2, max_len=32,
                            quant=ql.W8A8_INT8, eos_id=-1, path=path, kv_cache=kv)
        eng.submit(self._prompts(cfg), max_new=3)
        done = eng.run()
        assert len(done) == 2 and all(len(r.out) == 3 for r in done)

    def test_dequant_fp_first_token_matches_ref(self, smoke):
        cfg, _, qtree = smoke
        firsts = {}
        for path in (None, "dequant-fp"):
            eng = E.ServeEngine(cfg, qtree, batch_size=2, max_len=32,
                                quant=ql.W8A8_INT8, eos_id=-1, path=path)
            eng.submit(self._prompts(cfg), max_new=2)
            firsts[path] = [r.out[0] for r in eng.run()]
        assert firsts[None] == firsts["dequant-fp"]

    def test_unknown_path_rejected(self, smoke):
        cfg, params, _ = smoke
        with pytest.raises(ValueError, match="serving path"):
            E.ServeEngine(cfg, params, batch_size=1, max_len=16, path="int4-magic")
