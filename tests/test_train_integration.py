"""End-to-end training integration: loss goes down, microbatching is exact,
checkpoint-resume reproduces, gradient compression trains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.data import make_train_batches
from repro.models import model as M
from repro.training import compression as comp_lib
from repro.training import optimizer as opt_lib, trainer


@pytest.fixture(scope="module")
def setup():
    cfg = get("mamba2-130m", smoke=True)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    opt_cfg = opt_lib.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40)
    batch_fn = make_train_batches(cfg.vocab, 32, 8, seed=0)
    return cfg, params, opt_cfg, batch_fn


class TestTraining:
    def test_loss_decreases(self, setup):
        cfg, params, opt_cfg, batch_fn = setup
        step = jax.jit(trainer.make_train_step(cfg, opt_cfg))
        opt = opt_lib.init(params)
        losses = []
        for s in range(25):
            batch = {k: jnp.asarray(v) for k, v in batch_fn(s).items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses

    def test_microbatching_matches_full_batch(self, setup):
        """Gradient accumulation over n_micro must equal the single-batch gradient.

        Compared via the first Adam moment (m = (1-b1)·g after step 1): the params
        themselves are ill-conditioned for comparison — the first AdamW update is
        sign-like (m̂/√v̂ ≈ ±1), so fp32 accumulation-order noise flips whole ±lr
        steps on near-zero-gradient weights."""
        cfg, params, opt_cfg, batch_fn = setup
        batch = {k: jnp.asarray(v) for k, v in batch_fn(0).items()}
        s1 = jax.jit(trainer.make_train_step(cfg, opt_cfg, n_micro=1))
        s4 = jax.jit(trainer.make_train_step(cfg, opt_cfg, n_micro=4))
        opt = opt_lib.init(params)
        p1, o1, m1 = s1(params, opt, batch)
        p4, o4, m4 = s4(params, opt, batch)
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-5
        # bf16 forwards at different microbatch shapes round differently; observed
        # relative gradient deltas are ~3e-3 on this model.
        for a, b in zip(jax.tree_util.tree_leaves(o1.m),
                        jax.tree_util.tree_leaves(o4.m)):
            scale = float(jnp.max(jnp.abs(a))) + 1e-8
            np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                                       atol=6e-3)

    def test_checkpoint_resume_bitwise(self, setup, tmp_path):
        cfg, params0, opt_cfg, batch_fn = setup
        step = jax.jit(trainer.make_train_step(cfg, opt_cfg))

        def advance(params, opt, a, b):
            for s in range(a, b):
                batch = {k: jnp.asarray(v) for k, v in batch_fn(s).items()}
                params, opt, _ = step(params, opt, batch)
            return params, opt

        # straight run 0..8
        p_ref, o_ref = advance(params0, opt_lib.init(params0), 0, 8)

        # run 0..5, checkpoint, restore, run 5..8
        p, o = advance(params0, opt_lib.init(params0), 0, 5)
        cm = CheckpointManager(str(tmp_path))
        cm.save(5, {"p": p, "o": o}, blocking=True)
        restored, s = cm.restore({"p": p, "o": o})
        p2, o2 = advance(restored["p"], restored["o"], 5, 8)

        for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_compressed_training_converges(self, setup):
        cfg, params, opt_cfg, batch_fn = setup
        ccfg = comp_lib.CompressionConfig()
        step = jax.jit(trainer.make_train_step(cfg, opt_cfg, compression=ccfg))
        opt = opt_lib.init(params)
        err = comp_lib.init_error_state(params)
        losses = []
        for s in range(25):
            batch = {k: jnp.asarray(v) for k, v in batch_fn(s).items()}
            params, opt, err, m = step(params, opt, err, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15, losses

    def test_pick_n_micro_divides(self):
        cfg = get("deepseek-coder-33b")
        for gb, dp in [(256, 16), (256, 32), (128, 16), (96, 16)]:
            nm = trainer.pick_n_micro(cfg, gb, dp)
            assert gb % nm == 0, (gb, dp, nm)
