"""Calibration (static-c) pipeline: observer statistics, table attachment, and the
end-to-end quantize_tree flow."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import calibration, qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.models.quantize import quantize_tree


class TestObserver:
    def test_hard_max_accumulates(self):
        obs = calibration.Observer()
        obs.observe("l", jnp.asarray([[1.0, -2.0], [0.5, 1.0]]))
        obs.observe("l", jnp.asarray([[3.0, 0.1], [0.2, 0.3]]))
        np.testing.assert_allclose(obs.tables()["l"], [3.0, 2.0])

    def test_momentum_ema(self):
        obs = calibration.Observer(momentum=0.5)
        obs.observe("l", jnp.asarray([[2.0, 2.0]]))
        obs.observe("l", jnp.asarray([[4.0, 0.0]]))
        np.testing.assert_allclose(obs.tables()["l"], [3.0, 1.0])

    def test_batch_dims_flattened(self):
        obs = calibration.Observer()
        x = jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4)
        obs.observe("l", x)
        np.testing.assert_allclose(obs.tables()["l"], [20, 21, 22, 23])


class TestEndToEnd:
    def test_model_calibration_flow(self, key):
        """Eager (unroll) forward with an observer records every linear; the tables
        feed quantize_tree and the int8 model still runs."""
        cfg = get("starcoder2-7b", smoke=True)
        params = M.init_params(key, cfg)
        obs = calibration.Observer()
        ctx = QuantContext(ql.W8A8_CROSSQUANT, observer=obs)
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
        M.apply(params, batch, cfg, ctx=ctx, mode="train", unroll=True)
        raw = obs.tables()
        assert len(raw) >= 4 * cfg.n_layers          # wq/wk/wv/wo + mlp × layers
        for name, t in raw.items():
            assert t.ndim == 1 and (t >= 0).all(), name
        tables = calibration.stack_tables(raw)
        # stacked per-layer tables keyed by parameter path
        assert "blocks/0/attn/wq" in tables
        assert tables["blocks/0/attn/wq"].shape == (cfg.n_layers, cfg.d_model)

        qparams = quantize_tree(params, ql.W8A8_INT8, tables=tables)
        logits_q, _ = M.apply(qparams, batch, cfg, ctx=QuantContext(ql.W8A8_INT8),
                              mode="train")
        logits_f, _ = M.apply(params, batch, cfg, mode="train")
        assert not bool(jnp.any(jnp.isnan(logits_q)))
        # int8 static-c serving tracks the fp model (kernel is small on smoke data)
        rel = float(jnp.linalg.norm(logits_q - logits_f) /
                    jnp.linalg.norm(logits_f))
        assert rel < 0.35, rel

    def test_quantize_tree_shrinks_bytes(self, key):
        from repro.models.quantize import quantized_bytes
        cfg = get("starcoder2-7b", smoke=True)
        params = M.init_params(key, cfg)
        q8 = quantize_tree(params, ql.W8A8_INT8)
        assert quantized_bytes(q8) < 0.55 * quantized_bytes(params)
