"""Quantized-linear layer: execution-mode semantics + int8-path exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import qlinear as ql
from repro.core import quantizers as Q
from repro.data.synthetic import OPT_LIKE, outlier_activations

SET = dict(max_examples=15, deadline=None)


def _params(key, d_in, d_out, n_stack=None):
    return ql.init(key, d_in, d_out, n_stack=n_stack)


class TestModes:
    @pytest.mark.parametrize("cfg", [ql.FP, ql.W8A8_CROSSQUANT, ql.W8A8_PER_TOKEN,
                                     ql.W4A8_G128, ql.W4A4, ql.W8A8_INT8])
    def test_all_modes_run_2d(self, key, cfg):
        p = _params(key, 128, 64)
        x = jax.random.normal(key, (8, 128))
        y = ql.apply(p, x, cfg)
        assert y.shape == (8, 64)
        assert not bool(jnp.any(jnp.isnan(y)))

    @pytest.mark.parametrize("cfg", [ql.FP, ql.W8A8_CROSSQUANT])
    def test_stacked_experts_3d(self, key, cfg):
        p = _params(key, 32, 16, n_stack=4)
        x = jax.random.normal(key, (4, 8, 32))
        y = ql.apply(p, x, cfg)
        assert y.shape == (4, 8, 16)

    def test_fake_mode_close_to_fp(self, key):
        """W8A8 CrossQuant fake quant should track the fp output closely (the paper's
        'negligible precision loss' claim at INT8)."""
        p = _params(key, 256, 128)
        x = jnp.asarray(outlier_activations(64, 256, OPT_LIKE, seed=0))
        y_fp = ql.apply(p, x, ql.FP)
        y_cq = ql.apply(p, x, ql.W8A8_CROSSQUANT)
        y_pt = ql.apply(p, x, ql.W8A8_PER_TOKEN)
        err_cq = float(jnp.linalg.norm(y_cq - y_fp) / jnp.linalg.norm(y_fp))
        err_pt = float(jnp.linalg.norm(y_pt - y_fp) / jnp.linalg.norm(y_fp))
        assert err_cq < err_pt, (err_cq, err_pt)   # Fig. 1 ordering
        assert err_cq < 0.05, err_cq

    def test_prequantized_weights_bitwise_equal(self, key):
        from repro.models.quantize import fake_quantize_weights
        cfg = ql.W8A8_CROSSQUANT
        p = {"wq": _params(key, 64, 32)}
        x = jax.random.normal(key, (8, 64))
        y_in_graph = ql.apply(p["wq"], x, cfg)
        pq = fake_quantize_weights(p, cfg)
        y_offline = ql.apply(pq["wq"], x, dataclasses.replace(cfg, w_prequantized=True))
        np.testing.assert_array_equal(np.asarray(y_in_graph), np.asarray(y_offline))


class TestInt8Path:
    """The TPU-native static-c path must be exact w.r.t. its own fake-quant semantics
    (DESIGN.md §3.1): int8 GEMM + separable dequant == quantize-dequantize + fp GEMM
    when both use the same static column stats."""

    @settings(**SET)
    @given(st.integers(1, 6), st.integers(1, 4), st.integers(1, 4))
    def test_int8_matches_staticc_fake(self, seed, din_blk, dout_blk):
        d_in, d_out, T = 32 * din_blk, 16 * dout_blk, 24
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        w = jax.random.normal(k1, (d_in, d_out)) * 0.1
        x = jnp.asarray(outlier_activations(T, d_in, seed=seed))
        cmax = jnp.max(jnp.abs(x), axis=0)
        cfg = ql.W8A8_INT8

        # int8 path
        prepared = ql.prepare_int8({"w": w}, cfg, cmax=cmax)
        y_int = ql.apply(prepared, x, cfg)

        # reference: fake-quantize activations with static c, weights per-output-
        # channel on the b-folded weight, fp matmul
        b = jnp.maximum(cmax, Q.EPS) ** (1 - cfg.alpha)
        t = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), Q.EPS)
        a = (t ** cfg.alpha) / 127
        qx = jnp.clip(jnp.round(x / (a * b)), -127, 127)
        wb = w * b[:, None]
        sw = jnp.maximum(jnp.max(jnp.abs(wb), axis=0), Q.EPS) / 127
        qw = jnp.clip(jnp.round(wb / sw), -127, 127)
        y_ref = (qx @ qw) * a * sw
        np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_int8_kernel_geometry_preserved(self, key):
        """The int8 path's effective element scale is outer(a_i·qmax, b_j)·(1/qmax) =
        t^α c^(1-α)/qmax — the same kernel-shrinking geometry as eq. (5)."""
        x = jnp.asarray(outlier_activations(128, 256, OPT_LIKE, seed=7))
        cmax = jnp.max(jnp.abs(x), axis=0)
        cfg = ql.W8A8_INT8
        qx, a = ql.quantize_act_int8(x, jnp.maximum(cmax, Q.EPS) ** (1 - cfg.alpha), cfg)
        frac_int8 = float(jnp.mean((qx == 0) & (x != 0)))
        s_dyn = Q.crossquant_scale(x, 8, cfg.alpha, col_max=cmax)
        frac_fake = float(jnp.mean((jnp.abs(x) < 0.5 * s_dyn) & (x != 0)))
        assert abs(frac_int8 - frac_fake) < 0.01

    def test_prepare_int4_shapes(self, key):
        w = jax.random.normal(key, (256, 64))
        prepared = ql.prepare_int4({"w": w}, ql.W4A8_G128)
        assert prepared["qw4"].shape == (128, 64)
        assert prepared["sw"].shape == (2, 64)
        x = jax.random.normal(key, (8, 256))
        y = ql.apply(prepared, x, ql.W4A8_G128)
        y_fp = x @ w
        rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
        assert rel < 0.2, rel


class TestInt8StackedExperts:
    def test_int8_path_stacked_matches_fp(self, key):
        """Prepared int8 expert stacks (E, d_in, d_out) must track the fp einsum."""
        E, C, d_in, d_out = 4, 16, 64, 32
        k1, k2 = jax.random.split(key)
        w = jax.random.normal(k1, (E, d_in, d_out)) * 0.1
        x = jax.random.normal(k2, (E, C, d_in))
        cfg = ql.W8A8_INT8
        cmax = jnp.max(jnp.abs(x), axis=1)                 # (E, d_in)
        prepared = ql.prepare_int8({"w": w}, cfg, cmax=cmax)
        y = ql.apply(prepared, x, cfg)
        y_fp = jnp.einsum("eci,eio->eco", x, w)
        rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
        assert rel < 0.05, rel

    def test_int4_path_stacked_matches_fp(self, key):
        E, C, d_in, d_out = 2, 8, 128, 32
        k1, k2 = jax.random.split(key)
        w = jax.random.normal(k1, (E, d_in, d_out)) * 0.1
        x = jax.random.normal(k2, (E, C, d_in))
        cfg = dataclasses.replace(ql.W4A8_G128, mode="int8")
        prepared = ql.prepare_int4({"w": w}, cfg)
        y = ql.apply(prepared, x, cfg)
        y_fp = jnp.einsum("eci,eio->eco", x, w)
        rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
        assert rel < 0.25, rel
