"""Gradient compression: error-feedback convergence + CrossQuant-geometry kernel
shrinkage on gradients (the beyond-paper transplant, DESIGN.md §3.5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import OPT_LIKE, outlier_activations
from repro.training import compression as comp


class TestCompressLeaf:
    def test_roundtrip_error_small(self, key):
        g = jax.random.normal(key, (64, 128)) * 1e-3
        cfg = comp.CompressionConfig()
        ghat, err = comp.compress_leaf(g, jnp.zeros_like(g), cfg)
        rel = float(jnp.linalg.norm(ghat - g) / jnp.linalg.norm(g))
        assert rel < 0.05, rel

    def test_error_feedback_unbiased_over_steps(self, key):
        """Feeding the same gradient repeatedly: the *sum* of compressed updates must
        converge to the sum of true gradients (EF makes compression contractive)."""
        g = jnp.asarray(outlier_activations(32, 64, OPT_LIKE, seed=2)) * 1e-3
        cfg = comp.CompressionConfig()
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        T = 32
        for _ in range(T):
            ghat, err = comp.compress_leaf(g, err, cfg)
            acc = acc + ghat
        rel = float(jnp.linalg.norm(acc / T - g) / jnp.linalg.norm(g))
        assert rel < 0.02, rel

    def test_no_error_feedback_is_biased_on_outlier_grads(self):
        """Without EF, per-tensor int8 systematically drops small entries (the
        quantization-kernel failure mode) — EF must do strictly better."""
        g = jnp.asarray(outlier_activations(64, 128, OPT_LIKE, seed=3)) * 1e-3
        T = 16

        def run(cfg):
            err = jnp.zeros_like(g)
            acc = jnp.zeros_like(g)
            for _ in range(T):
                ghat, err = comp.compress_leaf(g, err, cfg)
                acc += ghat
            return float(jnp.linalg.norm(acc / T - g) / jnp.linalg.norm(g))
        with_ef = run(comp.CompressionConfig(scheme="per_tensor", error_feedback=True))
        without = run(comp.CompressionConfig(scheme="per_tensor", error_feedback=False))
        assert with_ef < without

    def test_small_leaves_pass_through(self, key):
        b = jax.random.normal(key, (64,))
        ghat, _ = comp.compress_leaf(b, jnp.zeros(()), comp.CompressionConfig())
        np.testing.assert_array_equal(np.asarray(ghat), np.asarray(b))


class TestKernelGeometry:
    def test_crossquant_kernel_smaller_than_per_tensor(self):
        g = jnp.asarray(outlier_activations(256, 512, OPT_LIKE, seed=1)) * 1e-3
        fr = comp.gradient_kernel_fractions(g)
        assert float(fr["crossquant"]) < 0.5 * float(fr["per_tensor"])

    def test_crossquant_scheme_better_single_shot(self):
        g = jnp.asarray(outlier_activations(128, 256, OPT_LIKE, seed=4)) * 1e-3

        def rel(scheme):
            cfg = comp.CompressionConfig(scheme=scheme, error_feedback=False)
            ghat, _ = comp.compress_leaf(g, jnp.zeros_like(g), cfg)
            return float(jnp.linalg.norm(ghat - g) / jnp.linalg.norm(g))
        assert rel("crossquant") < rel("per_tensor")


class TestTreeAPI:
    def test_compress_grads_tree(self, key):
        grads = {"a": {"w": jax.random.normal(key, (16, 16))},
                 "b": jax.random.normal(key, (8,))}
        err = comp.init_error_state(grads)
        ghat, new_err = comp.compress_grads(grads, err, comp.CompressionConfig())
        assert ghat["a"]["w"].shape == (16, 16)
        assert jax.tree_util.tree_structure(ghat) == jax.tree_util.tree_structure(grads)
