"""Config-zoo continuous serving (DESIGN.md §3.13): SSM and hybrid families
through the slot-table batcher.

The pre-§3.13 engine special-cased ``family in ("ssm", "hybrid")`` into
exact-length prefill groups; now mamba2/zamba2 serve through the same
length-bucketed padded admission, mid-decode retire+refill and donated-cache
decode as attention families, on the dense *and* paged layouts. The central
property stays token-exactness vs batch-size-1 greedy decode: right-padding
masks ``dt`` to zero at padded positions, which the SSD scan turns into
decay-1/update-0 recurrence no-ops (models/ssm.py), so the carried state is
exactly the exact-length state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.serving import engine as E
from repro.serving.config import EngineConfig

T = 32          # cache length for every engine in this module
LENS = [4, 7, 12, 9, 5]
MAX_NEW = [5, 3, 6, 2, 4]


# Prompt seed per family for the fake-path parity cases: the fake path's
# *dynamic* column statistic (quantizers.crossquant_scale with col_max=None)
# reduces over every row of the batch, so a multi-slot engine batch and the
# batch-size-1 reference see slightly different activation scales — the same
# empirical property the attention-family parity tests rely on: argmax margins
# absorb the perturbation for the pinned workload. The prepared-tree paths
# (dequant-fp / fused-int8) freeze column stats at quantize_tree time and are
# exact regardless of seed.
_PROMPT_SEED = {"mamba2-130m": 0, "zamba2-1.2b": 5}


@pytest.fixture(scope="module", params=["mamba2-130m", "zamba2-1.2b"])
def family(request):
    cfg = dataclasses.replace(get(request.param, smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    return cfg, params, qparams, _PROMPT_SEED[request.param]


def _mixed_prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=l).astype(np.int32) for l in LENS]


def _greedy_single(cfg, params, prompt, max_new, *, quant, path):
    """Batch-size-1 greedy decode through the raw step builders (exact-length
    prefill, scalar cur_len — the pre-§3.6 reference path)."""
    prefill = jax.jit(E.make_prefill_step(cfg, quant, path=path))
    decode = jax.jit(E.make_decode_step(cfg, quant, path=path))
    caches = M.init_cache(cfg, 1, T, dtype=jnp.float32)
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None])},
                             caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    cur = len(prompt)
    while len(out) < max_new and cur < T:
        cur += 1
        logits, caches = decode(params, jnp.asarray([[out[-1]]], jnp.int32),
                                caches, jnp.asarray(cur, jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


class TestZooSchedulerParity:
    """Mixed lengths + staggered max_new through the continuous batcher ==
    batch-size-1 greedy decode, token-exact, on every path × layout. With
    batch_size=2 and five requests, slots retire and refill mid-decode, so
    the masked-dt admission prefill, the per-slot state scatter and (paged)
    the state-page reuse of retired slots are all on the emitted-token path.
    """

    @pytest.mark.parametrize("layout", ["dense", "paged"])
    @pytest.mark.parametrize("path", ["fake", "dequant-fp", "fused-int8"])
    def test_mixed_workload_matches_bs1(self, family, path, layout):
        cfg, params, qparams, seed = family
        if path == "fake":
            serve_params, quant = params, ql.W8A8_CROSSQUANT
        else:
            serve_params, quant = qparams, ql.W8A8_INT8
        prompts = _mixed_prompts(cfg, seed=seed)
        ec = EngineConfig(batch_size=2, max_len=T, path=path,
                          cache_layout=layout, prefix_reuse=False)
        eng = E.ServeEngine(cfg, serve_params, config=ec, quant=quant)
        eng.submit(prompts, max_new=MAX_NEW)
        done = eng.run()
        # batch_size=2 < 5 requests: slots must have been refilled mid-decode
        assert eng.counters["mid_decode_admissions"] > 0
        assert [r.rid for r in done] == list(range(len(prompts)))
        for r in done:
            want = _greedy_single(cfg, serve_params, r.prompt, r.max_new,
                                  quant=quant, path=path)
            assert r.out == want, (path, layout, r.rid, r.out, want)

    def test_paged_state_page_reuse_is_clean(self, family):
        """A retired slot's state-checkpoint page goes back to the pool and is
        handed to a later admission; the admission prefill starts from a zero
        initial state, so the stale checkpoint must never leak (§3.13). Two
        waves through a minimal pool force the reuse."""
        cfg, params, _, _ = family
        ec = EngineConfig(batch_size=2, max_len=T, cache_layout="paged",
                          prefix_reuse=False)
        eng = E.ServeEngine(cfg, params, config=ec)
        prompts = _mixed_prompts(cfg, seed=3)
        eng.submit(prompts, max_new=MAX_NEW)
        done = eng.run()
        assert eng.stats().to_dict()["state_pages_in_use"] == 0
        for r in done:
            want = _greedy_single(cfg, params, r.prompt, r.max_new,
                                  quant=None, path=None)
            assert r.out == want, (r.rid, r.out, want)

    def test_batch_size_invariance(self, family):
        """Same workload, different batch sizes → identical per-request tokens
        (the slot table may schedule differently, the outputs must not)."""
        cfg, params, _, _ = family
        prompts = _mixed_prompts(cfg, seed=5)
        outs = {}
        for B in (1, 2, 4):
            eng = E.ServeEngine(cfg, params, config=EngineConfig(
                batch_size=B, max_len=T))
            eng.submit(prompts, max_new=MAX_NEW)
            outs[B] = {r.rid: r.out for r in eng.run()}
        assert outs[1] == outs[2] == outs[4]

    def test_grouped_baseline_matches_continuous(self, family):
        """The grouped scheduler (the §3.13 benchmark baseline for SSM) serves
        the same tokens; only the schedule differs."""
        cfg, params, _, _ = family
        rng = np.random.default_rng(7)
        # grouped admits whole batches of one exact length: two length groups
        prompts = [rng.integers(1, cfg.vocab, size=l).astype(np.int32)
                   for l in (6, 6, 11, 11)]
        outs = {}
        for scheduler in ("continuous", "grouped"):
            eng = E.ServeEngine(cfg, params, config=EngineConfig(
                batch_size=2, max_len=T, scheduler=scheduler))
            eng.submit(prompts, max_new=4)
            outs[scheduler] = {r.rid: r.out for r in eng.run()}
            if scheduler == "grouped":
                assert eng.counters["mid_decode_admissions"] == 0
        assert outs["continuous"] == outs["grouped"]
