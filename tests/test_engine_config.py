"""EngineConfig surface (DESIGN.md §3.11): every invalid knob combination
raises the same typed error through ``EngineConfig`` as through the legacy
kwarg path, the deprecation shim is parity-exact (same served tokens, exactly
one warning), and JSON round-trips are lossless."""
import argparse
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get
from repro.models import model as M
from repro.serving import engine as E
from repro.serving.config import (ChunkedStateError, EngineConfig, EngineStats,
                                  PrefixReuseStateError, SpeculativeStateError,
                                  UnsupportedModelError, add_config_args,
                                  config_from_args)


@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# Every cross-field invalid combination, with the error-message fragment both
# surfaces must raise (pure-config checks: no model needed).
BAD_COMBOS = [
    (dict(batch_size=0, max_len=32), "batch_size"),
    (dict(batch_size=2, max_len=0), "max_len"),
    (dict(batch_size=2, max_len=32, path="nope"), "unknown serving path"),
    (dict(batch_size=2, max_len=32, kv_cache="int4"), "kv_cache"),
    (dict(batch_size=2, max_len=32, cache_layout="ragged"), "cache_layout"),
    (dict(batch_size=2, max_len=32, scheduler="fifo"), "scheduler"),
    (dict(batch_size=2, max_len=32, page_size=0), "page_size"),
    (dict(batch_size=2, max_len=32, cache_layout="paged",
          scheduler="grouped"), "grouped baseline stays dense"),
    (dict(batch_size=2, max_len=32, chunked=True),
     "needs cache_layout='paged'"),
    (dict(batch_size=4, max_len=32, cache_layout="paged", chunked=True,
          token_budget=2), "token_budget"),
    (dict(batch_size=2, max_len=32, speculate=0), "speculate"),
    (dict(batch_size=2, max_len=32, speculate=2, temperature=0.7),
     "greedy sampling"),
    (dict(batch_size=2, max_len=32, speculate=2, scheduler="grouped"),
     "continuous scheduler"),
]


@pytest.mark.parametrize("kw,msg", BAD_COMBOS,
                         ids=[m.split("'")[0].strip()[:24].replace(" ", "-")
                              for _, m in BAD_COMBOS])
def test_invalid_combo_same_error_both_surfaces(small, kw, msg):
    cfg, params = small
    with pytest.raises(ValueError, match=msg) as via_config:
        EngineConfig(**kw)
    with pytest.raises(ValueError, match=msg) as via_legacy:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            E.ServeEngine(cfg, params, **kw)
    assert str(via_config.value) == str(via_legacy.value)


# One entry per typed rejection reason (DESIGN.md §3.13): speculative decoding
# cannot rewind the recurrence, radix prefix reuse cannot restart it mid-prompt,
# and chunked serving cannot scatter it positionally. Everything else —
# continuous, paged (without reuse), grouped, sharded — serves SSM/hybrid.
STATE_REJECTIONS = [
    (dict(batch_size=2, max_len=32, speculate=2), SpeculativeStateError,
     "rewind"),
    (dict(batch_size=2, max_len=32, cache_layout="paged"),
     PrefixReuseStateError, "prefix_reuse=False"),
    (dict(batch_size=4, max_len=32, cache_layout="paged", prefix_reuse=False,
          chunked=True, token_budget=16), ChunkedStateError, "ragged chunks"),
]


@pytest.mark.parametrize("family", ["mamba2-130m", "zamba2-1.2b"])
@pytest.mark.parametrize("kw,err,msg", STATE_REJECTIONS,
                         ids=[e.__name__ for _, e, _ in STATE_REJECTIONS])
def test_family_checks_need_the_model(kw, err, msg, family):
    """SSM/hybrid restrictions live in check_model (the pure config cannot see
    the family), raise one typed UnsupportedModelError subclass per reason,
    and fire identically through both engine surfaces."""
    ssm = dataclasses.replace(get(family, smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), ssm)
    config = EngineConfig(**kw)           # pure-config validation passes
    with pytest.raises(err, match=msg):
        config.check_model(ssm)
    with pytest.raises(err, match=msg):
        E.ServeEngine(ssm, params, config=config)
    with pytest.raises(err, match=msg):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            E.ServeEngine(ssm, params, **kw)
    # typed errors stay catchable as plain ValueError (pre-§3.13 callers)
    assert issubclass(err, UnsupportedModelError)
    assert issubclass(err, ValueError)


def test_state_families_pass_relaxed_check():
    """Continuous + paged-without-reuse + grouped all pass check_model for
    SSM/hybrid now (§3.13) — the pre-§3.13 blanket chunked/speculate rejection
    must not have left collateral rejections behind."""
    for family in ("mamba2-130m", "zamba2-1.2b"):
        cfg = get(family, smoke=True)
        for kw in (dict(batch_size=2, max_len=32),
                   dict(batch_size=2, max_len=32, cache_layout="paged",
                        prefix_reuse=False),
                   dict(batch_size=2, max_len=32, scheduler="grouped")):
            EngineConfig(**kw).check_model(cfg)   # must not raise


def test_unknown_field_typeerror(small):
    cfg, params = small
    with pytest.raises(TypeError, match="blocksize"):
        EngineConfig.from_kwargs(batch_size=2, max_len=32, blocksize=9)
    with pytest.raises(TypeError, match="blocksize"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            E.ServeEngine(cfg, params, batch_size=2, max_len=32, blocksize=9)


def test_config_plus_legacy_kwargs_typeerror(small):
    cfg, params = small
    config = EngineConfig(batch_size=2, max_len=32)
    with pytest.raises(TypeError, match="not both"):
        E.ServeEngine(cfg, params, config=config, batch_size=2)


def test_shim_parity_and_warns_once(small):
    """The legacy kwarg surface builds the identical engine (token-for-token)
    and emits exactly one DeprecationWarning per process."""
    cfg, params = small
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 6)]
    kw = dict(batch_size=2, max_len=32, kv_cache="int8", cache_layout="paged",
              page_size=8)

    new = E.ServeEngine(cfg, params, config=EngineConfig(**kw))
    new.submit([p.copy() for p in prompts], max_new=5)
    want = {r.rid: r.out for r in new.run()}

    E._LEGACY_KWARGS_WARNED = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old = E.ServeEngine(cfg, params, **kw)
        E.ServeEngine(cfg, params, batch_size=2, max_len=32)   # second build
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, "shim must warn exactly once per process"
    assert "EngineConfig" in str(dep[0].message)
    assert old.config == new.config          # shim built the identical config
    old.submit([p.copy() for p in prompts], max_new=5)
    got = {r.rid: r.out for r in old.run()}
    assert got == want


def test_json_round_trip_lossless():
    cfg = EngineConfig(batch_size=4, max_len=64, eos_id=7, path="fused-int8",
                       kv_cache="int8", cache_layout="paged", page_size=4,
                       n_pages=48, prefix_reuse=False, cache_dtype="bfloat16",
                       prefill_buckets=(8, 16, 64), chunked=True,
                       token_budget=16, speculate=4, drafter_ngram=2, seed=3)
    assert EngineConfig.from_json(cfg.to_json()) == cfg
    assert EngineConfig.from_dict(json.loads(cfg.to_json(indent=2))) == cfg
    # JSON lists normalize back to the tuple field, dtype to its canonical name
    loud = dict(cfg.to_dict(), prefill_buckets=[8, 16, 64],
                cache_dtype="bfloat16")
    assert EngineConfig.from_dict(loud) == cfg


def test_cli_flags_derive_from_fields():
    """add_config_args exposes every dataclass field; config_from_args layers
    explicit flags over a --config base over script defaults."""
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    helptext = ap.format_help()
    for f in dataclasses.fields(EngineConfig):
        assert f"--{f.name.replace('_', '-')}" in helptext, f.name
    base = EngineConfig(batch_size=2, max_len=32, cache_layout="paged",
                        kv_cache="fp")
    args = ap.parse_args(["--kv-cache", "int8", "--prefill-buckets", "8,32",
                          "--no-prefix-reuse"])
    got = config_from_args(args, base=base)
    assert got.kv_cache == "int8"            # explicit flag wins
    assert got.cache_layout == "paged"       # from the base config
    assert got.prefill_buckets == (8, 32)
    assert got.prefix_reuse is False
    # unset flags never clobber the base
    assert got.batch_size == 2 and got.max_len == 32


def test_stats_accessors_delegate(small):
    """stats() carries the same numbers as the four legacy accessors, and
    to_dict() flattens derived rates + raw counters into one stable schema."""
    cfg, params = small
    eng = E.ServeEngine(cfg, params,
                        config=EngineConfig(batch_size=2, max_len=32,
                                            cache_layout="paged"))
    rng = np.random.default_rng(1)
    shared = rng.integers(1, cfg.vocab, size=8).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, cfg.vocab, size=4 + i)
                               .astype(np.int32)]) for i in range(3)]
    eng.submit(prompts, max_new=4)
    eng.run()
    st = eng.stats()
    assert isinstance(st, EngineStats)
    assert st.occupancy == eng.occupancy()
    assert st.prefix_hit_rate == eng.prefix_hit_rate() > 0.0
    assert st.accept_rate == eng.accept_rate()
    assert st.tokens_per_step == eng.tokens_per_step()
    d = st.to_dict()
    assert d["prefix_hit_rate"] == st.prefix_hit_rate
    for k, v in eng.counters.items():
        assert d[k] == v
