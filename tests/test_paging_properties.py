"""Property tests for the host-side page bookkeeping (serving/paging.py).

An interpreter drives ``PagePool`` + ``RadixIndex`` through randomized
admit / retire / evict churn modelled on what ``ServeEngine`` does — admissions
match the radix tree, incref shared prefix pages, allocate (evicting under
pressure) the rest, and register full chunks; retirements decref everything the
sequence held. After **every** operation the full accounting invariant is
checked:

    refs[p]  ==  #active sequences holding p  +  #radix nodes retaining p

which simultaneously pins the three properties the engine relies on:

* refcounts never go negative (and free list ⊔ referenced pages partition the
  pool — ``PagePool.check``);
* LRU eviction never frees a page an active sequence still maps (evictable
  leaves are index-only, ``refs == 1``);
* a copy-on-write tail page never aliases any referenced page — the COW target
  comes off the free list, so the shared source page's KV is never clobbered.

Prompts are drawn over a tiny vocab with deliberate shared prefixes so radix
hits, partial hits, and chunk collisions are all common at ``max_examples``
scale.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.paging import PagePool, RadixIndex

PS = 4           # page size: tiny so multi-chunk prompts are cheap
N_PAGES = 12     # small pool: alloc failure + eviction pressure are routine

OPS = st.lists(
    st.tuples(st.sampled_from(["admit", "admit", "admit", "retire", "evict"]),
              st.integers(0, 2 ** 16 - 1)),
    min_size=1, max_size=50)


def _prompt(rng):
    """Random prompt over a 3-token vocab, usually sharing a page-aligned
    prefix with earlier prompts (vocab**PS = 81 chunk values → collisions)."""
    base = rng.integers(0, 3, size=PS * int(rng.integers(1, 4)))
    tail = rng.integers(0, 3, size=int(rng.integers(1, 2 * PS)))
    return np.concatenate([base, tail]).astype(np.int32)


class _Model:
    """Engine-shaped driver: active sequences hold one pool ref per mapped
    page; the radix index holds one per registered node."""

    def __init__(self):
        self.pool = PagePool(N_PAGES)
        self.radix = RadixIndex(PS)
        self.seqs = {}          # seq id -> (tokens, [pages])
        self.next_id = 0

    # ---- the invariant -------------------------------------------------
    def check(self):
        self.pool.check()
        assert (self.pool.refs >= 0).all()
        want = np.zeros(N_PAGES, np.int64)
        for _, pages in self.seqs.values():
            for p in pages:
                want[p] += 1
        for p in self.radix.held_pages():
            want[p] += 1
        np.testing.assert_array_equal(self.pool.refs, want)

    # ---- operations ----------------------------------------------------
    def admit(self, rng):
        tokens = _prompt(rng)
        pages, matched, partial = self.radix.match(tokens)
        # engine rule: keep at least one suffix token to prefill; a clamped
        # match invalidates the partial tail hit (it hangs off the unclamped
        # depth — _match_prefix does the same)
        while matched >= len(tokens):
            pages.pop()
            matched -= PS
            partial = None
        self.pool.incref(pages)
        if partial is not None:
            # engine rule (_plan_paged): pin the COW source over evict/alloc —
            # an index-only tail hit has refs == 1 and would otherwise be
            # evicted under pressure and handed back as a writable fresh page
            self.pool.incref([partial.page])
        need = -(-(len(tokens) - matched) // PS)
        if self.pool.free_count < need:
            self.radix.evict(self.pool, need)
        referenced = set(np.flatnonzero(self.pool.refs).tolist())
        fresh = self.pool.alloc(need)
        if partial is not None:
            self.pool.decref([partial.page])
        if fresh is None:                       # pool genuinely full: abort
            self.pool.decref(pages)
            return
        # COW property: the tail target is a fresh page, never the shared
        # source (partial.page) nor any other referenced page
        assert not (set(fresh) & referenced)
        if partial is not None:
            assert fresh[0] != partial.page
        self.radix.insert(tokens, pages + fresh, self.pool)
        self.seqs[self.next_id] = (tokens, pages + fresh)
        self.next_id += 1

    def retire(self, rng):
        if not self.seqs:
            return
        sid = sorted(self.seqs)[int(rng.integers(0, len(self.seqs)))]
        _, pages = self.seqs.pop(sid)
        self.pool.decref(pages)

    def evict(self, rng):
        held_by_seqs = {p for _, pages in self.seqs.values() for p in pages}
        self.radix.evict(self.pool, int(rng.integers(1, N_PAGES + 1)))
        # LRU eviction must never have freed a sequence-mapped page
        for p in held_by_seqs:
            assert self.pool.refs[p] >= 1


class TestPagingInvariants:
    @settings(max_examples=60, deadline=None)
    @given(ops=OPS, seed=st.integers(0, 2 ** 16 - 1))
    def test_churn_preserves_accounting(self, ops, seed):
        rng = np.random.default_rng(seed)
        m = _Model()
        for op, _ in ops:
            getattr(m, op)(rng)
            m.check()
        # drain: retiring everything and evicting the whole index empties
        # the pool back to its initial state
        while m.seqs:
            m.retire(rng)
            m.check()
        m.radix.evict(m.pool, N_PAGES + 1)
        m.check()
        while m.radix.n_nodes:
            freed = m.radix.evict(m.pool, N_PAGES + 1)
            if not freed:
                break
            m.check()
        assert m.pool.free_count == N_PAGES
        assert not m.radix.held_pages()

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2 ** 16 - 1))
    def test_shared_prefix_pages_survive_retire(self, seed):
        """Two sequences sharing a radix prefix: retiring one never frees the
        pages the other still maps."""
        rng = np.random.default_rng(seed)
        m = _Model()
        for _ in range(4):
            m.admit(rng)
            m.check()
        if len(m.seqs) >= 2:
            sids = sorted(m.seqs)
            survivor_pages = set(m.seqs[sids[1]][1])
            _, pages = m.seqs.pop(sids[0])
            m.pool.decref(pages)
            m.check()
            for p in survivor_pages:
                assert m.pool.refs[p] >= 1
