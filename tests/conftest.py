import os

# Tests run against the real single CPU device. (Only launch/dryrun.py forces 512
# placeholder devices, and only in its own process.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
