"""N:M structured sparsity (DESIGN.md §3.12): mask construction, prepare-time
pruning with scale refit, the block-sparse kernel vs the ref.py oracle, the
§4.1-gated sparsity plan, deployment byte accounting, and token parity of
sparse serving across the path matrix. No hypothesis dependency: this module
must run on minimal installs (the sparse kernel sweeps live here, not in
test_kernels.py, for that reason)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import packing, qlinear as ql
from repro.core import quantizers as Q
from repro.kernels import ops, ref
from repro.models import model as M
from repro.models import quantize as MQ
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine


class TestParseNM:
    def test_valid(self):
        assert MQ.parse_nm("2:4") == (2, 4)
        assert MQ.parse_nm("4:8") == (4, 8)

    @pytest.mark.parametrize("bad", ["", "4", "2:4:8", "a:b", "4:2", "0:4", "4:4"])
    def test_invalid(self, bad):
        with pytest.raises(ValueError):
            MQ.parse_nm(bad)


class TestNmKeepMask:
    @pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (1, 2)])
    def test_exact_survivors_per_group(self, n, m):
        score = jnp.abs(jax.random.normal(jax.random.PRNGKey(n * m), (8 * m, 16)))
        keep = MQ.nm_keep_mask(score, n, m)
        per_group = np.asarray(keep).reshape(-1, m, 16).sum(axis=1)
        np.testing.assert_array_equal(per_group, n)

    def test_keeps_the_top_scores(self):
        score = jnp.asarray([[4.0], [1.0], [3.0], [2.0],
                             [0.5], [9.0], [0.1], [8.0]])
        keep = np.asarray(MQ.nm_keep_mask(score, 2, 4))[:, 0]
        np.testing.assert_array_equal(
            keep, [True, False, True, False, False, True, False, True])

    def test_tail_remainder_stays_dense(self):
        score = jnp.ones((10, 3))          # 10 % 4 == 2: last two rows dense
        keep = np.asarray(MQ.nm_keep_mask(score, 2, 4))
        assert keep[8:].all()
        np.testing.assert_array_equal(keep[:8].reshape(2, 4, 3).sum(axis=1), 2)

    def test_stable_ties_prefer_lower_channel(self):
        keep = np.asarray(MQ.nm_keep_mask(jnp.ones((4, 2)), 2, 4))
        np.testing.assert_array_equal(keep[:, 0], [True, True, False, False])

    def test_batched_leading_dims(self):
        score = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (3, 8, 4)))
        keep = MQ.nm_keep_mask(score, 2, 4)
        assert keep.shape == (3, 8, 4)
        np.testing.assert_array_equal(
            np.asarray(keep).reshape(3, 2, 4, 4).sum(axis=2), 2)


class TestPackMask:
    def test_roundtrip(self):
        mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (3, 20, 6))
        packed = packing.pack_mask(mask)
        assert packed.dtype == jnp.uint8 and packed.shape == (3, 3, 6)
        got = packing.unpack_mask(packed, count=20)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(mask).astype(np.uint8))

    def test_pad_bits_zero_so_popcount_is_survivor_count(self):
        mask = jnp.ones((20, 4), bool)     # 20 rows -> 3 bytes, 4 pad bits
        packed = packing.pack_mask(mask)
        assert int(np.unpackbits(np.asarray(packed)).sum()) == 20 * 4


class TestSparsifyTree:
    @pytest.fixture(scope="class")
    def prepared(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        w = jax.random.normal(k1, (32, 16)) * 0.1
        cmax = jnp.abs(jax.random.normal(k2, (32,))) + 0.1
        return {"wq": ql.prepare_int8({"w": w}, ql.W8A8_INT8, cmax=cmax)}

    def test_prepared_node_pruned_and_rescaled(self, prepared):
        sp = MQ.sparsify_tree(prepared, MQ.SparsityPlan(nm=(2, 4)))["wq"]
        mask = np.asarray(packing.unpack_mask(sp["mask"], count=32)).astype(bool)
        np.testing.assert_array_equal(mask.reshape(8, 4, 16).sum(axis=1), 2)
        qw = np.asarray(sp["qw"])
        assert (qw[~mask] == 0).all()
        # scale refit: sw spans exactly the surviving b-folded weights
        wb = np.asarray(prepared["wq"]["qw"], np.float32) * np.asarray(
            prepared["wq"]["sw"])
        want_sw = np.maximum(np.abs(wb * mask).max(axis=0), float(Q.EPS)) / 127.0
        np.testing.assert_allclose(np.asarray(sp["sw"]), want_sw, rtol=1e-6)
        # survivors requantize on the refit grid
        np.testing.assert_array_equal(
            qw, np.clip(np.round(wb * mask / want_sw), -127, 127))

    def test_idempotent(self, prepared):
        plan = MQ.SparsityPlan(nm=(2, 4))
        once = MQ.sparsify_tree(prepared, plan)
        twice = MQ.sparsify_tree(once, plan)
        for k in ("qw", "sw", "mask"):
            np.testing.assert_array_equal(np.asarray(once["wq"][k]),
                                          np.asarray(twice["wq"][k]))

    def test_fp_node_pruned(self):
        tree = {"up": {"w": jax.random.normal(jax.random.PRNGKey(2), (16, 8))}}
        sp = MQ.sparsify_tree(tree, MQ.SparsityPlan(nm=(2, 4)))["up"]
        mask = np.asarray(packing.unpack_mask(sp["mask"], count=16)).astype(bool)
        w = np.asarray(sp["w"])
        assert (w[~mask] == 0).all() and (w[mask] != 0).all()
        np.testing.assert_array_equal(
            w[mask], np.asarray(tree["up"]["w"])[mask])

    def test_plan_layers_gate_which_leaves_prune(self, prepared):
        tree = {"wq": prepared["wq"], "wk": dict(prepared["wq"])}
        plan = MQ.SparsityPlan(nm=(2, 4), layers=("wk",))
        sp = MQ.sparsify_tree(tree, plan)
        assert "mask" not in sp["wq"] and "mask" in sp["wk"]

    def test_non_quantizable_leaves_untouched(self):
        tree = {"ln": {"w": jnp.ones((8, 4))}, "emb": jnp.ones((8, 4))}
        sp = MQ.sparsify_tree(tree, MQ.SparsityPlan(nm=(2, 4)))
        assert "mask" not in sp["ln"]
        np.testing.assert_array_equal(np.asarray(sp["ln"]["w"]), 1.0)

    def test_sparsity_summary_reports_kept_fraction(self, prepared):
        sp = MQ.sparsify_tree(prepared, MQ.SparsityPlan(nm=(2, 4)))
        assert MQ.sparsity_summary(sp) == {"wq": 0.5}


class TestQgemmW8A8Sparse:
    """N:M block-sparse int8 GEMM (DESIGN.md §3.12) vs the ref.py oracle,
    interpret mode on CPU.

    The ops-level contract: ``qw`` already carries zeros at pruned positions
    (``sparsify_tree`` guarantees this); ``mask`` only steers which K-blocks
    the kernel may skip. Tests therefore always pass ``qw * mask``.
    """

    @staticmethod
    def _operands(M, K, N, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        qx = jax.random.randint(k1, (M, K), -127, 128, jnp.int8)
        qw = jax.random.randint(k2, (K, N), -127, 128, jnp.int8)
        a = jax.random.uniform(k3, (M, 1), jnp.float32, 0.01, 1.0)
        sw = jax.random.uniform(k3, (N,), jnp.float32, 0.01, 1.0)
        return qx, qw, a, sw

    @pytest.mark.parametrize("nm", [(2, 4), (4, 8)])
    @pytest.mark.parametrize("M,K,N", [(128, 256, 128), (100, 300, 70)])
    def test_nm_masks_match_oracle(self, nm, M, K, N):
        qx, qw, a, sw = self._operands(M, K, N, M + K + N + nm[1])
        score = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (K, N)))
        mask = MQ.nm_keep_mask(score, *nm)
        qwm = jnp.where(mask, qw, 0)
        got = ops.qgemm_w8a8_sparse(qx, qwm, a, sw, mask)
        want = ref.qgemm_w8a8_sparse_ref(qx, qw, a, sw, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_all_ones_mask_bitwise_vs_dense_op(self):
        """Occupancy-full inputs route through the dense kernel (the wrapper's
        runtime cond) and must be bitwise identical to qgemm_w8a8."""
        qx, qw, a, sw = self._operands(128, 512, 128, 0)
        mask = jnp.ones((512, 128), bool)
        got = ops.qgemm_w8a8_sparse(qx, qw, a, sw, mask)
        want = ops.qgemm_w8a8(qx, qw, a, sw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_full_occupancy_sparse_kernel_bitwise_vs_dense_kernel(self):
        """The sparse kernel itself (not the wrapper's dense fallback) with an
        all-positive occupancy table runs the exact dense step sequence."""
        from repro.kernels import qgemm as qg
        M, K, N, b = 128, 256, 128, 128
        qx, qw, a, sw = self._operands(M, K, N, 1)
        sw2 = sw.reshape(1, -1)
        occ = jnp.full((K // b, N // b), b * b, jnp.int32)
        got = qg.qgemm_w8a8_sparse_pallas(qx, qw, a, sw2, occ,
                                          bm=b, bn=b, bk=b, interpret=True)
        want = qg.qgemm_w8a8_pallas(qx, qw, a, sw2, bm=b, bn=b, bk=b,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("zero_frac", [0.25, 0.5, 1.0])
    def test_zero_kblocks_skipped_exact(self, zero_frac):
        """Channel-block sparsity: whole (bk, bn) weight blocks zeroed. The
        kernel skips their dots; the output must still match the oracle
        exactly — including the all-zero column case (zero_frac=1)."""
        M, K, N, bk, bn = 64, 512, 128, 128, 128
        qx, qw, a, sw = self._operands(M, K, N, int(zero_frac * 100))
        n_k = K // bk
        kill = jnp.arange(n_k) < int(round(zero_frac * n_k))
        mask = jnp.repeat(~kill, bk)[:, None] & jnp.ones((K, N), bool)
        qwm = jnp.where(mask, qw, 0)
        got = ops.qgemm_w8a8_sparse(qx, qwm, a, sw, mask, bm=64, bn=bn, bk=bk)
        want = ref.qgemm_w8a8_sparse_ref(qx, qw, a, sw, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_ref_exec_mode_matches_pallas(self, monkeypatch):
        qx, qw, a, sw = self._operands(64, 256, 64, 7)
        mask = MQ.nm_keep_mask(jnp.abs(qw.astype(jnp.float32)) + 1e-3, 2, 4)
        qwm = jnp.where(mask, qw, 0)
        got_pl = ops.qgemm_w8a8_sparse(qx, qwm, a, sw, mask)
        monkeypatch.setenv("REPRO_KERNEL_EXEC", "ref")
        got_ref = ops.qgemm_w8a8_sparse(qx, qwm, a, sw, mask)
        np.testing.assert_allclose(np.asarray(got_ref), np.asarray(got_pl),
                                   rtol=1e-5)


class TestMakeSparsityPlan:
    @pytest.fixture(scope="class")
    def smoke(self):
        cfg = dataclasses.replace(get("starcoder2-7b", smoke=True),
                                  dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
        return cfg, params, [{"tokens": toks}]

    def test_threshold_one_prunes_every_eligible_leaf(self, smoke):
        cfg, params, batches = smoke
        plan = MQ.make_sparsity_plan(cfg, params, batches, threshold=1.0)
        assert plan.nm == (2, 4)
        assert any(p.endswith("attn/wq") for p in plan.layers)
        assert any(p.endswith("mlp/up") for p in plan.layers)
        assert all(0.0 <= f <= 1.0 for f in plan.fractions.values())
        assert set(plan.layers) <= set(plan.fractions)

    def test_negative_threshold_prunes_nothing(self, smoke):
        cfg, params, batches = smoke
        plan = MQ.make_sparsity_plan(cfg, params, batches, threshold=-1.0)
        assert plan.layers == ()
        sp = MQ.sparsify_tree(MQ.quantize_tree(params, ql.W8A8_INT8), plan)
        assert MQ.sparsity_summary(sp) == {}


class TestQuantizedBytes:
    def _tree(self):
        score = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (8, 4))) + 0.1
        return {
            "wq": {
                "qw": jnp.ones((8, 4), jnp.int8),
                "sw": jnp.ones((4,), jnp.float32),
                "bcol": jnp.ones((8,), jnp.float32),
                "qalpha": jnp.float32(0.15),
                "mask": packing.pack_mask(MQ.nm_keep_mask(score, 2, 4)),
            },
            "kv": {"k_scale": jnp.ones((2, 1), jnp.float32),
                   "v_scale": jnp.ones((2, 1), jnp.float32)},
        }

    def test_dense_accounting_counts_every_leaf(self):
        # qw 32 + sw 16 + bcol 32 + qalpha 4 + mask 4 + k/v scales 16 = 104
        assert MQ.quantized_bytes(self._tree()) == 104

    def test_deploy_sparse_costs_survivors_plus_mask(self):
        # 2:4 survivors: 16 int8 codes replace the 32-byte dense qw
        assert MQ.quantized_bytes(self._tree(), deploy_sparse=True) == 88

    def test_unmasked_tree_identical_both_ways(self):
        tree = {"wq": {"qw": jnp.ones((8, 4), jnp.int8),
                       "sw": jnp.ones((4,), jnp.float32)}}
        assert (MQ.quantized_bytes(tree)
                == MQ.quantized_bytes(tree, deploy_sparse=True) == 48)


class TestSparseServeParity:
    """Sparse trees serve token-exact across execution paths, and the engine's
    config-driven sparsification equals external sparsify_tree."""

    @pytest.fixture(scope="class")
    def served(self):
        cfg = get("starcoder2-7b", smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        qparams = MQ.quantize_tree(params, ql.W8A8_INT8)
        sq = MQ.sparsify_tree(qparams, MQ.SparsityPlan(nm=(2, 4)))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab, size=6).astype(np.int32)
                   for _ in range(2)]
        return cfg, qparams, sq, prompts

    @staticmethod
    def _serve(cfg, p, prompts, path, quant, sparsity="none"):
        config = EngineConfig(batch_size=2, max_len=24, eos_id=-1, path=path,
                              kv_cache="int8", sparsity=sparsity)
        eng = ServeEngine(cfg, p, config=config, quant=quant)
        eng.submit([x.copy() for x in prompts], max_new=4)
        return [list(map(int, r.out))
                for r in sorted(eng.run(), key=lambda r: r.rid)]

    def test_fused_matches_fake_quant_twin(self, served):
        cfg, _, sq, prompts = served
        fused = self._serve(cfg, sq, prompts, "fused-int8", ql.W8A8_INT8)
        # uncalibrated tree: b = 1, so the fused path's activation grid is
        # plain per-token — the fake twin must quantize the same way
        fake_cfg = dataclasses.replace(ql.W8A8_CROSSQUANT,
                                       act_quant="per_token", static_c=True,
                                       w_prequantized=True)
        fake = self._serve(cfg, MQ.dequantize_tree(sq, ql.W8A8_INT8), prompts,
                           "fake", fake_cfg)
        assert fused == fake

    def test_engine_config_sparsity_equals_external_sparsify(self, served):
        cfg, qparams, sq, prompts = served
        internal = self._serve(cfg, qparams, prompts, "fused-int8",
                               ql.W8A8_INT8, sparsity="2:4")
        external = self._serve(cfg, sq, prompts, "fused-int8", ql.W8A8_INT8)
        assert internal == external

    def test_dequant_fp_serves_pruned_tree(self, served):
        cfg, _, sq, prompts = served
        out = self._serve(cfg, sq, prompts, "dequant-fp", ql.W8A8_INT8)
        assert all(len(t) == 4 for t in out)
