"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
executed with interpret=True on CPU (the kernels target TPU Mosaic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import packing
from repro.kernels import ops, ref

SET = dict(max_examples=10, deadline=None)


def _pack(codes):
    return jnp.swapaxes(packing.pack_int4(jnp.swapaxes(codes, -1, -2)), -1, -2)


class TestQgemmW8A8:
    @pytest.mark.parametrize("M,K,N", [
        (128, 128, 128), (256, 512, 256), (100, 300, 70), (512, 1024, 384),
        (1, 128, 128), (130, 257, 129),
    ])
    def test_shapes(self, M, K, N):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(M + K + N), 3)
        qx = jax.random.randint(k1, (M, K), -127, 128, jnp.int8)
        qw = jax.random.randint(k2, (K, N), -127, 128, jnp.int8)
        a = jax.random.uniform(k3, (M, 1), jnp.float32, 0.01, 1.0)
        sw = jax.random.uniform(k3, (N,), jnp.float32, 0.01, 1.0)
        got = ops.qgemm_w8a8(qx, qw, a, sw)
        want = ref.qgemm_w8a8_ref(qx, qw, a, sw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    @settings(**SET)
    @given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5), st.integers(0, 99))
    def test_property_random_shapes(self, mm, kk, nn, seed):
        M, K, N = 32 * mm + seed % 7, 64 * kk + seed % 5, 32 * nn + seed % 3
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        qx = jax.random.randint(k1, (M, K), -127, 128, jnp.int8)
        qw = jax.random.randint(k2, (K, N), -127, 128, jnp.int8)
        a = jax.random.uniform(k3, (M, 1), jnp.float32, 0.01, 1.0)
        sw = jax.random.uniform(k3, (N,), jnp.float32, 0.01, 1.0)
        got = ops.qgemm_w8a8(qx, qw, a, sw)
        want = ref.qgemm_w8a8_ref(qx, qw, a, sw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_int32_accumulation_no_overflow_path(self):
        """Worst-case magnitudes: 127*127*K must accumulate in int32, not int8/16."""
        M = N = 128
        K = 1024
        qx = jnp.full((M, K), 127, jnp.int8)
        qw = jnp.full((K, N), 127, jnp.int8)
        a = jnp.ones((M, 1), jnp.float32)
        sw = jnp.ones((N,), jnp.float32)
        got = ops.qgemm_w8a8(qx, qw, a, sw)
        assert float(got[0, 0]) == 127 * 127 * K


class TestQgemmW4A8:
    @pytest.mark.parametrize("M,K,N,g", [
        (128, 256, 128, 128), (64, 512, 100, 128), (256, 384, 256, 128),
        (32, 128, 64, 64),
    ])
    def test_shapes(self, M, K, N, g):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(M + N), 3)
        codes = jax.random.randint(k1, (K, N), -8, 8, jnp.int8)
        qw4 = _pack(codes)
        qx = jax.random.randint(k2, (M, K), -127, 128, jnp.int8)
        a = jax.random.uniform(k3, (M, 1), jnp.float32, 0.01, 1.0)
        sw = jax.random.uniform(k3, (K // g, N), jnp.float32, 0.01, 1.0)
        got = ops.qgemm_w4a8(qx, qw4, a, sw, group=g)
        want = ref.qgemm_w4a8_ref(qx, qw4, a, sw, group=g)
        # f32 group-partial accumulation order differs kernel-vs-einsum: allow ulp-
        # level relative error on ~1e3-magnitude outputs.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=1e-3)

    def test_nibble_sign_extension(self):
        """All 16 int4 values must unpack exactly inside the kernel."""
        K, N = 128, 128
        codes = jnp.tile(jnp.arange(-8, 8, dtype=jnp.int8), (K // 16))[:, None]
        codes = jnp.broadcast_to(codes, (K, N))
        qw4 = _pack(codes)
        qx = jnp.eye(K, dtype=jnp.int8)[:16]        # selects rows 0..15
        a = jnp.ones((16, 1), jnp.float32)
        sw = jnp.ones((1, N), jnp.float32)
        got = ops.qgemm_w4a8(qx, qw4, a, sw, group=128)
        np.testing.assert_array_equal(np.asarray(got[:, 0]).astype(np.int32),
                                      np.arange(-8, 8))


class TestActQuantize:
    @pytest.mark.parametrize("M,K", [(256, 512), (100, 300), (512, 768), (1, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, M, K, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(M * K), 2)
        x = (jax.random.normal(k1, (M, K)) * 3).astype(dtype)
        bcol = jax.random.uniform(k2, (K,), jnp.float32, 0.1, 2.0)
        qg, ag = ops.act_quantize(x, bcol, alpha=0.15)
        qr, ar = ref.act_quantize_ref(x, bcol, alpha=0.15)
        # bf16 inputs can straddle rounding boundaries; allow <0.1% code mismatch
        mismatch = float(jnp.mean((qg != qr).astype(jnp.float32)))
        assert mismatch < 1e-3, mismatch
        np.testing.assert_allclose(np.asarray(ag), np.asarray(ar), rtol=1e-5)

    @pytest.mark.parametrize("alpha", [0.0, 0.15, 0.55, 1.0])
    def test_alpha_sweep(self, alpha):
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 256)) * 5
        bcol = jnp.ones((256,), jnp.float32)
        qg, ag = ops.act_quantize(x, bcol, alpha=alpha)
        qr, ar = ref.act_quantize_ref(x, bcol, alpha=alpha)
        np.testing.assert_array_equal(np.asarray(qg), np.asarray(qr))

    def test_int4_bits(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 128)) * 2
        bcol = jnp.ones((128,), jnp.float32)
        qg, _ = ops.act_quantize(x, bcol, bits=4)
        assert int(jnp.max(jnp.abs(qg))) <= 7


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Hkv,S,D", [
        (1, 2, 1, 128, 64), (2, 4, 2, 256, 128), (1, 2, 2, 200, 64),
    ])
    def test_causal_gqa(self, B, H, Hkv, S, D):
        ks = jax.random.split(jax.random.PRNGKey(S + D), 3)
        q = jax.random.normal(ks[0], (B, H, S, D))
        k = jax.random.normal(ks[1], (B, Hkv, S, D))
        v = jax.random.normal(ks[2], (B, Hkv, S, D))
        got = ops.flash_attention(q, k, v, causal=True, bq=128, bk=128)
        kr = jnp.repeat(k, H // Hkv, axis=1)
        vr = jnp.repeat(v, H // Hkv, axis=1)
        want = ref.flash_attention_ref(q, kr, vr, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64)) * 4
        k = jax.random.normal(ks[1], (1, 2, 128, 64)) * 4
        v = jax.random.normal(ks[2], (1, 2, 128, 64))
        got = ops.flash_attention(q, k, v, causal=True, softcap=30.0, bq=128, bk=128)
        want = ref.flash_attention_ref(q, k, v, causal=True, softcap=30.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_sliding_window(self):
        B, H, S, D, W = 1, 2, 256, 64, 64
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, H, S, D))
        k = jax.random.normal(ks[1], (B, H, S, D))
        v = jax.random.normal(ks[2], (B, H, S, D))
        got = ops.flash_attention(q, k, v, causal=True, window=W, bq=128, bk=128)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (D ** -0.5)
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(S)[None, :]
        m = (qp >= kp) & ((qp - kp) < W)
        want = jnp.einsum("bhqk,bhkd->bhqd",
                          jax.nn.softmax(jnp.where(m, s, -1e30), -1), v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_matches_model_blockwise_oracle(self):
        """The Pallas kernel and the model's jnp blockwise attention agree."""
        from repro.models.layers import blockwise_attention
        B, H, Hkv, S, D = 1, 4, 2, 192, 32
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        want = blockwise_attention(q, k, v, causal=True, window=None, softcap=None,
                                   q_block=64, kv_block=64)
        got = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                  k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3), causal=True,
                                  bq=128, bk=128).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


class TestEndToEnd:
    def test_quantize_then_gemm_matches_qlinear_ref(self):
        """Full int8 pipeline: act_quantize kernel -> qgemm kernel == qlinear jnp path."""
        from repro.core import qlinear as ql
        key = jax.random.PRNGKey(5)
        k1, k2 = jax.random.split(key)
        d_in, d_out, T = 256, 128, 64
        w = jax.random.normal(k1, (d_in, d_out)) * 0.1
        x = jax.random.normal(k2, (T, d_in)) * 2
        cmax = jnp.max(jnp.abs(x), axis=0)
        cfg = ql.W8A8_INT8
        prepared = ql.prepare_int8({"w": w}, cfg, cmax=cmax)
        y_ref = ql.apply(prepared, x, cfg, use_pallas=False)
        y_pallas = ql.apply(prepared, x, cfg, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pallas),
                                   rtol=1e-4, atol=1e-4)


class TestFlashInModel:
    def test_model_forward_matches_jnp_path(self):
        """Full-model forward with the Pallas flash-attention path (interpret mode)
        matches the jnp blockwise oracle path."""
        import dataclasses
        from repro.configs import get
        from repro.models import model as M
        from repro.models.layers import QuantContext
        from repro.core import qlinear as ql

        cfg = get("starcoder2-7b", smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 128), 0, cfg.vocab)
        logits_ref, _ = M.apply(params, {"tokens": toks}, cfg,
                                ctx=QuantContext(ql.FP), mode="train")
        logits_fa, _ = M.apply(params, {"tokens": toks}, cfg,
                               ctx=QuantContext(ql.FP, use_pallas=True),
                               mode="train")
        np.testing.assert_allclose(np.asarray(logits_fa), np.asarray(logits_ref),
                                   atol=0.1)
