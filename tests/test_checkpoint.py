"""Checkpoint manager: roundtrip, atomicity, integrity, GC, async writes."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


@pytest.fixture
def tree():
    return {
        "params": {"embed": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
                   "blocks": [{"w": np.ones((2, 2), np.float32)}]},
        "step_count": np.asarray(7, np.int32),
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, tree):
        cm = CheckpointManager(str(tmp_path))
        cm.save(3, tree, blocking=True)
        got, step = cm.restore(tree)
        assert step == 3
        np.testing.assert_array_equal(got["params"]["embed"]["w"],
                                      tree["params"]["embed"]["w"])
        np.testing.assert_array_equal(got["step_count"], tree["step_count"])

    def test_latest_and_specific_step(self, tmp_path, tree):
        cm = CheckpointManager(str(tmp_path), keep_n=10)
        for s in (1, 5, 9):
            t = dict(tree)
            t["step_count"] = np.asarray(s, np.int32)
            cm.save(s, t, blocking=True)
        got, step = cm.restore(tree)
        assert step == 9 and int(got["step_count"]) == 9
        got5, s5 = cm.restore(tree, step=5)
        assert s5 == 5 and int(got5["step_count"]) == 5

    def test_keep_n_gc(self, tmp_path, tree):
        cm = CheckpointManager(str(tmp_path), keep_n=2)
        for s in range(5):
            cm.save(s, tree, blocking=True)
        assert cm.all_steps() == [3, 4]

    def test_async_save_then_wait(self, tmp_path, tree):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, tree, blocking=False)
        cm.wait()
        assert cm.latest_step() == 1

    def test_corruption_detected(self, tmp_path, tree):
        cm = CheckpointManager(str(tmp_path))
        cm.save(2, tree, blocking=True)
        d = tmp_path / "step_000000002"
        # Corrupt the array archive but keep the manifest.
        flat = dict(np.load(d / "arrays.npz"))
        k = next(iter(flat))
        flat[k] = flat[k] + 1
        np.savez(d / "arrays.npz", **flat)
        with pytest.raises(IOError, match="corruption"):
            cm.restore(tree)

    def test_tmp_dir_never_visible(self, tmp_path, tree):
        """A stale .tmp staging dir must not be listed or restored from."""
        cm = CheckpointManager(str(tmp_path))
        cm.save(4, tree, blocking=True)
        os.makedirs(tmp_path / "step_000000009.tmp")
        assert cm.all_steps() == [4]
        assert cm.latest_step() == 4

    def test_missing_leaf_raises(self, tmp_path, tree):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, tree, blocking=True)
        bigger = dict(tree)
        bigger["extra"] = np.zeros(3)
        with pytest.raises(KeyError):
            cm.restore(bigger)

    def test_jax_arrays_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(8.0).reshape(2, 4), "b": jnp.zeros((3,), jnp.bfloat16)}
        cm.save(0, tree, blocking=True)
        got, _ = cm.restore(tree)
        np.testing.assert_array_equal(np.asarray(got["w"], np.float32),
                                      np.arange(8.0).reshape(2, 4))
        assert got["b"].dtype == jnp.bfloat16
