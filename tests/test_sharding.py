"""Sharding planner: tier selection, divisibility degradation (never errors),
head-padding functional equivalence, and spec construction on a real multi-device
mesh (subprocess with forced host device count)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_archs, get, with_padded_heads
from repro.models import model as M
from repro.models.quantize import pad_head_params
from repro.sharding import planner


class FakeMesh:
    """Just enough Mesh for make_plan/_maybe (shape lookup)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


class TestPlanTiers:
    def test_tiers_for_assigned_archs(self):
        mesh = FakeMesh(data=16, model=16)
        expect = {
            "deepseek-coder-33b": "tp_ffn",     # 56 q heads, 8 kv
            "gemma2-9b": "tp_kv_rep",           # 16 q, 8 kv
            "hubert-xlarge": "tp_full",         # 16 q, 16 kv
            "zamba2-1.2b": "tp_full",           # 32 q, 32 kv
            "starcoder2-7b": "tp_ffn",          # 36 q
            "nemotron-4-15b": "tp_kv_rep",      # 48 q, 8 kv
        }
        for arch, tier in expect.items():
            plan = planner.make_plan(get(arch), SHAPES["train_4k"], mesh)
            assert plan.tier == tier, (arch, plan.tier, tier)

    def test_moe_modes(self):
        mesh = FakeMesh(data=16, model=16)
        assert planner.make_plan(get("llama4-scout-17b-a16e"), SHAPES["train_4k"],
                                 mesh).moe_mode == "ep"          # 16 experts
        assert planner.make_plan(get("granite-moe-3b-a800m"), SHAPES["train_4k"],
                                 mesh).moe_mode == "expert_tp"   # 40 experts, dff 512

    def test_seq_shard_kv_for_serving_kinds(self):
        mesh = FakeMesh(data=16, model=16)
        cfg = get("gemma2-9b")
        assert planner.make_plan(cfg, SHAPES["decode_32k"], mesh).seq_shard_kv
        assert planner.make_plan(cfg, SHAPES["prefill_32k"], mesh).seq_shard_kv
        assert not planner.make_plan(cfg, SHAPES["train_4k"], mesh).seq_shard_kv

    def test_never_raises_for_any_cell(self):
        mesh = FakeMesh(pod=2, data=16, model=16)
        for arch in all_archs():
            for shape in SHAPES.values():
                planner.make_plan(get(arch), shape, mesh)   # must not raise


class TestHeadPadding:
    def test_padded_counts(self):
        assert with_padded_heads(get("deepseek-coder-33b"), 16).n_heads == 64
        assert with_padded_heads(get("starcoder2-7b"), 16).n_heads == 48
        assert with_padded_heads(get("llama4-scout-17b-a16e"), 16).n_heads == 48
        assert with_padded_heads(get("gemma2-9b"), 16).n_heads == 16    # unchanged

    def test_functional_equivalence(self, key):
        """Padded model with zero-padded wq columns / wo rows computes the SAME
        function — the exactness claim behind serving head padding."""
        cfg = get("starcoder2-7b", smoke=True)          # 4 heads smoke
        cfg_pad = with_padded_heads(cfg, 3)             # 4 -> 6 heads
        assert cfg_pad.n_heads == 6
        params = M.init_params(key, cfg)
        params_pad = pad_head_params(params, cfg, cfg_pad)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
        logits, _ = M.apply(params, {"tokens": toks}, cfg, mode="train")
        logits_pad, _ = M.apply(params_pad, {"tokens": toks}, cfg_pad, mode="train")
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_pad),
                                   atol=2e-2)

    def test_ssm_family_not_padded(self):
        cfg = get("mamba2-130m")
        assert with_padded_heads(cfg, 16) is cfg


class TestParamSpecs:
    def test_specs_on_8dev_mesh_subprocess(self):
        """Full spec construction + jit lowering of a smoke train step on a real
        (4, 2) mesh — the dry-run machinery end-to-end, at test scale."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            from jax.sharding import Mesh
            from repro.configs import get, SHAPES
            import dataclasses
            from repro.launch.dryrun import build_cell, default_quant
            from repro.sharding import hints

            mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
            cfg = get("starcoder2-7b", smoke=True)
            shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
            step, args, in_sh, out_sh, donate, plan, extra = build_cell(
                cfg, shape, mesh, default_quant("train"))
            with mesh, hints.sharding_hints(dp_axes=plan.dp_axes,
                                            tp_axis=plan.tp_axis, mesh=mesh):
                compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                                   donate_argnums=donate).lower(*args).compile()
            print("OK", compiled.memory_analysis().temp_size_in_bytes > 0)
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600,
                           env={**__import__("os").environ, "PYTHONPATH": "src"},
                           cwd="/root/repo")
        assert "OK" in r.stdout, r.stderr[-2000:]

    def test_param_shardings_cover_tree(self, key):
        mesh = FakeMesh(data=4, model=2)
        # NamedSharding needs a real mesh; use shape-only checks through _param_spec.
        cfg = get("gemma2-9b", smoke=True)
        sds = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        plan = planner.make_plan(cfg, SHAPES["train_4k"], mesh)
        flat = jax.tree_util.tree_flatten_with_path(sds)[0]
        for path, leaf in flat:
            spec = planner._param_spec(planner._path_str(path), leaf.shape, cfg,
                                       plan, mesh)
            assert len(spec) == len(leaf.shape)
            # every mesh axis used at most once
            used = [a for s in spec if s is not None
                    for a in ((s,) if isinstance(s, str) else s)]
            assert len(used) == len(set(used)), (path, spec)
