"""Sharding planner: tier selection, divisibility degradation (never errors),
head-padding functional equivalence, and spec construction on a real multi-device
mesh (subprocess with forced host device count)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, all_archs, get, with_padded_heads
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.quantize import pad_head_params, quantize_tree
from repro.sharding import planner

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


class FakeMesh:
    """Just enough Mesh for make_plan/_maybe (shape lookup)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


class TestPlanTiers:
    def test_tiers_for_assigned_archs(self):
        mesh = FakeMesh(data=16, model=16)
        expect = {
            "deepseek-coder-33b": "tp_ffn",     # 56 q heads, 8 kv
            "gemma2-9b": "tp_kv_rep",           # 16 q, 8 kv
            "hubert-xlarge": "tp_full",         # 16 q, 16 kv
            "zamba2-1.2b": "tp_full",           # 32 q, 32 kv
            "starcoder2-7b": "tp_ffn",          # 36 q
            "nemotron-4-15b": "tp_kv_rep",      # 48 q, 8 kv
        }
        for arch, tier in expect.items():
            plan = planner.make_plan(get(arch), SHAPES["train_4k"], mesh)
            assert plan.tier == tier, (arch, plan.tier, tier)

    def test_moe_modes(self):
        mesh = FakeMesh(data=16, model=16)
        assert planner.make_plan(get("llama4-scout-17b-a16e"), SHAPES["train_4k"],
                                 mesh).moe_mode == "ep"          # 16 experts
        assert planner.make_plan(get("granite-moe-3b-a800m"), SHAPES["train_4k"],
                                 mesh).moe_mode == "expert_tp"   # 40 experts, dff 512

    def test_seq_shard_kv_for_serving_kinds(self):
        mesh = FakeMesh(data=16, model=16)
        cfg = get("gemma2-9b")
        assert planner.make_plan(cfg, SHAPES["decode_32k"], mesh).seq_shard_kv
        assert planner.make_plan(cfg, SHAPES["prefill_32k"], mesh).seq_shard_kv
        assert not planner.make_plan(cfg, SHAPES["train_4k"], mesh).seq_shard_kv

    def test_never_raises_for_any_cell(self):
        mesh = FakeMesh(pod=2, data=16, model=16)
        for arch in all_archs():
            for shape in SHAPES.values():
                planner.make_plan(get(arch), shape, mesh)   # must not raise


class TestHeadPadding:
    def test_padded_counts(self):
        assert with_padded_heads(get("deepseek-coder-33b"), 16).n_heads == 64
        assert with_padded_heads(get("starcoder2-7b"), 16).n_heads == 48
        assert with_padded_heads(get("llama4-scout-17b-a16e"), 16).n_heads == 48
        assert with_padded_heads(get("gemma2-9b"), 16).n_heads == 16    # unchanged

    def test_functional_equivalence(self, key):
        """Padded model with zero-padded wq columns / wo rows computes the SAME
        function — the exactness claim behind serving head padding."""
        cfg = get("starcoder2-7b", smoke=True)          # 4 heads smoke
        cfg_pad = with_padded_heads(cfg, 3)             # 4 -> 6 heads
        assert cfg_pad.n_heads == 6
        params = M.init_params(key, cfg)
        params_pad = pad_head_params(params, cfg, cfg_pad)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
        logits, _ = M.apply(params, {"tokens": toks}, cfg, mode="train")
        logits_pad, _ = M.apply(params_pad, {"tokens": toks}, cfg_pad, mode="train")
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_pad),
                                   atol=2e-2)

    def test_ssm_family_not_padded(self):
        cfg = get("mamba2-130m")
        assert with_padded_heads(cfg, 16) is cfg


class TestParamSpecs:
    def test_specs_on_8dev_mesh_subprocess(self):
        """Full spec construction + jit lowering of a smoke train step on a real
        (4, 2) mesh — the dry-run machinery end-to-end, at test scale."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            from jax.sharding import Mesh
            from repro.configs import get, SHAPES
            import dataclasses
            from repro.launch.dryrun import build_cell, default_quant
            from repro.sharding import hints

            mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
            cfg = get("starcoder2-7b", smoke=True)
            shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
            step, args, in_sh, out_sh, donate, plan, extra = build_cell(
                cfg, shape, mesh, default_quant("train"))
            with mesh, hints.sharding_hints(dp_axes=plan.dp_axes,
                                            tp_axis=plan.tp_axis, mesh=mesh):
                compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                                   donate_argnums=donate).lower(*args).compile()
            print("OK", compiled.memory_analysis().temp_size_in_bytes > 0)
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600,
                           env={**os.environ, "PYTHONPATH": SRC})
        assert "OK" in r.stdout, r.stderr[-2000:]

    def test_param_shardings_cover_tree(self, key):
        mesh = FakeMesh(data=4, model=2)
        # NamedSharding needs a real mesh; use shape-only checks through _param_spec.
        cfg = get("gemma2-9b", smoke=True)
        sds = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        plan = planner.make_plan(cfg, SHAPES["train_4k"], mesh)
        flat = jax.tree_util.tree_flatten_with_path(sds)[0]
        for path, leaf in flat:
            spec = planner._param_spec(planner._path_str(path), leaf.shape, cfg,
                                       plan, mesh)
            assert len(spec) == len(leaf.shape)
            # every mesh axis used at most once
            used = [a for s in spec if s is not None
                    for a in ((s,) if isinstance(s, str) else s)]
            assert len(used) == len(set(used)), (path, spec)


class TestQuantizedServingSpecs:
    """Serving plans for prepared integer trees (DESIGN.md §3.7): scale leaves
    follow their weight's model-axis split; non-dividing shapes degrade to
    replication, never error."""

    def _plan(self, model=2):
        mesh = FakeMesh(data=8 // model, model=model)
        cfg = get("starcoder2-7b", smoke=True)
        return cfg, planner.make_serve_plan(cfg, mesh), mesh

    def test_scale_leaves_follow_weight_model_axis(self):
        cfg, plan, mesh = self._plan()
        d, f = cfg.d_model, cfg.d_ff
        # column-parallel up: qw shards d_out over model, sw follows d_out
        assert planner._param_spec("blocks/0/mlp/up/qw", (d, f), cfg, plan,
                                   mesh)[-1] == "model"
        assert planner._param_spec("blocks/0/mlp/up/sw", (f,), cfg, plan,
                                   mesh)[-1] == "model"
        # row-parallel down: qw shards d_in, bcol follows d_in, sw (d_out) replicates
        assert planner._param_spec("blocks/0/mlp/down/qw", (f, d), cfg, plan,
                                   mesh)[-2] == "model"
        assert planner._param_spec("blocks/0/mlp/down/bcol", (f,), cfg, plan,
                                   mesh)[-1] == "model"
        assert planner._param_spec("blocks/0/mlp/down/sw", (d,), cfg, plan,
                                   mesh) == P(None)
        # qalpha (effective-alpha scalar leaf): always replicated
        assert planner._param_spec("blocks/0/mlp/down/qalpha", (), cfg, plan,
                                   mesh) == P()

    def test_int4_group_scales_follow_row_parallel_shard(self):
        cfg, plan, mesh = self._plan()
        # row-parallel W4: per-layer sw is (G, d_out); the group axis follows the
        # weight's d_in shard when tp divides G (whole groups per shard). Scanned
        # leaves carry a leading layer-stack dim: (n_blocks, G, d_out).
        spec = planner._param_spec("tail/0/mlp/down/sw", (4, cfg.d_model), cfg,
                                   plan, mesh)
        assert spec[-2] == "model" and spec[-1] is None
        spec = planner._param_spec("blocks/0/mlp/down/sw", (2, 4, cfg.d_model),
                                   cfg, plan, mesh)
        assert spec == P(None, "model", None)
        # ... and replicates when tp does not divide G (G=3 vs tp=2)
        spec = planner._param_spec("blocks/0/mlp/down/sw", (2, 3, cfg.d_model),
                                   cfg, plan, mesh)
        assert all(s is None for s in spec)

    def test_stacked_int8_row_parallel_sw_never_shards_layer_axis(self):
        """A scanned int8 sw is (n_blocks, d_out): its dim -2 is the layer-stack
        axis, not a group axis — sharding it would make XLA all-gather the whole
        stack outside the decode scan. Must replicate even when n_blocks divides
        tp."""
        cfg, plan, mesh = self._plan()
        spec = planner._param_spec("blocks/0/mlp/down/sw", (2, cfg.d_model), cfg,
                                   plan, mesh)
        assert all(s is None for s in spec)

    def test_prepared_tree_covered_and_degrades_to_replication(self):
        """Every leaf of a fully quantized tree gets a rank-matching spec with each
        mesh axis used at most once; a mesh nothing divides (model=7) yields pure
        replication — never an error (the planner's §3.4 contract, extended to
        quantization metadata)."""
        cfg = get("starcoder2-7b", smoke=True)
        qsds = jax.eval_shape(
            lambda: quantize_tree(M.init_params(jax.random.PRNGKey(0), cfg),
                                  ql.W8A8_INT8))
        for mesh, expect_replicated in ((FakeMesh(data=4, model=2), False),
                                        (FakeMesh(data=1, model=7), True)):
            plan = planner.make_serve_plan(cfg, mesh)
            n_model_sharded = 0
            for path, leaf in jax.tree_util.tree_flatten_with_path(qsds)[0]:
                spec = planner._param_spec(planner._path_str(path), leaf.shape,
                                           cfg, plan, mesh)
                assert len(spec) == len(leaf.shape)
                used = [a for s in spec if s is not None
                        for a in ((s,) if isinstance(s, str) else s)]
                assert len(used) == len(set(used)), (path, spec)
                n_model_sharded += "model" in used
                if expect_replicated:
                    assert all(s is None for s in spec), (path, spec)
            if not expect_replicated:
                assert n_model_sharded > 0

    def test_int8_kv_cache_scale_leaves_follow_codes(self):
        """cache_shardings: k_scale/v_scale carry the same (B→dp, T→model) split
        as the int8 codes they dequantize."""
        import numpy as _np
        mesh = jax.sharding.Mesh(
            _np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        cfg = get("starcoder2-7b", smoke=True)
        plan = planner.make_serve_plan(cfg, mesh)
        assert plan.seq_shard_kv
        caches = jax.eval_shape(
            lambda: M.init_cache(cfg, 4, 32, dtype=jnp.float32, kv_int8=True))
        sh = planner.cache_shardings(caches, cfg, plan, mesh)
        blk = sh["blocks"][0]
        # stacked leaves: (n_blocks, B, T, ...) — B on dp, T on model
        assert blk["k"].spec[1] == ("data",) and blk["k"].spec[2] == "model"
        assert blk["k_scale"].spec[:3] == blk["k"].spec[:3]
        assert blk["v_scale"].spec[:3] == blk["v"].spec[:3]


class TestDebugMesh:
    def test_make_debug_mesh_raises_with_device_count_hint(self):
        """A short host must raise with the XLA_FLAGS hint (like
        make_production_mesh), not silently build a wrong-shaped mesh."""
        from repro.launch.mesh import make_debug_mesh
        with pytest.raises(RuntimeError,
                           match="xla_force_host_platform_device_count"):
            make_debug_mesh(64, 64)


class TestTp2TokenParity:
    def test_tp2_decode_matches_single_device_subprocess(self):
        """tp=2 host-mesh serving emits token-identical greedy output to
        single-device decode on a *pure-TP* (1, 2) mesh — the degenerate-dp
        layout the tp=2/tp=4 matrix of tests/test_sharded_serving.py (which runs
        on (4, 2)/(2, 4) meshes) does not cover. Two forced devices only, so
        this stays cheap under tier-1."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
            os.environ["JAX_PLATFORMS"] = "cpu"
            import dataclasses
            import jax, numpy as np
            from repro.configs import get
            from repro.core import qlinear as ql
            from repro.models import model as M
            from repro.serving import engine as E
            from repro.launch.mesh import make_debug_mesh

            cfg = dataclasses.replace(get("starcoder2-7b", smoke=True),
                                      dtype="float32")
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            rng = np.random.default_rng(0)
            prompts = [rng.integers(1, cfg.vocab, size=l).astype(np.int32)
                       for l in (5, 9)]

            def serve(mesh):
                eng = E.ServeEngine(cfg, params, batch_size=2, max_len=32,
                                    quant=ql.W8A8_CROSSQUANT, path="fake",
                                    mesh=mesh)
                eng.submit([p.copy() for p in prompts], max_new=4)
                return {r.rid: r.out for r in eng.run()}

            base = serve(None)
            got = serve(make_debug_mesh(1, 2))
            assert got == base, (got, base)
            print("TP2-PARITY-OK")
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600,
                           env={**os.environ, "PYTHONPATH": SRC})
        assert "TP2-PARITY-OK" in r.stdout, r.stderr[-2000:]
