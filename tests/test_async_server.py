"""Async serving front end (DESIGN.md §3.11): AsyncServer streams token-exact
output vs a direct ``ServeEngine.run()`` of the same prompts on every path ×
KV mode × layout; bounded admission rejects with a typed error past the
deadline; prefix-affinity routing keeps shared-prefix traffic on one replica;
a killed replica's in-flight requests complete on survivors with no token
loss (and the replica restarts, or goes dead once its budget is spent)."""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.runtime import FailureInjector
from repro.serving import engine as E
from repro.serving.api import AdmissionError, FinishReason, Request
from repro.serving.config import EngineConfig
from repro.serving.server import AsyncServer

T = 32
LENS = [6, 9, 5, 12]
MAX_NEW = [5, 3, 6, 4]


@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    return cfg, params, qparams


def _prompts(cfg, lens=LENS, seed=0, shared=None):
    rng = np.random.default_rng(seed)
    pre = shared if shared is not None else np.zeros(0, np.int32)
    return [np.concatenate([pre, rng.integers(1, cfg.vocab, size=n)
                            .astype(np.int32)]) for n in lens]


def _reference(cfg, params, config, prompts, max_new, quant=None):
    """Direct synchronous ServeEngine.run() of the same workload."""
    eng = E.ServeEngine(cfg, params, config=config, quant=quant)
    eng.submit([p.copy() for p in prompts], max_new=list(max_new))
    done = eng.run()
    return {tuple(p.tolist()): r.out for p, r in zip(prompts, done)}


async def _collect(srv, req):
    toks, fin = [], None
    async for ev in srv.submit(req):
        if ev.kind == "token":
            toks.append(ev.token)
        elif ev.kind == "finished":
            fin = ev
        else:
            raise AssertionError(f"stream error: {ev.error}")
    return toks, fin


# pairwise coverage of every path, KV mode and layout
MATRIX = [("fake", "fp", "dense"), ("fake", "int8", "paged"),
          ("dequant-fp", "fp", "paged"), ("dequant-fp", "int8", "dense"),
          ("fused-int8", "fp", "dense"), ("fused-int8", "int8", "paged")]


class TestStreamingParity:
    @pytest.mark.parametrize("path,kv,layout", MATRIX)
    def test_streams_token_exact_vs_direct_engine(self, small, path, kv,
                                                  layout):
        cfg, params, qparams = small
        if path == "fake":
            serve_params, quant = params, ql.W8A8_CROSSQUANT
        else:
            serve_params, quant = qparams, ql.W8A8_INT8
        config = EngineConfig(batch_size=2, max_len=T, path=path,
                              kv_cache=kv, cache_layout=layout)
        prompts = _prompts(cfg)
        want = _reference(cfg, serve_params, config, prompts, MAX_NEW,
                          quant=quant)

        async def main():
            async with AsyncServer(cfg, serve_params, config=config,
                                   replicas=2, quant=quant) as srv:
                res = await asyncio.gather(*[
                    _collect(srv, Request(prompt=p.tolist(), max_new=mn))
                    for p, mn in zip(prompts, MAX_NEW)])
            for (toks, fin), p in zip(res, prompts):
                assert toks == want[tuple(p.tolist())], (path, kv, layout)
                assert fin.finish_reason == FinishReason.LENGTH
                assert fin.metrics.n_tokens == len(toks)
                assert fin.metrics.ttft_s >= 0.0

        asyncio.run(main())

    def test_chunked_config_streams_token_exact(self, small):
        cfg, params, _ = small
        config = EngineConfig(batch_size=2, max_len=T, cache_layout="paged",
                              chunked=True, token_budget=16)
        prompts = _prompts(cfg, seed=4)
        want = _reference(cfg, params, config, prompts, MAX_NEW)

        async def main():
            async with AsyncServer(cfg, params, config=config,
                                   replicas=2) as srv:
                res = await asyncio.gather(*[
                    _collect(srv, Request(prompt=p.tolist(), max_new=mn))
                    for p, mn in zip(prompts, MAX_NEW)])
            for (toks, _), p in zip(res, prompts):
                assert toks == want[tuple(p.tolist())]

        asyncio.run(main())

    def test_finish_reasons(self, small):
        """EOS truncates the stream with FinishReason.EOS; a prompt that fills
        its cache row retires as CACHE_FULL after the last append."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=2, max_len=T)
        prompt = _prompts(cfg, lens=[8], seed=5)[0]
        base = _reference(cfg, params, config, [prompt], [6])
        eos = base[tuple(prompt.tolist())][2]     # third greedy token
        cfg_eos = EngineConfig(batch_size=2, max_len=T, eos_id=eos)
        long_prompt = _prompts(cfg, lens=[T - 2], seed=6)[0]

        async def main():
            async with AsyncServer(cfg, params, config=cfg_eos,
                                   replicas=1) as srv:
                toks, fin = await _collect(
                    srv, Request(prompt=prompt.tolist(), max_new=6))
                assert fin.finish_reason == FinishReason.EOS
                assert toks == base[tuple(prompt.tolist())][:3]
                toks, fin = await _collect(
                    srv, Request(prompt=long_prompt.tolist(), max_new=8))
                assert fin.finish_reason == FinishReason.CACHE_FULL
                assert len(toks) == 3     # admit fills T-2; two appends hit T

        asyncio.run(main())

    def test_kernel_proportion_metric(self, small):
        """kernel_stats=True reports the paper's §4.1 quantization-kernel
        proportion measured on the request's own served tokens."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=1, max_len=T, path="fake")

        async def main():
            async with AsyncServer(cfg, params, config=config, replicas=1,
                                   quant=ql.W8A8_CROSSQUANT,
                                   kernel_stats=True) as srv:
                _, fin = await _collect(
                    srv, Request(prompt=_prompts(cfg)[0].tolist(), max_new=4))
                kp = fin.metrics.kernel_proportion
                assert kp is not None and 0.0 < kp <= 1.0

        asyncio.run(main())


class TestAdmission:
    def test_backpressure_rejects_past_deadline(self, small):
        """With every replica frozen, submits past max_queue wait for the
        admission deadline and then fail with the typed AdmissionError;
        resuming drains the queued requests to completion."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=1, max_len=T)
        prompts = _prompts(cfg, lens=[6, 6, 6], seed=7)

        async def main():
            async with AsyncServer(cfg, params, config=config, replicas=2,
                                   max_queue=2,
                                   admission_timeout=0.05) as srv:
                srv.pause()
                tasks = [asyncio.create_task(
                    _collect(srv, Request(prompt=p.tolist(), max_new=3)))
                    for p in prompts[:2]]
                await asyncio.sleep(0.02)         # both hold admission slots
                t0 = asyncio.get_running_loop().time()
                with pytest.raises(AdmissionError) as ei:
                    await _collect(srv, Request(prompt=prompts[2].tolist(),
                                                max_new=3))
                assert ei.value.reason == "queue_full"
                assert asyncio.get_running_loop().time() - t0 >= 0.05
                assert srv.counters["rejected"] == 1
                srv.resume()
                for (toks, fin) in await asyncio.gather(*tasks):
                    assert len(toks) == 3 and fin.kind == "finished"

        asyncio.run(main())

    def test_pool_pressure_rejects_oversized_reservation(self, small):
        """Paged layout: a request whose worst-case page reservation exceeds
        the whole pool rejects with reason="pool_pressure" — no amount of
        waiting could ever serve it — while a right-sized request on the same
        server admits and finishes; the in-flight queue never fills."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=1, max_len=T, cache_layout="paged",
                              page_size=8, n_pages=3)
        big = _prompts(cfg, lens=[20], seed=13)[0]   # 20+8-1 toks -> 4 pages
        ok = _prompts(cfg, lens=[6], seed=14)[0]     # 6+3-1 toks  -> 1 page

        async def main():
            async with AsyncServer(cfg, params, config=config, replicas=1,
                                   admission_timeout=0.05) as srv:
                with pytest.raises(AdmissionError) as ei:
                    await _collect(srv, Request(prompt=big.tolist(),
                                                max_new=8))
                assert ei.value.reason == "pool_pressure"
                assert srv.counters["rejected"] == 1
                toks, fin = await _collect(srv, Request(prompt=ok.tolist(),
                                                        max_new=3))
                assert len(toks) == 3 and fin.kind == "finished"

        asyncio.run(main())

    def test_pool_pressure_transient_admits_after_release(self, small):
        """Pinning every free page (as live sequences would) makes submits
        reject with reason="pool_pressure"; releasing the pages lets the same
        request admit and finish."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=1, max_len=T, cache_layout="paged",
                              page_size=8)
        prompt = _prompts(cfg, lens=[6], seed=15)[0]

        async def main():
            async with AsyncServer(cfg, params, config=config, replicas=1,
                                   max_queue=4,
                                   admission_timeout=0.05) as srv:
                while srv.replicas[0].engine is None:     # replica warms up
                    await asyncio.sleep(0.01)
                pool = srv.replicas[0].engine.pool
                held = pool.alloc(pool.free_count)
                assert held is not None
                with pytest.raises(AdmissionError) as ei:
                    await _collect(srv, Request(prompt=prompt.tolist(),
                                                max_new=3))
                assert ei.value.reason == "pool_pressure"
                pool.decref(held)
                toks, fin = await _collect(srv, Request(prompt=prompt.tolist(),
                                                        max_new=3))
                assert len(toks) == 3 and fin.kind == "finished"

        asyncio.run(main())


class TestRouting:
    def test_affinity_keeps_shared_prefixes_together(self, small):
        """Two prefix families land on two different replicas (least-loaded
        seeds the split while both are busy); every follow-up request routes
        to the replica whose radix cache holds its prefix, so both engines
        see real §3.8 prefix hits."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=2, max_len=T, cache_layout="paged",
                              page_size=8)
        rng = np.random.default_rng(8)
        pre_a = rng.integers(1, cfg.vocab, size=16).astype(np.int32)
        pre_b = rng.integers(1, cfg.vocab, size=16).astype(np.int32)
        fam_a = _prompts(cfg, lens=[5, 6, 7, 8], seed=9, shared=pre_a)
        fam_b = _prompts(cfg, lens=[5, 6, 7, 8], seed=10, shared=pre_b)

        async def main():
            async with AsyncServer(cfg, params, config=config,
                                   replicas=2) as srv:
                # freeze: the two seed requests dispatch while both replicas
                # are busy, so least-loaded splits them across the fleet
                srv.pause()
                seeds = [asyncio.create_task(
                    _collect(srv, Request(prompt=p.tolist(), max_new=3)))
                    for p in (fam_a[0], fam_b[0])]
                await asyncio.sleep(0.02)
                srv.resume()
                (ra, rb) = [fin.metrics.replica
                            for _, fin in await asyncio.gather(*seeds)]
                assert ra != rb
                for fam, home in ((fam_a, ra), (fam_b, rb)):
                    for p in fam[1:]:
                        _, fin = await _collect(
                            srv, Request(prompt=p.tolist(), max_new=3))
                        assert fin.metrics.replica == home
                        assert fin.metrics.prefix_reused >= 16
                assert srv.router.affinity_hits >= 6
                m = srv.metrics()
                for rep in m["replicas"]:
                    assert rep["engine"]["prefix_hit_rate"] > 0.0

        asyncio.run(main())


class TestReplicaFailure:
    def test_killed_replica_drains_to_survivor_token_exact(self, small):
        """Replica 0 dies mid-decode; its in-flight requests are requeued onto
        replica 1 as prompt+emitted continuations and every request's total
        stream equals the no-failure reference, token for token. Replica 0
        restarts and serves again."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=2, max_len=T, cache_layout="paged")
        prompts = _prompts(cfg, seed=11)
        want = _reference(cfg, params, config, prompts, [8] * 4)

        async def main():
            inj = {0: FailureInjector(fail_at_steps=(3,))}
            async with AsyncServer(cfg, params, config=config, replicas=2,
                                   injectors=inj, max_restarts=2) as srv:
                res = await asyncio.gather(*[
                    _collect(srv, Request(prompt=p.tolist(), max_new=8,
                                          replica_hint=0))
                    for p in prompts])
                requeued = 0
                for (toks, fin), p in zip(res, prompts):
                    assert toks == want[tuple(p.tolist())], "token loss"
                    requeued += fin.metrics.requeues
                assert requeued >= 1          # the failure interrupted work
                m = srv.metrics()
                assert m["server"]["restarts"] == 1
                assert m["replicas"][0]["state"] == "live"
                assert m["replicas"][0]["restarts"] == 1
                # the restarted replica serves new traffic again
                _, fin = await _collect(srv, Request(
                    prompt=prompts[0].tolist(), max_new=4, replica_hint=0))
                assert fin.metrics.replica == 0

        asyncio.run(main())

    def test_restart_budget_exhaustion_marks_replica_dead(self, small):
        """max_restarts=0: the first failure kills replica 0 for good; its
        requests still complete on the survivor and later traffic never
        routes to the dead replica (even with a hint)."""
        cfg, params, _ = small
        config = EngineConfig(batch_size=2, max_len=T)
        prompts = _prompts(cfg, seed=12)
        want = _reference(cfg, params, config, prompts, [6] * 4)

        async def main():
            inj = {0: FailureInjector(fail_at_steps=(2,))}
            async with AsyncServer(cfg, params, config=config, replicas=2,
                                   injectors=inj, max_restarts=0) as srv:
                res = await asyncio.gather(*[
                    _collect(srv, Request(prompt=p.tolist(), max_new=6,
                                          replica_hint=0))
                    for p in prompts])
                for (toks, _), p in zip(res, prompts):
                    assert toks == want[tuple(p.tolist())]
                assert srv.metrics()["replicas"][0]["state"] == "dead"
                _, fin = await _collect(srv, Request(
                    prompt=prompts[0].tolist(), max_new=3, replica_hint=0))
                assert fin.metrics.replica == 1   # hint ignored: replica dead

        asyncio.run(main())
