"""Paged KV cache + radix prefix reuse (DESIGN.md §3.8).

Four property groups:

* **Dense parity** — the paged layout is a pure representation change: any mixed
  workload served through the page pool emits token-identical output to the
  dense slot table on every integer path × KV-cache mode (cold admissions are
  *bitwise* identical by construction: same prefill attention codepath, and the
  pool gather reproduces the dense (B, T, ...) row layout exactly).
* **Prefix reuse** — shared-prefix admissions map cached pages copy-free, only
  prefill the suffix, and emit the same tokens as a cold engine; int8 pages
  share bit-exactly (deterministic codes+scales); partial tail pages COW.
* **Allocator/refcount invariants** — the pool and radix index stay consistent
  under churn + eviction pressure.
* **Kernel parity** — the Pallas paged decode kernel vs the jnp oracle across a
  shape/table sweep (interpret mode).

Plus the two satellite pins: max_len-prompt headroom (admit-and-retire, no
silent clipped scatter) and head-of-line bucket scheduling.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.serving import engine as E
from repro.serving.paging import PagePool, RadixIndex

T = 32
PS = 8
LENS = [4, 7, 12, 9, 5]
MAX_NEW = [5, 3, 6, 2, 4]


@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    return cfg, params, qparams


def _mixed_prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=n).astype(np.int32) for n in LENS]


def _shared_prefix_prompts(cfg, n_req=4, shared_len=16, seed=2):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab, size=shared_len).astype(np.int32)
    return [np.concatenate([shared,
                            rng.integers(1, cfg.vocab, size=4 + i).astype(np.int32)])
            for i in range(n_req)]


def _serve(cfg, params, prompts, max_new, **kw):
    eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T, **kw)
    eng.submit([p.copy() for p in prompts], max_new=max_new)
    done = eng.run()
    return {r.rid: r.out for r in done}, eng


class TestPagedDenseParity:
    @pytest.mark.parametrize("path,kv", [("fake", "fp"), ("fake", "int8"),
                                         ("dequant-fp", "fp"),
                                         ("dequant-fp", "int8"),
                                         ("fused-int8", "fp"),
                                         ("fused-int8", "int8")])
    def test_paged_matches_dense(self, small, path, kv):
        """Mixed lengths + staggered budgets through the page pool == the dense
        slot table, token-exact, with mid-decode churn on both engines."""
        cfg, params, qparams = small
        if path == "fake":
            serve_params, quant = params, ql.W8A8_CROSSQUANT
        else:
            serve_params, quant = qparams, ql.W8A8_INT8
        prompts = _mixed_prompts(cfg)
        dense, _ = _serve(cfg, serve_params, prompts, MAX_NEW, quant=quant,
                          path=path, kv_cache=kv)
        paged, eng = _serve(cfg, serve_params, prompts, MAX_NEW, quant=quant,
                            path=path, kv_cache=kv, cache_layout="paged",
                            page_size=PS)
        assert eng.counters["mid_decode_admissions"] > 0
        assert paged == dense, (path, kv)
        eng.pool.check()

    def test_model_level_parity(self, small):
        """Prefill logits through a paged cache are *bitwise* equal to the
        dense cache on both KV modes (cold paged prefill shares the dense
        attention codepath verbatim; the table scatter is a pure layout
        change). Decode serves through the Pallas paged kernel on every path —
        same f32 math with an online softmax over pages, so its logits agree
        with the dense plain-softmax to reassociation level and the sampled
        token is identical (the contract the serving parity tests gate)."""
        cfg, params, _ = small
        rng = np.random.default_rng(7)
        lens = [5, 11]
        toks = np.zeros((2, max(lens)), np.int32)
        for i, n in enumerate(lens):
            toks[i, :n] = rng.integers(1, cfg.vocab, size=n)
        for kv_int8 in (False, True):
            dense = M.init_cache(cfg, 2, T, dtype=jnp.float32, kv_int8=kv_int8)
            paged = M.init_cache(cfg, 2, T, dtype=jnp.float32, kv_int8=kv_int8,
                                 layout="paged", page_size=PS)
            paged["page_table"] = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]],
                                              jnp.int32)
            cl = jnp.asarray(lens, jnp.int32)
            ld, exd = M.apply(params, {"tokens": jnp.asarray(toks)}, cfg,
                              mode="prefill", caches=dense, cur_len=cl)
            lp, exp_ = M.apply(params, {"tokens": jnp.asarray(toks)}, cfg,
                               mode="prefill", caches=paged, cur_len=cl)
            np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
            nxt = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
            ld2, _ = M.apply(params, {"tokens": nxt}, cfg, mode="decode",
                             caches=exd["caches"], cur_len=cl + 1)
            lp2, _ = M.apply(params, {"tokens": nxt}, cfg, mode="decode",
                             caches=exp_["caches"], cur_len=cl + 1)
            np.testing.assert_allclose(np.asarray(ld2), np.asarray(lp2),
                                       rtol=2e-5, atol=2e-5)
            np.testing.assert_array_equal(np.asarray(jnp.argmax(ld2, -1)),
                                          np.asarray(jnp.argmax(lp2, -1)))


class TestPrefixReuse:
    @pytest.mark.parametrize("path,kv", [("fake", "fp"), ("fused-int8", "int8")])
    def test_warm_admissions_match_cold(self, small, path, kv):
        """Prefix-hit admissions emit exactly the tokens of a cold (reuse-off)
        paged engine — and of the dense engine — while measurably saving
        prefill tokens."""
        cfg, params, qparams = small
        serve_params = params if path == "fake" else qparams
        quant = ql.W8A8_CROSSQUANT if path == "fake" else ql.W8A8_INT8
        prompts = _shared_prefix_prompts(cfg)
        warm, ew = _serve(cfg, serve_params, prompts, 4, quant=quant, path=path,
                          kv_cache=kv, cache_layout="paged", page_size=PS)
        cold, ec = _serve(cfg, serve_params, prompts, 4, quant=quant, path=path,
                          kv_cache=kv, cache_layout="paged", page_size=PS,
                          prefix_reuse=False)
        dense, _ = _serve(cfg, serve_params, prompts, 4, quant=quant, path=path,
                          kv_cache=kv)
        assert warm == cold == dense, (path, kv)
        assert ew.counters["prefix_hits"] > 0
        assert ew.prefix_hit_rate() > 0.0
        assert ec.counters["prefix_hits"] == 0
        assert ew.counters["prefill_tokens"] < ec.counters["prefill_tokens"]
        assert (ew.counters["prefill_tokens"] + ew.counters["prefix_tokens_reused"]
                == ew.counters["prompt_tokens"])

    def test_shared_pages_are_copy_free(self, small):
        """A prefix-hit admission's leading page ids are literally the cached
        pages (no copy), and the radix index holds one reference on them."""
        cfg, params, _ = small
        prompts = _shared_prefix_prompts(cfg, n_req=2)
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                            cache_layout="paged", page_size=PS)
        eng.submit([prompts[0].copy()], max_new=4)
        eng.run()
        held = set(eng.radix.held_pages())
        assert len(held) == len(prompts[0]) // PS  # full prompt pages cached
        eng.submit([prompts[1].copy()], max_new=4)
        eng._admit([])
        slot = next(i for i, s in enumerate(eng._slots) if s is not None)
        n_shared = len(prompts[1]) // PS
        shared_now = eng._seq_pages[slot][:n_shared]
        assert set(shared_now) <= held        # same physical pages, no copy
        for p in shared_now:
            assert eng.pool.refs[p] == 2      # radix retain + this sequence

    def test_int8_shared_pages_bit_identical(self, small):
        """Why int8 pages share exactly: per-token quantization is
        deterministic, so the cached prefix pages a warm admission maps are
        byte-identical (codes AND scales) to the pages a cold prefill of the
        same tokens writes."""
        cfg, params, _ = small
        prompts = _shared_prefix_prompts(cfg, n_req=2)

        def pages_of(eng, prompt):
            eng.submit([prompt.copy()], max_new=2)
            eng._admit([])
            slot = next(i for i, s in enumerate(eng._slots) if s is not None)
            ids = eng._seq_pages[slot][: len(prompt) // PS]
            leaves = {}
            for key in ("k_pages", "v_pages", "k_scale_pages", "v_scale_pages"):
                leaves[key] = np.asarray(eng.caches["blocks"][0][key][:, ids])
            return leaves

        a = pages_of(E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                                   cache_layout="paged", page_size=PS,
                                   kv_cache="int8"), prompts[0])
        b = pages_of(E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                                   cache_layout="paged", page_size=PS,
                                   kv_cache="int8"), prompts[1])
        n = min(a["k_pages"].shape[1], b["k_pages"].shape[1])
        for key in a:
            np.testing.assert_array_equal(a[key][:, :n], b[key][:, :n])

    def test_partial_tail_copy_on_write(self, small):
        """A prompt matching k full pages plus part of a cached page copies the
        matched token rows into a fresh page (COW) instead of re-prefilling
        them — and still emits cold-identical tokens."""
        cfg, params, _ = small
        rng = np.random.default_rng(5)
        base = rng.integers(1, cfg.vocab, size=16).astype(np.int32)
        fork = np.concatenate([base[:12],
                               rng.integers(1, cfg.vocab, size=6).astype(np.int32)])
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                            cache_layout="paged", page_size=PS)
        eng.submit([base.copy()], max_new=3)
        eng.run()
        eng.submit([fork.copy()], max_new=4)
        got = {r.rid: r.out for r in eng.run()}
        assert eng.counters["cow_copies"] == 1
        assert eng.counters["prefix_tokens_reused"] >= PS + 4  # page 0 + 4 COW rows
        cold, _ = _serve(cfg, params, [base, fork], [3, 4],
                         cache_layout="paged", page_size=PS, prefix_reuse=False)
        assert got[1] == cold[1]
        eng.pool.check()


class TestAllocatorInvariants:
    def test_pool_basics(self):
        pool = PagePool(4)
        a = pool.alloc(3)
        assert sorted(a) == [0, 1, 2] and pool.free_count == 1
        assert pool.alloc(2) is None          # insufficient: no partial grant
        pool.incref([a[0]])
        assert pool.decref([a[0]]) == []      # still held once
        assert pool.decref(a) == a            # all freed now
        pool.check()
        assert pool.free_count == 4

    def test_radix_match_insert_evict(self):
        pool = PagePool(8)
        idx = RadixIndex(4)
        toks = np.arange(12, dtype=np.int32)
        pages = pool.alloc(3)
        idx.insert(toks, pages[: len(toks) // 4], pool)
        got_pages, matched, partial = idx.match(np.arange(10, dtype=np.int32))
        assert got_pages == pages[:2] and matched == 8
        # rest [8, 9] partially matches the third cached chunk [8..11]
        assert partial is not None and partial.page == pages[2]
        assert partial.length == 2
        # partial: diverge inside the second chunk
        fork = np.asarray([0, 1, 2, 3, 4, 5, 99, 98], np.int32)
        got_pages, matched, partial = idx.match(fork)
        assert got_pages == [pages[0]] and matched == 4
        assert partial is not None and partial.page == pages[1]
        assert partial.length == 2
        # eviction frees LRU leaves only down to what's needed
        pool.decref(pages)                    # only the index holds them now
        freed = idx.evict(pool, pool.free_count + 2)
        assert freed == 2
        pool.check()

    def test_refcount_invariants_under_churn(self, small):
        """Small pool + shared-prefix churn: every page is either free or
        accounted to live sequences / the prefix index, before and after."""
        cfg, params, _ = small
        rng = np.random.default_rng(9)
        shared = rng.integers(1, cfg.vocab, size=8).astype(np.int32)
        prompts = []
        for i in range(8):
            sfx = rng.integers(1, cfg.vocab, size=3 + (i % 5)).astype(np.int32)
            prompts.append(np.concatenate([shared, sfx]) if i % 2 else sfx)
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                            cache_layout="paged", page_size=PS, n_pages=7)
        eng.submit(prompts, max_new=[2 + (i % 4) for i in range(8)])
        done = eng.run()
        assert len(done) == 8
        eng.pool.check()
        held = eng.radix.held_pages()
        assert len(held) == len(set(held))
        # all sequences retired: remaining references belong to the index alone
        assert all(eng.pool.refs[p] == 1 for p in held)
        assert eng.pool.used_count == len(held)
        assert eng.counters["peak_pages_in_use"] <= 7

    def test_matched_prefix_survives_eviction_pressure(self, small):
        """Planning must incref the matched prefix pages *before* evicting for
        its own allocation: an index-only prefix (refs == 1) would otherwise be
        evicted under pressure and handed straight back as a writable own page
        of the very plan that matched it — corrupting the reused prefix. Here
        the sacrificial cached prefix evicts instead, and the reused one stays
        intact (tokens equal a cold engine's)."""
        cfg, params, _ = small
        rng = np.random.default_rng(21)
        base = rng.integers(1, cfg.vocab, size=16).astype(np.int32)   # 2 pages
        other = rng.integers(1, cfg.vocab, size=9).astype(np.int32)   # 1 page
        eng = E.ServeEngine(cfg, params, batch_size=1, max_len=T,
                            cache_layout="paged", page_size=PS, n_pages=4)
        eng.submit([base.copy()], max_new=2)
        eng.run()
        eng.submit([other.copy()], max_new=2)
        eng.run()
        assert len(eng.radix.held_pages()) == 3   # 4-page pool, 1 free
        fork = np.concatenate([base,
                               rng.integers(1, cfg.vocab, size=1).astype(np.int32)])
        eng.submit([fork.copy()], max_new=15)     # needs 2 shared + 2 own
        got = eng.run()[0].out
        assert eng.counters["pages_evicted"] >= 1    # the sacrificial prefix went
        assert eng.counters["prefix_tokens_reused"] >= 16
        assert sorted(set(eng.radix.held_pages())) == sorted(eng.radix.held_pages())
        eng.pool.check()
        cold = E.ServeEngine(cfg, params, batch_size=1, max_len=T,
                             cache_layout="paged", page_size=PS, n_pages=4,
                             prefix_reuse=False)
        cold.submit([fork.copy()], max_new=15)
        assert got == cold.run()[0].out

    def test_unsatisfiable_pressure_fails_clean(self, small):
        """When eviction cannot help (the request needs more pages than the
        pool holds even after giving everything up), planning must release the
        references it took and the engine raise — never hand a matched page
        out twice."""
        cfg, params, _ = small
        rng = np.random.default_rng(22)
        base = rng.integers(1, cfg.vocab, size=16).astype(np.int32)
        eng = E.ServeEngine(cfg, params, batch_size=1, max_len=T,
                            cache_layout="paged", page_size=PS, n_pages=3)
        eng.submit([base.copy()], max_new=2)
        eng.run()
        held = set(eng.radix.held_pages())
        fork = np.concatenate([base,
                               rng.integers(1, cfg.vocab, size=1).astype(np.int32)])
        eng.submit([fork.copy()], max_new=15)     # needs 4 pages of a 3-page pool
        with pytest.raises(RuntimeError, match="page pool too small"):
            eng.run()
        eng.pool.check()
        assert set(eng.radix.held_pages()) == held   # prefix neither evicted
        assert all(eng.pool.refs[p] == 1 for p in held)  # nor leaked a ref

    def test_reservation_is_exact_not_one_over(self, small):
        """The final sampled token is never scattered (retire fires first), so
        a prompt of one page plus max_new = page_size + 1 fits exactly two
        pages — a 2-page pool must serve it rather than over-reserve a third."""
        cfg, params, _ = small
        rng = np.random.default_rng(23)
        eng = E.ServeEngine(cfg, params, batch_size=1, max_len=T,
                            cache_layout="paged", page_size=PS, n_pages=2)
        eng.submit([rng.integers(1, cfg.vocab, size=PS).astype(np.int32)],
                   max_new=PS + 1)
        out = eng.run()[0].out
        assert len(out) == PS + 1
        assert eng.counters["peak_pages_in_use"] == 2
        eng.pool.check()

    def test_pool_too_small_raises(self, small):
        cfg, params, _ = small
        eng = E.ServeEngine(cfg, params, batch_size=1, max_len=T,
                            cache_layout="paged", page_size=PS, n_pages=2)
        eng.submit([np.arange(1, 20, dtype=np.int32)], max_new=8)
        with pytest.raises(RuntimeError, match="page pool too small"):
            eng.run()


def _rand_table(rng, B, P, ps, maxP):
    """Random injective tables with sentinel tails past each row's pages."""
    tab = np.full((B, maxP), P, np.int32)
    kvl = np.zeros(B, np.int32)
    perm = rng.permutation(P)
    off = 0
    for b in range(B):
        n = int(rng.integers(1, min(maxP, P - off) + 1))
        tab[b, :n] = perm[off: off + n]
        off += n
        kvl[b] = int(rng.integers((n - 1) * ps + 1, n * ps + 1))
    return jnp.asarray(tab), jnp.asarray(kvl)


def _rand_pools(rng, P, ps, Hkv, D, kv_int8):
    """(k_pages, v_pages, k_scale_pages|None, v_scale_pages|None)."""
    if not kv_int8:
        return (jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32),
                jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32),
                None, None)
    return (jnp.asarray(rng.integers(-127, 128, (P, ps, Hkv, D)), jnp.int8),
            jnp.asarray(rng.integers(-127, 128, (P, ps, Hkv, D)), jnp.int8),
            jnp.asarray(0.002 + 0.05 * rng.random((P, ps, Hkv, 1)), jnp.float32),
            jnp.asarray(0.002 + 0.05 * rng.random((P, ps, Hkv, 1)), jnp.float32))


class TestPagedKernelVsOracle:
    @pytest.mark.parametrize("kv_int8", [False, True])
    @pytest.mark.parametrize("B,Hkv,G,D,P,ps,maxP",
                             [(2, 2, 2, 16, 8, 8, 4),
                              (1, 1, 4, 32, 4, 16, 2),
                              (3, 2, 1, 64, 16, 4, 8)])
    def test_sweep(self, B, Hkv, G, D, P, ps, maxP, kv_int8):
        """window= / softcap= edge paths vs the oracle, fp AND int8-KV pools
        (in-kernel per-token dequant at the score/prob level)."""
        rng = np.random.default_rng(B * 100 + D + kv_int8)
        H = Hkv * G
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        kp, vp, ks, vs = _rand_pools(rng, P, ps, Hkv, D, kv_int8)
        tab, kvl = _rand_table(rng, B, P, ps, maxP)
        for window, softcap in ((None, None), (5, None), (None, 30.0)):
            got = kops.paged_decode_attention(q, kp, vp, tab, kvl,
                                              k_scale_pages=ks, v_scale_pages=vs,
                                              window=window, softcap=softcap)
            want = kref.paged_decode_attention_ref(
                q.reshape(B, Hkv, G, D), kp, vp, tab, kvl,
                k_scale_pages=ks, v_scale_pages=vs,
                window=window, softcap=softcap)
            np.testing.assert_allclose(
                np.asarray(got.reshape(B, Hkv, G, D)), np.asarray(want),
                rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("kv_int8", [False, True])
    def test_kv_len_scalar_broadcasts_like_vector(self, kv_int8):
        """ops.paged_decode_attention accepts a scalar kv_len (all slots
        aligned) and must compute exactly the (B,)-vector result."""
        rng = np.random.default_rng(31 + kv_int8)
        B, Hkv, G, D, P, ps, maxP = 2, 2, 2, 16, 8, 8, 4
        q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
        kp, vp, ks, vs = _rand_pools(rng, P, ps, Hkv, D, kv_int8)
        tab = jnp.asarray([[0, 1, 2, P], [3, 4, 5, P]], jnp.int32)
        kw = dict(k_scale_pages=ks, v_scale_pages=vs)
        got_scalar = kops.paged_decode_attention(
            q, kp, vp, tab, jnp.asarray(17, jnp.int32), **kw)
        got_vector = kops.paged_decode_attention(
            q, kp, vp, tab, jnp.full((B,), 17, jnp.int32), **kw)
        np.testing.assert_array_equal(np.asarray(got_scalar),
                                      np.asarray(got_vector))

    @pytest.mark.parametrize("kv_int8", [False, True])
    def test_single_live_page_and_all_sentinel_row(self, kv_int8):
        """One slot holding a single live page (kv_len inside page 0) matches
        the oracle; a *free* slot — all-sentinel table row, the shape a retired
        slot decodes with in lock-step — must produce finite output without
        touching any live page's result."""
        rng = np.random.default_rng(57 + kv_int8)
        B, Hkv, G, D, P, ps, maxP = 2, 2, 2, 16, 8, 8, 4
        q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
        kp, vp, ks, vs = _rand_pools(rng, P, ps, Hkv, D, kv_int8)
        tab = jnp.asarray([[5] + [P] * (maxP - 1), [P] * maxP], jnp.int32)
        kvl = jnp.asarray([3, 1], jnp.int32)   # free slots decode with cur_len 1
        kw = dict(k_scale_pages=ks, v_scale_pages=vs)
        got = kops.paged_decode_attention(q, kp, vp, tab, kvl, **kw)
        want = kref.paged_decode_attention_ref(
            q.reshape(B, Hkv, G, D), kp, vp, tab, kvl, **kw)
        np.testing.assert_allclose(np.asarray(got[0]),
                                   np.asarray(want.reshape(B, 1, Hkv * G, D)[0]),
                                   rtol=2e-5, atol=2e-5)
        assert np.isfinite(np.asarray(got)).all()
        # the live row's result is independent of the free row's garbage
        got_solo = kops.paged_decode_attention(q[:1], kp, vp, tab[:1], kvl[:1],
                                               **kw)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(got_solo[0]))


class TestHeadroomAndScheduling:
    @pytest.mark.parametrize("layout", ["dense", "paged"])
    def test_max_len_prompt_admits_and_retires(self, small, layout):
        """A prompt of exactly max_len fills its cache at admission: it emits
        the one token its prefill logits produce and retires before any decode
        step could scatter past the cache — and a neighbor slot's request is
        entirely unaffected."""
        cfg, params, _ = small
        rng = np.random.default_rng(3)
        full = rng.integers(1, cfg.vocab, size=T).astype(np.int32)
        other = rng.integers(1, cfg.vocab, size=5).astype(np.int32)
        kw = {"cache_layout": layout, "page_size": PS} if layout == "paged" else {}
        got, eng = _serve(cfg, params, [full, other], [6, 4], **kw)
        assert len(got[0]) == 1               # admit-and-retire, no decode
        bs1 = E.ServeEngine(cfg, params, batch_size=1, max_len=T, **kw)
        bs1.submit([other.copy()], max_new=4)
        assert got[1] == bs1.run()[0].out
        if layout == "paged":
            eng.pool.check()

    def test_submit_rejects_oversized(self, small):
        cfg, params, _ = small
        eng = E.ServeEngine(cfg, params, batch_size=1, max_len=T)
        with pytest.raises(ValueError):
            eng.submit([np.arange(1, T + 2, dtype=np.int32)])
        with pytest.raises(ValueError):
            eng.submit([np.zeros(0, np.int32)])

    def test_head_of_line_bucket_scan(self, small):
        """One odd-length head request must not pre-empt the larger same-bucket
        group behind it: the group admits together, in one prefill call, and
        the served tokens stay order-independent."""
        cfg, params, _ = small
        rng = np.random.default_rng(11)
        odd = rng.integers(1, cfg.vocab, size=5).astype(np.int32)    # bucket 8
        a = rng.integers(1, cfg.vocab, size=12).astype(np.int32)     # bucket 16
        b = rng.integers(1, cfg.vocab, size=13).astype(np.int32)     # bucket 16
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T)
        eng.submit([odd, a, b], max_new=3)
        eng._admit([])
        assert sorted(r.rid for r in eng._slots if r is not None) == [1, 2]
        assert eng.counters["prefill_calls"] == 1
        done = {r.rid: r.out for r in eng.run()}
        ref = E.ServeEngine(cfg, params, batch_size=2, max_len=T)
        ref.submit([a, b, odd], max_new=3)     # bucket-sorted submission order
        ref_done = {r.rid: r.out for r in ref.run()}
        assert done[0] == ref_done[2] and done[1] == ref_done[0]


class TestCacheDtype:
    def test_default_follows_params_dtype(self, small):
        cfg, params, _ = small
        bf16 = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        eng = E.ServeEngine(cfg, bf16, batch_size=2, max_len=T)
        assert eng.caches["blocks"][0]["k"].dtype == jnp.bfloat16
        eng32 = E.ServeEngine(cfg, params, batch_size=2, max_len=T)
        assert eng32.caches["blocks"][0]["k"].dtype == jnp.float32
        eng.submit(_mixed_prompts(cfg)[:2], max_new=3)
        assert all(len(r.out) == 3 for r in eng.run())

    def test_explicit_override_and_int8_unaffected(self, small):
        cfg, params, _ = small
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                            cache_dtype=jnp.bfloat16)
        assert eng.caches["blocks"][0]["k"].dtype == jnp.bfloat16
        eng8 = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                             cache_dtype=jnp.bfloat16, kv_cache="int8")
        assert eng8.caches["blocks"][0]["k"].dtype == jnp.int8
        assert eng8.caches["blocks"][0]["k_scale"].dtype == jnp.float32
