"""Data substrate: determinism, loader ordering, planted outlier statistics."""
import numpy as np

from repro.data import HostDataLoader, make_train_batches
from repro.data.synthetic import (LLAMA_LIKE, OPT_LIKE, OutlierSpec, markov_corpus,
                                  outlier_activations)


class TestMarkovCorpus:
    def test_deterministic(self):
        a = markov_corpus(128, 32, 4, seed=7)
        b = markov_corpus(128, 32, 4, seed=7)
        np.testing.assert_array_equal(a, b)
        c = markov_corpus(128, 32, 4, seed=8)
        assert not np.array_equal(a, c)

    def test_learnable_structure(self):
        """A first-order model predicts the chain: bigram entropy << unigram entropy."""
        toks = markov_corpus(64, 512, 8, branching=2, seed=0)
        flat = toks.reshape(-1)
        pairs = set(zip(flat[:-1].tolist(), flat[1:].tolist()))
        # With branching=2, each token has at most 2 successors (chain restarts at
        # sequence boundaries add a few extras).
        succ = {}
        for a, b in pairs:
            succ.setdefault(a, set()).add(b)
        avg_succ = np.mean([len(v) for v in succ.values()])
        assert avg_succ < 4, avg_succ


class TestBatchFn:
    def test_step_determinism_and_host_sharding(self):
        f0 = make_train_batches(256, 16, 8, host_id=0, num_hosts=2, seed=1)
        f1 = make_train_batches(256, 16, 8, host_id=1, num_hosts=2, seed=1)
        a, b = f0(5), f0(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (4, 16)           # local = global / hosts
        assert not np.array_equal(f0(5)["tokens"], f1(5)["tokens"])

    def test_loader_orders_steps(self):
        f = make_train_batches(64, 8, 4, seed=0)
        with HostDataLoader(f, start_step=0, depth=3) as dl:
            steps = [next(dl)[0] for _ in range(5)]
        assert steps == [0, 1, 2, 3, 4]

    def test_loader_restart_reproduces(self):
        f = make_train_batches(64, 8, 4, seed=0)
        with HostDataLoader(f, start_step=2) as dl:
            s, batch = next(dl)
        assert s == 2
        np.testing.assert_array_equal(batch["tokens"], f(2)["tokens"])


class TestOutlierActivations:
    def test_planted_outlier_statistics(self):
        """Matches App. A: a small fraction of channels carries >=20x values."""
        spec = OutlierSpec(frac_channels=0.01, magnitude=40.0, row_frac=0.9)
        x = outlier_activations(2048, 1000, spec, seed=0)
        col_max = np.abs(x).max(axis=0)
        base = np.median(col_max)
        outlier_cols = (col_max > 20 * base).sum()
        assert 5 <= outlier_cols <= 20      # planted 10 of 1000

    def test_opt_regime_has_stronger_outliers_than_llama(self):
        xo = outlier_activations(1024, 1024, OPT_LIKE, seed=1)
        xl = outlier_activations(1024, 1024, LLAMA_LIKE, seed=1)
        ro = np.abs(xo).max() / np.median(np.abs(xo).max(axis=0))
        rl = np.abs(xl).max() / np.median(np.abs(xl).max(axis=0))
        assert ro > rl

    def test_deterministic(self):
        a = outlier_activations(64, 64, seed=3)
        b = outlier_activations(64, 64, seed=3)
        np.testing.assert_array_equal(a, b)
