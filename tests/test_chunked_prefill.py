"""Ragged chunked-prefill (DESIGN.md §3.10): kernel-vs-oracle + engine parity.

Three layers of pinning, innermost first:

* **Kernel vs oracle** — the packed-ragged Pallas kernel
  (``kernels.flash_attention._ragged_prefill_kernel``) against the gather
  oracle over random injective page tables: q_len/kv_len/prefix combos
  (including chunks that start mid-page — the packed-buffer overlay offset
  goes negative there), dead (q_len == 0) slots, all-sentinel table rows, and
  int8-KV scale pools on/off. The decode degenerate (q_len == 1,
  kv_len == cs + 1) must agree with the decode kernel.
* **Engine parity** — ``ServeEngine(chunked=True, token_budget=...)`` must
  emit, per request, exactly the tokens of the same engine without chunking,
  on every path × KV-cache combination, across budgets small enough to force
  multi-chunk prompts and admission bursts that overlap in-flight decodes.
* **Interactions** — chunked + speculate=4 serves draft windows as q_len > 1
  rows of the same packed launch and must stay token-exact vs plain decode;
  the §4.1 per-chunk quantization-kernel proportion is unchanged vs
  whole-prompt prefill (examples/serve_batch.py replay).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.flash_attention import (paged_decode_attention_pallas,
                                           ragged_prefill_attention_pallas)
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.serving import engine as E

T = 32           # cache length for every engine in this module
PS = 8           # page size for paged engines

COMBOS = [("fake", "fp"), ("fake", "int8"),
          ("dequant-fp", "fp"), ("dequant-fp", "int8"),
          ("fused-int8", "fp"), ("fused-int8", "int8")]


def _rand_table(rng, B, P, ps, maxP):
    """Random injective tables with sentinel tails past each row's pages."""
    tab = np.full((B, maxP), P, np.int32)
    kvl = np.zeros(B, np.int32)
    perm = rng.permutation(P)
    off = 0
    for b in range(B):
        n = int(rng.integers(1, min(maxP, P - off) + 1))
        tab[b, :n] = perm[off: off + n]
        off += n
        kvl[b] = int(rng.integers((n - 1) * ps + 1, n * ps + 1))
    return jnp.asarray(tab), jnp.asarray(kvl)


def _rand_pools(rng, P, ps, Hkv, D, kv_int8):
    """(k_pages, v_pages, k_scale_pages|None, v_scale_pages|None)."""
    if not kv_int8:
        return (jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32),
                jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32),
                None, None)
    return (jnp.asarray(rng.integers(-127, 128, (P, ps, Hkv, D)), jnp.int8),
            jnp.asarray(rng.integers(-127, 128, (P, ps, Hkv, D)), jnp.int8),
            jnp.asarray(0.002 + 0.05 * rng.random((P, ps, Hkv, 1)), jnp.float32),
            jnp.asarray(0.002 + 0.05 * rng.random((P, ps, Hkv, 1)), jnp.float32))


def _rand_chunks(rng, kvl, C, *, allow_dead=True):
    """Packed chunk extents: per slot a chunk length in [0, min(C, kvl)] with
    contiguous packing. Returns (q_start, q_len, Nt)."""
    qln = np.zeros(len(kvl), np.int32)
    for b, kv in enumerate(np.asarray(kvl)):
        lo = 0 if allow_dead else 1
        qln[b] = int(rng.integers(lo, min(C, int(kv)) + 1))
    qs = np.concatenate([[0], np.cumsum(qln)[:-1]]).astype(np.int32)
    return jnp.asarray(qs), jnp.asarray(qln), int(qln.sum())


def _packed_new(rng, Nt, Hkv, D):
    return (jnp.asarray(rng.standard_normal((max(Nt, 1), Hkv, D)), jnp.float32),
            jnp.asarray(rng.standard_normal((max(Nt, 1), Hkv, D)), jnp.float32))


def _kernel_vs_oracle(rng, B, Hkv, G, D, P, ps, maxP, C, kv_int8, *,
                      window=None, softcap=None, force_qln=None,
                      force_kvl=None, sentinel_row=None):
    kp, vp, ksp, vsp = _rand_pools(rng, P, ps, Hkv, D, kv_int8)
    tab, kvl = _rand_table(rng, B, P, ps, maxP)
    if force_kvl is not None:
        kvl = jnp.asarray(force_kvl, jnp.int32)
    qs, qln, Nt = _rand_chunks(rng, kvl, C)
    if force_qln is not None:
        qln = jnp.asarray(force_qln, jnp.int32)
        # chunk tokens are the newest kv_len tokens, so q_len <= kv_len
        kvl = jnp.maximum(kvl, qln)
        qs = jnp.asarray(np.concatenate(
            [[0], np.cumsum(np.asarray(qln))[:-1]]), jnp.int32)
        Nt = int(np.asarray(qln).sum())
    if force_kvl is not None or force_qln is not None:
        # rebuild the table so each row covers its (possibly forced) kv_len
        tab = np.full((B, maxP), P, np.int32)
        perm = rng.permutation(P)
        off = 0
        for b in range(B):
            n = -(-int(np.asarray(kvl)[b]) // ps)
            assert off + n <= P and n <= maxP, (off, n, P, maxP)
            tab[b, :n] = perm[off: off + n]
            off += n
        tab = jnp.asarray(tab)
    if sentinel_row is not None:
        tab = tab.at[sentinel_row].set(P)
    q = jnp.asarray(rng.standard_normal((max(Nt, 1), Hkv * G, D)), jnp.float32)
    kn, vn = _packed_new(rng, Nt, Hkv, D)
    got = kops.ragged_prefill_attention(
        q, kn, vn, kp, vp, tab, qs, qln, kvl, chunk_cap=C,
        k_scale_pages=ksp, v_scale_pages=vsp, window=window, softcap=softcap)
    qg = q.reshape(max(Nt, 1), Hkv, G, D)
    ref = kref.ragged_prefill_attention_ref(
        qg, kn, vn, kp, vp, tab, qs, qln, kvl, chunk_cap=C,
        k_scale_pages=ksp, v_scale_pages=vsp, window=window,
        softcap=softcap).reshape(max(Nt, 1), Hkv * G, D)
    return np.asarray(got), np.asarray(ref), np.asarray(qs), np.asarray(qln)


class TestRaggedKernelVsOracle:
    """Packed ragged chunks through the pallas kernel vs the gather oracle.

    Valid rows must agree to 2e-5; rows no slot owns must be exactly zero in
    both (the kernel zero-inits its shared output block)."""

    @pytest.mark.parametrize("kv_int8", [False, True])
    @pytest.mark.parametrize("C", [4, 8, 16])
    @pytest.mark.parametrize("B,Hkv,G,D,P,ps,maxP",
                             [(2, 2, 2, 16, 8, 8, 4),
                              (1, 1, 4, 32, 4, 16, 2),
                              (3, 2, 1, 64, 16, 4, 8)])
    def test_chunk_sweep(self, B, Hkv, G, D, P, ps, maxP, C, kv_int8):
        rng = np.random.default_rng(1000 * C + 10 * B + kv_int8)
        got, ref, qs, qln = _kernel_vs_oracle(rng, B, Hkv, G, D, P, ps, maxP,
                                              C, kv_int8)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)
        assert np.isfinite(got).all()

    @pytest.mark.parametrize("window,softcap", [(5, None), (None, 30.0)])
    def test_window_and_softcap(self, window, softcap):
        B, Hkv, G, D, P, ps, maxP, C = 2, 2, 2, 16, 8, 8, 4, 8
        rng = np.random.default_rng(77)
        got, ref, _, _ = _kernel_vs_oracle(rng, B, Hkv, G, D, P, ps, maxP, C,
                                           True, window=window, softcap=softcap)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("kv_int8", [False, True])
    def test_mid_page_chunk_start(self, kv_int8):
        """Chunk starts mid-page (prefix not a page multiple): the overlay
        offset for the straddling page is negative relative to the packed
        origin — exactly what the ps leading pad rows absorb."""
        B, Hkv, G, D, P, ps, maxP, C = 2, 2, 2, 16, 8, 8, 4, 8
        rng = np.random.default_rng(21 + kv_int8)
        # kvl chosen so cs = kvl - qln lands strictly inside a page
        got, ref, _, _ = _kernel_vs_oracle(
            rng, B, Hkv, G, D, P, ps, maxP, C, kv_int8,
            force_kvl=[ps + 3, 2 * ps + 5], force_qln=[5, 6])
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)

    def test_page_aligned_chunk_boundaries(self):
        """Chunk exactly one page, starting and ending on page boundaries."""
        B, Hkv, G, D, P, ps, maxP, C = 2, 1, 2, 16, 8, 8, 4, 8
        rng = np.random.default_rng(31)
        got, ref, _, _ = _kernel_vs_oracle(
            rng, B, Hkv, G, D, P, ps, maxP, C, True,
            force_kvl=[2 * ps, 3 * ps], force_qln=[ps, ps])
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)

    def test_dead_slot_rows_stay_zero(self):
        """A q_len == 0 slot contributes no packed rows, walks no pages, and
        leaves the shared output block untouched."""
        B, Hkv, G, D, P, ps, maxP, C = 3, 2, 2, 16, 8, 8, 4, 8
        rng = np.random.default_rng(41)
        got, ref, qs, qln = _kernel_vs_oracle(
            rng, B, Hkv, G, D, P, ps, maxP, C, True, force_qln=[4, 0, 5])
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)
        assert np.isfinite(got).all()

    def test_all_sentinel_row_is_finite(self):
        """A freshly admitted slot whose table row is all sentinel must stay
        finite (NaN would poison the jit-donated cache buffers) and must not
        perturb any other slot's rows."""
        B, Hkv, G, D, P, ps, maxP, C = 2, 2, 2, 16, 8, 8, 4, 8
        rng = np.random.default_rng(51)
        got, ref, qs, qln = _kernel_vs_oracle(
            rng, B, Hkv, G, D, P, ps, maxP, C, True,
            force_kvl=[2 * ps, 1], force_qln=[6, 1], sentinel_row=1)
        assert np.isfinite(got).all()
        n0 = int(qln[0])
        np.testing.assert_allclose(got[:n0], ref[:n0], rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("kv_int8", [False, True])
    def test_decode_degenerate_matches_decode_kernel(self, kv_int8):
        """q_len == 1 rows with kv_len == cs + 1 are single-token decode: the
        ragged launch must agree with the decode kernel on those rows (not
        bitwise — the fp overlay reads the packed k/v for the newest token
        where decode reads its scattered page — so the pool rows here are the
        scattered packed values, making both paths see identical inputs)."""
        B, Hkv, G, D, P, ps, maxP = 2, 2, 2, 16, 8, 8, 4
        rng = np.random.default_rng(61)
        kp, vp, ksp, vsp = _rand_pools(rng, P, ps, Hkv, D, kv_int8)
        tab, kvl = _rand_table(rng, B, P, ps, maxP)
        qs = jnp.asarray([0, 1], jnp.int32)
        qln = jnp.ones(B, jnp.int32)
        q = jnp.asarray(rng.standard_normal((B, Hkv * G, D)), jnp.float32)
        # the decode kernel attends the newest token from the pool; mirror by
        # packing that pool row as the overlay k/v so inputs agree exactly
        tabn, kvn = np.asarray(tab), np.asarray(kvl)
        rows_k, rows_v = [], []
        for b in range(B):
            pg = tabn[b, (kvn[b] - 1) // ps]
            r = (kvn[b] - 1) % ps
            kf = np.asarray(kp[pg, r], np.float32)
            vf = np.asarray(vp[pg, r], np.float32)
            if kv_int8:
                kf = kf * np.asarray(ksp[pg, r], np.float32)
                vf = vf * np.asarray(vsp[pg, r], np.float32)
            rows_k.append(kf)
            rows_v.append(vf)
        kn = jnp.asarray(np.stack(rows_k))
        vn = jnp.asarray(np.stack(rows_v))
        got = kops.ragged_prefill_attention(
            q, kn, vn, kp, vp, tab, qs, qln, kvl, chunk_cap=4,
            k_scale_pages=ksp, v_scale_pages=vsp)
        qd = q.reshape(B, Hkv, G, D)
        ks = vs = None
        if kv_int8:
            ks = jnp.transpose(ksp[..., 0], (0, 2, 1))
            vs = jnp.transpose(vsp[..., 0], (0, 2, 1))
        dec = paged_decode_attention_pallas(qd, kp, vp, tab, kvl,
                                            k_scale=ks, v_scale=vs,
                                            interpret=True)
        np.testing.assert_allclose(
            np.asarray(got).reshape(B, Hkv, G, D), np.asarray(dec),
            rtol=2e-5, atol=2e-5)

    def test_full_budget_single_slot(self):
        """One slot consumes the whole packed block (cold prefill, cs == 0)."""
        B, Hkv, G, D, P, ps, maxP, C = 1, 2, 2, 16, 8, 8, 4, 16
        rng = np.random.default_rng(71)
        got, ref, _, _ = _kernel_vs_oracle(
            rng, B, Hkv, G, D, P, ps, maxP, C, False,
            force_kvl=[16], force_qln=[16])
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Engine-level parity: chunked scheduler vs the bucketed admission path.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    return cfg, params, qparams


def _prompts(seed=5, n=4, shared=16):
    """Shared-prefix workload: radix hits make later chunks start mid-page."""
    rng = np.random.default_rng(seed)
    cfg_vocab = 256      # starcoder2 smoke vocab: tokens must stay in range
    pre = rng.integers(1, cfg_vocab, size=shared).astype(np.int32)
    return [np.concatenate([pre, rng.integers(1, cfg_vocab, size=4 + i).astype(np.int32)])
            for i in range(n)]


MAX_NEW = [6, 4, 7, 3]


def _serve(small, path, kv, prompts=None, max_new=None, **kw):
    cfg, params, qparams = small
    p, q = (params, None) if path == "fake" else (qparams, ql.W8A8_INT8)
    eng = E.ServeEngine(cfg, p, quant=q, batch_size=3, max_len=T,
                        cache_layout="paged", page_size=PS, path=path,
                        kv_cache=kv, **kw)
    eng.submit(prompts if prompts is not None else _prompts(),
               max_new if max_new is not None else MAX_NEW)
    done = eng.run()
    return {r.rid: list(r.out) for r in done}, eng


class TestChunkedEngineParity:
    """chunked=True must be token-exact vs the bucketed admission engine.

    int8 KV note: a prompt split across chunks reads its *own* earlier chunks
    int8-dequantized from the pool, where whole-suffix prefill sees them in
    fp — so multi-chunk int8 prefill is not bitwise-identical attention.
    As with warm int8 prefix reuse (test_paged_serving), argmax token
    equality is pinned empirically at the test seeds; the first chunk's pool
    pages land bit-identically, later chunks drift by a few code units.
    """

    @pytest.mark.parametrize("path,kv", COMBOS)
    @pytest.mark.parametrize("tb", [9, 12])
    def test_paths_kv_combos(self, small, path, kv, tb):
        base, _ = _serve(small, path, kv)
        chk, eng = _serve(small, path, kv, chunked=True, token_budget=tb)
        assert chk == base
        st = eng.counters
        assert st["chunk_steps"] > 0
        assert st["chunk_prefill_rows"] > 0   # tb forces multi-chunk prompts

    @pytest.mark.parametrize("tb", [8, 10, 14, 16, 24, 64])
    def test_budget_sweep_fp(self, small, tb):
        """fp KV is bitwise chunk-invariant: every budget must be exact."""
        base, _ = _serve(small, "dequant-fp", "fp")
        chk, _ = _serve(small, "dequant-fp", "fp", chunked=True, token_budget=tb)
        assert chk == base

    def test_cold_no_sharing(self, small):
        prompts = [np.arange(1, 1 + n, dtype=np.int32) * 3 % 509 + 1
                   for n in (20, 7, 13, 24)]
        base, _ = _serve(small, "fake", "fp", prompts=prompts)
        chk, _ = _serve(small, "fake", "fp", prompts=prompts,
                        chunked=True, token_budget=10)
        assert chk == base

    def test_radix_stats_match(self, small):
        _, b = _serve(small, "fake", "fp")
        _, c = _serve(small, "fake", "fp", chunked=True, token_budget=12)
        assert c.prefix_hit_rate() == b.prefix_hit_rate()

    def test_int8_pool_divergence_is_bounded(self, small):
        """First chunk lands bit-identically; later chunks drift by at most a
        few code units (their hidden states attended the first chunk through
        the int8 dequant, the whole-suffix baseline saw it in fp)."""
        cfg, params, qparams = small
        outs = {}
        for chunked in (False, True):
            kw = dict(chunked=True, token_budget=9) if chunked else {}
            eng = E.ServeEngine(cfg, qparams, quant=ql.W8A8_INT8, batch_size=3,
                                max_len=T, cache_layout="paged", page_size=PS,
                                path="fused-int8", kv_cache="int8", **kw)
            eng.submit(_prompts()[:1], [1])
            eng.run()
            outs[chunked] = jax.tree.map(np.asarray, eng.caches)
        flat_a = [l for l in jax.tree.leaves(outs[False]) if l.ndim == 5]
        flat_b = [l for l in jax.tree.leaves(outs[True]) if l.ndim == 5]
        assert flat_a and len(flat_a) == len(flat_b)
        used = (len(_prompts()[0]) + PS - 1) // PS  # pages touched by slot 0
        for a, b in zip(flat_a, flat_b):
            # chunk 1 covers page 0 exactly (budget 9 -> page-aligned cut at 8)
            np.testing.assert_array_equal(a[:, 0], b[:, 0])
            da = np.abs(a[:, 1:used].astype(np.float32)
                        - b[:, 1:used].astype(np.float32))
            assert da.max() <= 16, da.max()

    def test_long_prompt_retires_at_cap(self, small):
        """A prompt of length T fills the cache; both paths emit 1 token."""
        rng = np.random.default_rng(9)
        prompts = [rng.integers(1, 512, size=T).astype(np.int32)]
        base, _ = _serve(small, "fake", "fp", prompts=prompts, max_new=[4])
        chk, _ = _serve(small, "fake", "fp", prompts=prompts, max_new=[4],
                        chunked=True, token_budget=8)
        assert chk == base
        assert all(len(v) == 1 for v in chk.values())


class TestChunkedInteractions:
    def test_admission_burst(self, small):
        """Requests injected mid-decode interleave with running slots."""
        late = [np.arange(2, 2 + n, dtype=np.int32) * 5 % 503 + 1
                for n in (18, 11)]
        base, _ = _serve(small, "dequant-fp", "int8")
        base_late, _ = _serve(small, "dequant-fp", "int8", prompts=late,
                              max_new=[5, 5])
        cfg, params, qparams = small
        eng = E.ServeEngine(cfg, qparams, quant=ql.W8A8_INT8, batch_size=3,
                            max_len=T, cache_layout="paged", page_size=PS,
                            path="dequant-fp", kv_cache="int8",
                            chunked=True, token_budget=10)
        eng.submit(_prompts(), MAX_NEW)
        finished = []
        for _ in range(3):
            assert eng.step(finished)
        eng.submit(late, [5, 5])          # burst lands mid-run
        while eng.step(finished):
            pass
        got = {r.rid: list(r.out) for r in finished}
        want = dict(base)
        want.update({k + len(base): v for k, v in base_late.items()})
        assert got == want
        assert eng.counters["mid_decode_admissions"] > 0

    def test_chunked_speculative(self, small):
        """Draft windows ride the same ragged launch; tokens stay exact."""
        base, _ = _serve(small, "dequant-fp", "int8")
        chk, eng = _serve(small, "dequant-fp", "int8", chunked=True,
                          token_budget=16, speculate=4)
        assert chk == base
        st = eng.counters
        assert st["spec_drafted"] > 0

    def test_budget_floor_enforced(self, small):
        cfg, params, _ = small
        with pytest.raises(ValueError):
            E.ServeEngine(cfg, params, batch_size=3, max_len=T,
                          cache_layout="paged", page_size=PS,
                          chunked=True, token_budget=8, speculate=4)

    def test_chunked_requires_paged(self, small):
        cfg, params, _ = small
        with pytest.raises(ValueError):
            E.ServeEngine(cfg, params, batch_size=3, max_len=T,
                          chunked=True, token_budget=16)


class TestRefExecParity:
    """``REPRO_KERNEL_EXEC=ref`` (kernels/ops.py) routes the paged serving
    kernels to the pure-jnp oracle off-TPU — the execution the serving
    benchmark times. Served tokens must not depend on the execution backend:
    the oracle IS the kernels' semantic ground truth, so a token flip here
    means the two executions disagree beyond argmax resolution."""

    @pytest.mark.parametrize("path,kv", [("dequant-fp", "fp"),
                                         ("fused-int8", "int8")])
    def test_ref_exec_tokens_match_pallas(self, small, path, kv, monkeypatch):
        base, _ = _serve(small, path, kv)
        monkeypatch.setenv("REPRO_KERNEL_EXEC", "ref")
        got, _ = _serve(small, path, kv)
        chk, eng = _serve(small, path, kv, chunked=True, token_budget=12)
        assert got == base
        assert chk == base
        assert eng.counters["chunk_prefill_rows"] > 0

    def test_bad_exec_mode_rejected(self, small, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_EXEC", "mosaic")
        from repro.kernels import ops as kops
        with pytest.raises(AssertionError):
            kops._exec_mode()
