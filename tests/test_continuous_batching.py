"""Continuous batcher (DESIGN.md §3.6): scheduler parity, per-slot cur_len, and the
per-slot length masking in the attention kernels.

The central property: any mix of prompt lengths and ``max_new`` values served
through the slot-table batcher yields, per request, exactly the tokens of a
batch-size-1 greedy decode — on all three integer paths and both KV-cache modes.
Token-exactness (not approximate) holds because right-padding only adds rows/keys
whose contributions are exactly masked or exactly zero in the online softmax.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import blockwise_attention
from repro.models.quantize import quantize_tree
from repro.serving import engine as E

T = 32          # cache length for every engine in this module
LENS = [4, 7, 12, 9, 5]
MAX_NEW = [5, 3, 6, 2, 4]


@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    return cfg, params, qparams


def _mixed_prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=l).astype(np.int32) for l in LENS]


def _greedy_single(cfg, params, prompt, max_new, *, quant, path, kv):
    """Batch-size-1 greedy decode through the raw step builders (exact-length
    prefill, scalar cur_len — the pre-§3.6 seed-proven path)."""
    prefill = jax.jit(E.make_prefill_step(cfg, quant, path=path))
    decode = jax.jit(E.make_decode_step(cfg, quant, path=path))
    caches = M.init_cache(cfg, 1, T, dtype=jnp.float32, kv_int8=(kv == "int8"))
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None])}, caches)
    out = [int(jnp.argmax(logits[0, -1]))]
    cur = len(prompt)
    while len(out) < max_new and cur < T:
        cur += 1
        logits, caches = decode(params, jnp.asarray([[out[-1]]], jnp.int32),
                                caches, jnp.asarray(cur, jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


class TestSchedulerParity:
    """Mixed lengths + staggered max_new through the continuous batcher ==
    batch-size-1 greedy decode, token-exact, on every path × KV mode."""

    @pytest.mark.parametrize("path,kv", [("fake", "fp"), ("fake", "int8"),
                                         ("dequant-fp", "fp"),
                                         ("dequant-fp", "int8"),
                                         ("fused-int8", "fp"),
                                         ("fused-int8", "int8")])
    def test_mixed_workload_matches_bs1(self, small, path, kv):
        cfg, params, qparams = small
        if path == "fake":
            serve_params, quant = params, ql.W8A8_CROSSQUANT
        else:
            serve_params, quant = qparams, ql.W8A8_INT8
        prompts = _mixed_prompts(cfg)
        eng = E.ServeEngine(cfg, serve_params, batch_size=2, max_len=T,
                            quant=quant, path=path, kv_cache=kv)
        eng.submit(prompts, max_new=MAX_NEW)
        done = eng.run()
        # batch_size=2 < 5 requests: slots must have been refilled mid-decode
        assert eng.counters["mid_decode_admissions"] > 0
        assert [r.rid for r in done] == list(range(len(prompts)))
        for r in done:
            want = _greedy_single(cfg, serve_params, r.prompt, r.max_new,
                                  quant=quant, path=path, kv=kv)
            assert r.out == want, (path, kv, r.rid, r.out, want)

    def test_mid_decode_refill_order_independent(self, small):
        """Same workload, different batch sizes → identical per-request tokens
        (the slot table may schedule differently, the outputs must not)."""
        cfg, params, _ = small
        prompts = _mixed_prompts(cfg, seed=3)
        outs = {}
        for B in (1, 2, 4):
            eng = E.ServeEngine(cfg, params, batch_size=B, max_len=T)
            eng.submit(prompts, max_new=MAX_NEW)
            outs[B] = {r.rid: r.out for r in eng.run()}
        assert outs[1] == outs[2] == outs[4]


class TestPerSlotCurLen:
    def test_vector_cur_len_matches_scalar(self, small):
        """Aligned slots: (B,) cur_len vector ≡ the legacy scalar contract."""
        cfg, params, _ = small
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 1, cfg.vocab)
        outs = {}
        for tag, pre_len, dec_len in (
                ("scalar", jnp.asarray(8, jnp.int32), jnp.asarray(9, jnp.int32)),
                ("vector", jnp.full((2,), 8, jnp.int32), jnp.full((2,), 9, jnp.int32))):
            caches = M.init_cache(cfg, 2, T, dtype=jnp.float32)
            logits, ex = M.apply(params, {"tokens": toks}, cfg, mode="prefill",
                                 caches=caches, cur_len=pre_len)
            nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            logits_d, _ = M.apply(params, {"tokens": nxt}, cfg, mode="decode",
                                  caches=ex["caches"], cur_len=dec_len)
            outs[tag] = (np.asarray(logits), np.asarray(logits_d))
        np.testing.assert_array_equal(outs["scalar"][0], outs["vector"][0])
        np.testing.assert_array_equal(outs["scalar"][1], outs["vector"][1])

    def test_padded_prefill_gathers_per_slot_logits(self, small):
        """Right-padded mixed-length prefill returns each slot's own last-valid
        logits — identical to exact-length batch-size-1 prefills."""
        cfg, params, _ = small
        rng = np.random.default_rng(7)
        lens = [3, 8, 6]
        prompts = [rng.integers(1, cfg.vocab, size=l).astype(np.int32) for l in lens]
        S = max(lens)
        toks = np.zeros((len(lens), S), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
        caches = M.init_cache(cfg, len(lens), T, dtype=jnp.float32)
        logits, _ = M.apply(params, {"tokens": jnp.asarray(toks)}, cfg,
                            mode="prefill", caches=caches,
                            cur_len=jnp.asarray(lens, jnp.int32))
        for i, p in enumerate(prompts):
            c1 = M.init_cache(cfg, 1, T, dtype=jnp.float32)
            want, _ = M.apply(params, {"tokens": jnp.asarray(p[None])}, cfg,
                              mode="prefill", caches=c1,
                              cur_len=jnp.asarray(len(p), jnp.int32))
            np.testing.assert_array_equal(np.asarray(logits[i]),
                                          np.asarray(want[0]))

    def test_staggered_decode_scatter(self, small):
        """Slots at different lengths decode in one step: each token lands at its
        own cache position and attends only its own valid prefix."""
        cfg, params, _ = small
        rng = np.random.default_rng(11)
        lens = [4, 9]
        prompts = [rng.integers(1, cfg.vocab, size=l).astype(np.int32) for l in lens]
        toks = np.zeros((2, 9), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
        caches = M.init_cache(cfg, 2, T, dtype=jnp.float32)
        logits, ex = M.apply(params, {"tokens": jnp.asarray(toks)}, cfg,
                             mode="prefill", caches=caches,
                             cur_len=jnp.asarray(lens, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        cur = jnp.asarray([l + 1 for l in lens], jnp.int32)
        logits_d, _ = M.apply(params, {"tokens": nxt}, cfg, mode="decode",
                              caches=ex["caches"], cur_len=cur)
        for i, p in enumerate(prompts):
            c1 = M.init_cache(cfg, 1, T, dtype=jnp.float32)
            lg, e1 = M.apply(params, {"tokens": jnp.asarray(p[None])}, cfg,
                             mode="prefill", caches=c1,
                             cur_len=jnp.asarray(len(p), jnp.int32))
            n1 = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
            ld, _ = M.apply(params, {"tokens": n1}, cfg, mode="decode",
                            caches=e1["caches"],
                            cur_len=jnp.asarray(len(p) + 1, jnp.int32))
            np.testing.assert_array_equal(np.asarray(logits_d[i]),
                                          np.asarray(ld[0]))


class TestFlashKvLenMasking:
    def test_kernel_matches_oracle_per_slot(self):
        """Pallas flash kernel with a per-slot kv_len vector == the jnp blockwise
        oracle with the same kv_valid_len (right-padded prefill masking)."""
        from repro.kernels import ops as kops
        B, H, Hkv, S, D = 2, 4, 2, 128, 32
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (B, S, H, D), jnp.float32)
        k = jax.random.normal(k2, (B, S, Hkv, D), jnp.float32)
        v = jax.random.normal(k3, (B, S, Hkv, D), jnp.float32)
        kv_len = jnp.asarray([128, 70], jnp.int32)
        got = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), kv_len=kv_len, causal=True,
            bq=128, bk=128).transpose(0, 2, 1, 3)
        want = blockwise_attention(q, k, v, causal=True, window=None, softcap=None,
                                   kv_valid_len=kv_len, q_block=128, kv_block=128)
        # only compare rows the serving engine keeps: queries inside the valid len
        for b, L in enumerate([128, 70]):
            np.testing.assert_allclose(np.asarray(got[b, :L]),
                                       np.asarray(want[b, :L]),
                                       rtol=2e-5, atol=2e-5)

    def test_scalar_kv_len_broadcasts(self):
        from repro.kernels import ops as kops
        B, H, S, D = 1, 2, 128, 32
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q, k, v = (jax.random.normal(kk, (B, H, S, D), jnp.float32) for kk in ks)
        full = kops.flash_attention(q, k, v, causal=True, bq=128, bk=128)
        masked = kops.flash_attention(q, k, v, kv_len=jnp.asarray(S, jnp.int32),
                                      causal=True, bq=128, bk=128)
        np.testing.assert_allclose(np.asarray(full), np.asarray(masked),
                                   rtol=1e-6, atol=1e-6)


class TestSamplingAndEos:
    def test_eos_default_is_none_not_pad(self, small):
        """eos_id no longer defaults to the pad token: with no EOS every request
        runs its full token budget even if token 0 is sampled."""
        cfg, params, _ = small
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T)
        assert eng.eos is None
        eng.submit(_mixed_prompts(cfg), max_new=4)
        assert all(len(r.out) == 4 for r in eng.run())

    def test_eos_terminates(self, small):
        cfg, params, _ = small
        prompts = _mixed_prompts(cfg)
        ref = E.ServeEngine(cfg, params, batch_size=2, max_len=T)
        ref.submit(prompts, max_new=6)
        ref_out = {r.rid: r.out for r in ref.run()}
        eos = ref_out[0][2]        # a token request 0 is known to emit
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T, eos_id=eos)
        eng.submit(prompts, max_new=6)
        got = {r.rid: r.out for r in eng.run()}
        # every request truncates at its first eos occurrence (inclusive)
        for rid, toks in got.items():
            want = ref_out[rid]
            if eos in want:
                assert toks == want[: want.index(eos) + 1]
            else:
                assert toks == want

    def test_top_k_one_equals_greedy(self, small):
        """temperature>0 with top_k=1 collapses to greedy on-device sampling."""
        cfg, params, _ = small
        prompts = _mixed_prompts(cfg)
        greedy = E.ServeEngine(cfg, params, batch_size=2, max_len=T)
        greedy.submit(prompts, max_new=4)
        want = {r.rid: r.out for r in greedy.run()}
        sampled = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                                temperature=0.7, top_k=1, seed=123)
        sampled.submit(prompts, max_new=4)
        got = {r.rid: r.out for r in sampled.run()}
        assert got == want

    def test_temperature_sampling_stays_in_vocab(self, small):
        cfg, params, _ = small
        eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                            temperature=1.5, top_k=8, seed=7)
        eng.submit(_mixed_prompts(cfg), max_new=4)
        for r in eng.run():
            assert all(0 <= t < cfg.vocab for t in r.out)


class TestGroupedBaseline:
    def test_grouped_scheduler_matches_continuous_tokens(self, small):
        """The legacy grouped scheduler (benchmark baseline) serves the same
        mixed workload to the same per-request tokens — only the schedule (and
        the occupancy) differs."""
        cfg, params, _ = small
        prompts = _mixed_prompts(cfg, seed=5)
        outs = {}
        for scheduler in ("continuous", "grouped"):
            eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T,
                                scheduler=scheduler)
            eng.submit(prompts, max_new=MAX_NEW)
            outs[scheduler] = {r.rid: r.out for r in eng.run()}
            if scheduler == "grouped":
                assert eng.counters["mid_decode_admissions"] == 0
        assert outs["continuous"] == outs["grouped"]
