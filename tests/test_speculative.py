"""Speculative decoding (DESIGN.md §3.9): token-exactness harness.

Three layers of pinning, outermost first:

* **Engine parity** — ``ServeEngine(speculate=k)`` must emit, per request,
  exactly the tokens of the same engine with ``speculate=1``, on every
  path × KV-cache mode × cache layout combination. Greedy acceptance makes
  this exact by construction (a rejected draft position falls back to the
  verified argmax), so any drift is a masking/scatter bug in the verify path.
* **Mid-window retirement** — a request hitting EOS / ``max_new`` / cache-full
  inside a draft window must retire at exactly the token sequential decode
  would, and the rejected tail must not leak into pages a new admission will
  reuse (the engine asserts its page mappings are clean at that point).
* **Kernel vs oracle** — the (B, W·G, ps)-row verify kernel against the dense
  gather oracle over random injective page tables, including the ``q_win == 1``
  degenerate (bitwise the decode kernel) and all-sentinel table rows.

The drafter is host-side numpy with no exactness obligations (a wrong draft
only costs acceptance rate), so its tests are plain unit checks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import qlinear as ql
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.flash_attention import paged_decode_attention_pallas
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.serving import engine as E
from repro.serving.drafter import NGramDrafter

T = 32           # cache length for every engine in this module
PS = 8           # page size for paged engines

COMBOS = [("fake", "fp"), ("fake", "int8"),
          ("dequant-fp", "fp"), ("dequant-fp", "int8"),
          ("fused-int8", "fp"), ("fused-int8", "int8")]


@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get("starcoder2-7b", smoke=True), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    return cfg, params, qparams


def _spec_prompts(cfg, seed=0):
    """Drafter-friendly mix: repeated motifs (n-gram lookups hit, windows fill)
    plus plain random prompts (lookups miss, slots degrade to 1-token steps).
    Lengths are staggered so mid-decode admissions land inside other slots'
    draft windows."""
    rng = np.random.default_rng(seed)
    motif = rng.integers(1, cfg.vocab, size=4).astype(np.int32)
    return [np.tile(motif, 3),                                   # 12, periodic
            rng.integers(1, cfg.vocab, size=7).astype(np.int32),  # random
            np.tile(motif[:3], 2),                               # 6, periodic
            rng.integers(1, cfg.vocab, size=9).astype(np.int32)]  # random


MAX_NEW = [6, 4, 7, 3]


def _serve(cfg, params, prompts, max_new, *, speculate, eos_id=None, **kw):
    eng = E.ServeEngine(cfg, params, batch_size=2, max_len=T, eos_id=eos_id,
                        speculate=speculate, **kw)
    eng.submit(prompts, max_new=max_new)
    done = eng.run()
    return {r.rid: r.out for r in done}, eng


class TestEngineParity:
    """speculate=4 ≡ speculate=1, token-exact, on every path × kv × layout."""

    @pytest.mark.parametrize("layout", ["dense", "paged"])
    @pytest.mark.parametrize("path,kv", COMBOS)
    def test_matches_nonspeculative(self, small, path, kv, layout):
        cfg, params, qparams = small
        if path == "fake":
            serve_params, quant = params, ql.W8A8_CROSSQUANT
        else:
            serve_params, quant = qparams, ql.W8A8_INT8
        kw = dict(quant=quant, path=path, kv_cache=kv)
        if layout == "paged":
            kw.update(cache_layout="paged", page_size=PS)
        prompts = _spec_prompts(cfg)
        base, _ = _serve(cfg, serve_params, prompts, MAX_NEW, speculate=1, **kw)
        spec, eng = _serve(cfg, serve_params, prompts, MAX_NEW, speculate=4, **kw)
        assert spec == base, (path, kv, layout)
        # the workload must actually have exercised multi-token windows
        assert eng.counters["spec_steps"] > 0
        assert eng.counters["spec_drafted"] > 0

    def test_speculation_accepts_on_periodic_prompts(self, small):
        """Motif prompts through a greedy random-init model are repetitive
        enough that the n-gram drafter must land accepted tokens — i.e. the
        harness genuinely tests multi-token acceptance, not just k=1 fallback."""
        cfg, params, _ = small
        spec, eng = _serve(cfg, params, _spec_prompts(cfg), MAX_NEW, speculate=4)
        assert eng.counters["spec_accepted"] > 0
        assert eng.accept_rate() > 0.0
        assert eng.tokens_per_step() > 1.0

    def test_window_sizes_agree(self, small):
        """Every window size k (incl. k=1 == plain engine) yields the same
        per-request tokens."""
        cfg, params, _ = small
        prompts = _spec_prompts(cfg, seed=5)
        outs = {k: _serve(cfg, params, prompts, MAX_NEW, speculate=k)[0]
                for k in (1, 2, 4)}
        assert outs[1] == outs[2] == outs[4]

    def test_window_longer_than_remaining_budget(self, small):
        """speculate far beyond max_new and the cache budget: the engine must
        clamp the draft so no request overruns max_new or the cache."""
        cfg, params, _ = small
        prompts = [np.tile(np.arange(1, 5, dtype=np.int32), 6),   # len 24, T=32
                   np.tile(np.arange(5, 8, dtype=np.int32), 2)]
        base, _ = _serve(cfg, params, prompts, [10, 2], speculate=1)
        spec, _ = _serve(cfg, params, prompts, [10, 2], speculate=8)
        assert spec == base
        assert all(len(v) <= m for v, m in zip(spec.values(), [10, 2]))

    def test_rejects_sampling_and_static_scheduler(self, small):
        cfg, params, _ = small
        with pytest.raises(ValueError, match="greedy"):
            E.ServeEngine(cfg, params, batch_size=2, max_len=T, speculate=4,
                          temperature=0.7)
        with pytest.raises(ValueError, match="continuous"):
            E.ServeEngine(cfg, params, batch_size=2, max_len=T, speculate=4,
                          scheduler="grouped")


class TestMidWindowRetirement:
    """A request finishing inside a draft window (EOS / max_new / cache-full)
    retires at exactly the sequential-decode token; the rejected tail never
    reaches its pages (ServeEngine asserts the mappings are clean — an
    AssertionError here IS the regression)."""

    @pytest.mark.parametrize("layout", ["dense", "paged"])
    def test_eos_inside_window(self, small, layout):
        cfg, params, _ = small
        kw = dict(cache_layout="paged", page_size=PS) if layout == "paged" else {}
        prompts = _spec_prompts(cfg, seed=7)
        # pick an EOS from a clean run: the 3rd token of request 0 guarantees
        # the stop lands mid-stream — and, with speculate=4 windows flowing,
        # mid-window for at least one request
        base, _ = _serve(cfg, params, prompts, MAX_NEW, speculate=1, **kw)
        eos = base[0][2]
        base_eos, _ = _serve(cfg, params, prompts, MAX_NEW, speculate=1,
                             eos_id=eos, **kw)
        spec_eos, eng = _serve(cfg, params, prompts, MAX_NEW, speculate=4,
                               eos_id=eos, **kw)
        assert spec_eos == base_eos
        assert any(v and v[-1] == eos for v in spec_eos.values())

    def test_freed_slot_reuse_after_mid_window_eos(self, small):
        """batch_size < n_requests with an EOS retire mid-window: the admission
        into the freed slot must decode as if the slot were fresh."""
        cfg, params, _ = small
        prompts = _spec_prompts(cfg, seed=11)
        base, _ = _serve(cfg, params, prompts, MAX_NEW, speculate=1,
                         cache_layout="paged", page_size=PS)
        eos = base[0][1]
        want, _ = _serve(cfg, params, prompts, MAX_NEW, speculate=1,
                         eos_id=eos, cache_layout="paged", page_size=PS)
        got, eng = _serve(cfg, params, prompts, MAX_NEW, speculate=4,
                          eos_id=eos, cache_layout="paged", page_size=PS)
        assert got == want
        assert eng.counters["mid_decode_admissions"] > 0


class TestDrafter:
    def test_ngram_hit_proposes_continuation(self):
        d = NGramDrafter(max_ngram=3)
        hist = np.array([1, 2, 3, 9, 8, 1, 2, 3], np.int32)
        np.testing.assert_array_equal(d.draft(hist, 3), [9, 8, 1])

    def test_prefers_longest_suffix_match(self):
        d = NGramDrafter(max_ngram=3)
        # suffix [2,3] occurs earlier (→ 7); plain [3] occurs even earlier (→ 5)
        hist = np.array([3, 5, 2, 3, 7, 2, 3], np.int32)
        np.testing.assert_array_equal(d.draft(hist, 2), [7, 2])

    def test_most_recent_occurrence_wins(self):
        d = NGramDrafter(max_ngram=1)
        hist = np.array([4, 10, 4, 20, 4], np.int32)
        np.testing.assert_array_equal(d.draft(hist, 1), [20])

    def test_miss_returns_empty(self):
        d = NGramDrafter()
        assert d.draft(np.array([1, 2, 3, 4], np.int32), 4).size == 0

    def test_degenerate_inputs(self):
        d = NGramDrafter()
        assert d.draft(np.zeros(0, np.int32), 3).size == 0      # empty history
        assert d.draft(np.array([7], np.int32), 3).size == 0    # pending only
        assert d.draft(np.array([1, 2, 1], np.int32), 0).size == 0   # n == 0

    def test_window_clamped_to_n(self):
        """A long continuation is truncated to the requested budget — the
        engine passes ``n = min(k-1, cache room, max_new room)``."""
        d = NGramDrafter(max_ngram=2)
        hist = np.array([5, 6, 1, 2, 3, 4, 5, 6], np.int32)
        got = d.draft(hist, 2)
        assert got.size <= 2
        np.testing.assert_array_equal(got, [1, 2])

    def test_continuation_shorter_than_budget(self):
        d = NGramDrafter(max_ngram=2)
        hist = np.array([1, 2, 9, 1, 2], np.int32)
        np.testing.assert_array_equal(d.draft(hist, 5), [9, 1, 2])


def _rand_table(rng, B, P, ps, maxP):
    """Random injective tables with sentinel tails past each row's pages."""
    tab = np.full((B, maxP), P, np.int32)
    kvl = np.zeros(B, np.int32)
    perm = rng.permutation(P)
    off = 0
    for b in range(B):
        n = int(rng.integers(1, min(maxP, P - off) + 1))
        tab[b, :n] = perm[off: off + n]
        off += n
        kvl[b] = int(rng.integers((n - 1) * ps + 1, n * ps + 1))
    return jnp.asarray(tab), jnp.asarray(kvl)


def _rand_pools(rng, P, ps, Hkv, D, kv_int8):
    """(k_pages, v_pages, k_scale_pages|None, v_scale_pages|None)."""
    if not kv_int8:
        return (jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32),
                jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32),
                None, None)
    return (jnp.asarray(rng.integers(-127, 128, (P, ps, Hkv, D)), jnp.int8),
            jnp.asarray(rng.integers(-127, 128, (P, ps, Hkv, D)), jnp.int8),
            jnp.asarray(0.002 + 0.05 * rng.random((P, ps, Hkv, 1)), jnp.float32),
            jnp.asarray(0.002 + 0.05 * rng.random((P, ps, Hkv, 1)), jnp.float32))


def _rand_qlen(rng, kvl, W):
    """Valid window rows per slot: 1 ≤ q_len ≤ min(W, kv_len)."""
    hi = np.minimum(np.asarray(kvl), W)
    return jnp.asarray([int(rng.integers(1, h + 1)) for h in hi], jnp.int32)


class TestVerifyKernelVsOracle:
    """(B, W) verify windows through the pallas kernel vs the gather oracle.

    Rows ≥ q_len are garbage-but-finite by contract, so comparisons slice to
    the valid window rows per slot."""

    @pytest.mark.parametrize("kv_int8", [False, True])
    @pytest.mark.parametrize("W", [1, 2, 4])
    @pytest.mark.parametrize("B,Hkv,G,D,P,ps,maxP",
                             [(2, 2, 2, 16, 8, 8, 4),
                              (1, 1, 4, 32, 4, 16, 2),
                              (3, 2, 1, 64, 16, 4, 8)])
    def test_window_sweep(self, B, Hkv, G, D, P, ps, maxP, W, kv_int8):
        rng = np.random.default_rng(100 * W + B + 7 * kv_int8)
        kp, vp, ksp, vsp = _rand_pools(rng, P, ps, Hkv, D, kv_int8)
        tab, kvl = _rand_table(rng, B, P, ps, maxP)
        qln = _rand_qlen(rng, kvl, W)
        q = jnp.asarray(rng.standard_normal((B, W, Hkv * G, D)), jnp.float32)
        got = kops.paged_verify_attention(q, kp, vp, tab, kvl, qln,
                                          k_scale_pages=ksp, v_scale_pages=vsp)
        qg = jnp.transpose(q.reshape(B, W, Hkv, G, D), (0, 2, 1, 3, 4))
        ref = jnp.transpose(
            kref.paged_verify_attention_ref(qg, kp, vp, tab, kvl, qln,
                                            k_scale_pages=ksp,
                                            v_scale_pages=vsp),
            (0, 2, 1, 3, 4)).reshape(B, W, Hkv * G, D)
        for b in range(B):
            n = int(qln[b])
            np.testing.assert_allclose(got[b, :n], ref[b, :n],
                                       rtol=2e-5, atol=2e-5)
        assert np.isfinite(np.asarray(got)).all()

    @pytest.mark.parametrize("window,softcap", [(5, None), (None, 30.0)])
    def test_window_and_softcap(self, window, softcap):
        B, Hkv, G, D, P, ps, maxP, W = 2, 2, 2, 16, 8, 8, 4, 3
        rng = np.random.default_rng(31)
        kp, vp, ksp, vsp = _rand_pools(rng, P, ps, Hkv, D, True)
        tab, kvl = _rand_table(rng, B, P, ps, maxP)
        qln = _rand_qlen(rng, kvl, W)
        q = jnp.asarray(rng.standard_normal((B, W, Hkv * G, D)), jnp.float32)
        got = kops.paged_verify_attention(q, kp, vp, tab, kvl, qln,
                                          k_scale_pages=ksp, v_scale_pages=vsp,
                                          window=window, softcap=softcap)
        qg = jnp.transpose(q.reshape(B, W, Hkv, G, D), (0, 2, 1, 3, 4))
        ref = jnp.transpose(
            kref.paged_verify_attention_ref(qg, kp, vp, tab, kvl, qln,
                                            k_scale_pages=ksp, v_scale_pages=vsp,
                                            window=window, softcap=softcap),
            (0, 2, 1, 3, 4)).reshape(B, W, Hkv * G, D)
        for b in range(B):
            n = int(qln[b])
            np.testing.assert_allclose(got[b, :n], ref[b, :n],
                                       rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("kv_int8", [False, True])
    def test_w1_bitwise_equals_decode_kernel(self, kv_int8):
        """q_win=1 must be *bitwise* the decode kernel — the engine's
        speculate=1 path and all existing decode parity results carry over."""
        B, Hkv, G, D, P, ps, maxP = 2, 2, 2, 16, 8, 8, 4
        rng = np.random.default_rng(3)
        kp, vp, ksp, vsp = _rand_pools(rng, P, ps, Hkv, D, kv_int8)
        tab, kvl = _rand_table(rng, B, P, ps, maxP)
        q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
        dec = kops.paged_decode_attention(q, kp, vp, tab, kvl,
                                          k_scale_pages=ksp, v_scale_pages=vsp)
        ver = kops.paged_verify_attention(q, kp, vp, tab, kvl,
                                          jnp.ones(B, jnp.int32),
                                          k_scale_pages=ksp, v_scale_pages=vsp)
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(ver))

    def test_first_window_row_bitwise_equals_decode(self):
        """With q_len=1 in a W>1 launch, row 0 attends exactly the decode
        positions — bitwise equal to the decode kernel's output."""
        B, Hkv, G, D, P, ps, maxP, W = 2, 2, 2, 16, 8, 8, 4, 3
        rng = np.random.default_rng(4)
        kp, vp, _, _ = _rand_pools(rng, P, ps, Hkv, D, False)
        tab, kvl = _rand_table(rng, B, P, ps, maxP)
        q1 = jnp.asarray(rng.standard_normal((B, Hkv, G, D)), jnp.float32)
        qw = jnp.concatenate(
            [q1.reshape(B, Hkv, G, D)[:, :, None],
             jnp.asarray(rng.standard_normal((B, Hkv, W - 1, G, D)),
                         jnp.float32)], axis=2).reshape(B, Hkv, W * G, D)
        dec = paged_decode_attention_pallas(q1, kp, vp, tab, kvl,
                                            interpret=True)
        ver = paged_decode_attention_pallas(qw, kp, vp, tab, kvl, q_win=W,
                                            q_len=jnp.ones(B, jnp.int32),
                                            interpret=True)
        np.testing.assert_array_equal(
            np.asarray(dec), np.asarray(ver.reshape(B, Hkv, W, G, D)[:, :, 0]))

    def test_all_sentinel_row_is_finite(self):
        """A slot whose table row is all sentinel (freshly admitted, pages not
        yet mapped) must produce finite output — the engine discards it, but a
        NaN would poison the jit-donated cache buffers."""
        B, Hkv, G, D, P, ps, maxP, W = 2, 2, 2, 16, 8, 8, 4, 4
        rng = np.random.default_rng(5)
        kp, vp, ksp, vsp = _rand_pools(rng, P, ps, Hkv, D, True)
        tab, kvl = _rand_table(rng, B, P, ps, maxP)
        tab = tab.at[1].set(P)                  # row 1: every page sentinel
        kvl = kvl.at[1].set(1)
        qln = jnp.asarray([W, 1], jnp.int32)
        q = jnp.asarray(rng.standard_normal((B, W, Hkv * G, D)), jnp.float32)
        out = kops.paged_verify_attention(q, kp, vp, tab, kvl, qln,
                                          k_scale_pages=ksp, v_scale_pages=vsp)
        assert np.isfinite(np.asarray(out)).all()
        # row 0 untouched by row 1's sentinels
        qg = jnp.transpose(q.reshape(B, W, Hkv, G, D), (0, 2, 1, 3, 4))
        ref = jnp.transpose(
            kref.paged_verify_attention_ref(qg, kp, vp, tab, kvl, qln,
                                            k_scale_pages=ksp,
                                            v_scale_pages=vsp),
            (0, 2, 1, 3, 4)).reshape(B, W, Hkv * G, D)
        np.testing.assert_allclose(out[0], ref[0], rtol=2e-5, atol=2e-5)
