"""Model-zoo tests: per-arch smoke (assignment requirement), prefill/decode parity,
arch-specific features (softcap, windows, shared blocks, frontends)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_archs, cell_supported, get
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext, blockwise_attention
from repro.kernels.ref import flash_attention_ref


def _batch(cfg, key, B=2, S=32):
    if cfg.frontend == "audio_stub":
        return {"frames": jax.random.normal(key, (B, S, cfg.frontend_dim),
                                            jnp.bfloat16),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)
    return batch


class TestArchSmoke:
    """One reduced-config forward/train step per assigned architecture: output shapes
    + no NaNs (the per-arch smoke tests required by the assignment)."""

    @pytest.mark.parametrize("arch", all_archs())
    def test_forward_and_loss(self, arch, key):
        cfg = get(arch, smoke=True)
        params = M.init_params(key, cfg)
        batch = _batch(cfg, key)
        logits, extras = M.apply(params, batch, cfg, mode="train")
        B = 2
        S = 32
        assert logits.shape == (B, S, cfg.vocab_padded)
        assert not bool(jnp.any(jnp.isnan(logits)))
        loss, metrics = M.loss_fn(params, batch, cfg, remat=False)
        assert bool(jnp.isfinite(loss))
        assert float(loss) > 0

    @pytest.mark.parametrize("arch", all_archs())
    def test_grad_step_finite(self, arch, key):
        cfg = get(arch, smoke=True)
        params = M.init_params(key, cfg)
        batch = _batch(cfg, key)
        (loss, _), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg, remat=True), has_aux=True)(params)
        leaves = jax.tree_util.tree_leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)

    @pytest.mark.parametrize("arch", ["deepseek-coder-33b", "gemma2-9b",
                                      "mamba2-130m", "zamba2-1.2b",
                                      "granite-moe-3b-a800m"])
    def test_quantized_forward(self, arch, key):
        cfg = get(arch, smoke=True)
        params = M.init_params(key, cfg)
        batch = _batch(cfg, key)
        for qc in (ql.W8A8_CROSSQUANT, ql.W4A8_G128):
            logits, _ = M.apply(params, batch, cfg, ctx=QuantContext(qc), mode="train")
            assert not bool(jnp.any(jnp.isnan(logits)))


class TestPrefillDecodeParity:
    """decode(prefill(x)) must equal the train-mode forward at the same positions.
    MoE archs use a generous capacity factor to exclude capacity-drop differences."""

    @pytest.mark.parametrize("arch", ["deepseek-coder-33b", "gemma2-9b",
                                      "starcoder2-7b", "mamba2-130m", "zamba2-1.2b",
                                      "nemotron-4-15b"])
    def test_parity(self, arch, key):
        cfg = get(arch, smoke=True)
        params = M.init_params(key, cfg)
        B, S, T = 2, 16, 32
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        caches = M.init_cache(cfg, B, T, dtype=jnp.float32)
        logits_p, ex = M.apply(params, {"tokens": toks}, cfg, mode="prefill",
                               caches=caches, cur_len=jnp.asarray(S, jnp.int32))
        nxt = jnp.argmax(logits_p[:, -1], -1)[:, None].astype(jnp.int32)
        logits_d, _ = M.apply(params, {"tokens": nxt}, cfg, mode="decode",
                              caches=ex["caches"], cur_len=jnp.asarray(S + 1, jnp.int32))
        full = jnp.concatenate([toks, nxt], axis=1)
        logits_f, _ = M.apply(params, {"tokens": full}, cfg, mode="train")
        # bf16 residual streams: one-ulp differences at logit magnitude ~4 are 0.06.
        np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                                   np.asarray(logits_f[:, S - 1]), atol=0.1)
        np.testing.assert_allclose(np.asarray(logits_d[:, -1]),
                                   np.asarray(logits_f[:, S]), atol=0.1)

    def test_moe_parity_high_capacity(self, key):
        cfg = dataclasses.replace(get("granite-moe-3b-a800m", smoke=True),
                                  capacity_factor=8.0)
        params = M.init_params(key, cfg)
        B, S, T = 2, 16, 32
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        caches = M.init_cache(cfg, B, T, dtype=jnp.float32)
        logits_p, ex = M.apply(params, {"tokens": toks}, cfg, mode="prefill",
                               caches=caches, cur_len=jnp.asarray(S, jnp.int32))
        nxt = jnp.argmax(logits_p[:, -1], -1)[:, None].astype(jnp.int32)
        logits_d, _ = M.apply(params, {"tokens": nxt}, cfg, mode="decode",
                              caches=ex["caches"], cur_len=jnp.asarray(S + 1, jnp.int32))
        full = jnp.concatenate([toks, nxt], axis=1)
        logits_f, _ = M.apply(params, {"tokens": full}, cfg, mode="train")
        np.testing.assert_allclose(np.asarray(logits_d[:, -1]),
                                   np.asarray(logits_f[:, S]), atol=0.05)


class TestBlockwiseAttention:
    """The jnp flash-attention oracle itself, against plain softmax attention."""

    @pytest.mark.parametrize("S,H,Hkv,D", [(32, 4, 2, 16), (65, 8, 8, 8),
                                           (128, 4, 1, 32)])
    def test_matches_plain_attention(self, S, H, Hkv, D, key):
        B = 2
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        out = blockwise_attention(q, k, v, causal=True, window=None, softcap=None,
                                  q_block=16, kv_block=16)
        kr = jnp.repeat(k, H // Hkv, axis=2)
        vr = jnp.repeat(v, H // Hkv, axis=2)
        want = flash_attention_ref(q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3),
                                   vr.transpose(0, 2, 1, 3), causal=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(want.transpose(0, 2, 1, 3)),
                                   atol=2e-3)

    def test_sliding_window_masks_far_tokens(self, key):
        B, S, H, D, W = 1, 64, 2, 8, 8
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, H, D))
        v = jax.random.normal(ks[2], (B, S, H, D))
        out_w = blockwise_attention(q, k, v, causal=True, window=W, softcap=None,
                                    q_block=16, kv_block=16)
        # Truncating the KV to the window for the last query must give the same output.
        out_trunc = blockwise_attention(
            q[:, -1:], k[:, S - W:], v[:, S - W:], causal=False, window=None,
            softcap=None, q_block=16, kv_block=16)
        np.testing.assert_allclose(np.asarray(out_w[:, -1]),
                                   np.asarray(out_trunc[:, 0]), atol=2e-3)

    def test_softcap_applied(self, key):
        B, S, H, D = 1, 16, 1, 8
        q = jax.random.normal(key, (B, S, H, D)) * 10
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D)) * 10
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
        out_cap = blockwise_attention(q, k, v, causal=True, window=None, softcap=5.0,
                                      q_block=16, kv_block=16)
        out_raw = blockwise_attention(q, k, v, causal=True, window=None, softcap=None,
                                      q_block=16, kv_block=16)
        assert not np.allclose(np.asarray(out_cap), np.asarray(out_raw), atol=1e-3)


class TestCellSupport:
    def test_40_cells_partition(self):
        """10 archs × 4 shapes = 40 cells; 31 live + 9 documented skips."""
        live = skip = 0
        for arch in all_archs():
            cfg = get(arch)
            for shape in SHAPES.values():
                ok, why = cell_supported(cfg, shape)
                live += ok
                skip += not ok
                if not ok:
                    assert why
        assert live + skip == 40
        assert live == 31 and skip == 9

    def test_encoder_only_skips_decode(self):
        cfg = get("hubert-xlarge")
        ok, why = cell_supported(cfg, SHAPES["decode_32k"])
        assert not ok and "encoder" in why

    def test_long_context_only_subquadratic(self):
        assert cell_supported(get("mamba2-130m"), SHAPES["long_500k"])[0]
        assert cell_supported(get("zamba2-1.2b"), SHAPES["long_500k"])[0]
        assert not cell_supported(get("deepseek-coder-33b"), SHAPES["long_500k"])[0]
