"""ShapeDtypeStruct stand-ins for every model input of every (arch × shape) cell —
weak-type-correct, shardable, zero device allocation. The dry-run lowers against these.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.training import optimizer as opt_lib

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """Batch inputs for train/prefill. Decode tokens are (B, 1)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": SDS((B, 1), jnp.int32)}
        return batch
    if cfg.frontend == "audio_stub":
        batch = {"frames": SDS((B, S, cfg.frontend_dim), jnp.bfloat16)}
        if shape.kind == "train":
            batch["labels"] = SDS((B, S), jnp.int32)
        return batch
    batch = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = SDS((B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)
    return batch


def param_specs(cfg: ModelConfig, *, dtype=jnp.float32,
                quant: Optional[ql.QuantConfig] = None):
    """Abstract params (and optionally the prepared-integer tree) via eval_shape."""
    key = jax.random.PRNGKey(0)
    sds = jax.eval_shape(lambda: M.init_params(key, cfg, dtype=dtype))
    if quant is not None and quant.mode == "int8":
        sds = jax.eval_shape(functools.partial(quantize_tree, cfg=quant), sds)
    return sds


def opt_specs(params_sds):
    return jax.eval_shape(opt_lib.init, params_sds)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    B = shape.global_batch
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, B, shape.seq_len, dtype=dtype))
