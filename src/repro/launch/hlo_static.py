"""Trip-count-aware static analysis of optimized HLO text.

``compiled.cost_analysis()`` visits a ``while`` body ONCE — a scan-over-layers
program under-reports FLOPs and collective bytes by the trip count (62× on
deepseek-33b). This module parses the optimized HLO text into computations, resolves
the call graph (while bodies, fusions, calls) with loop-trip multipliers, and
accumulates:

  * dot FLOPs, split into fp (bf16/f32 operands) and int8 (s8 operands) — the MXU
    runs int8 at 2× bf16 peak, so the roofline compute term weights them separately;
  * per-kind collective operand bytes (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute), each scaled by its enclosing loops' trips.

Trip counts come from the ``backend_config={"known_trip_count":{"n":"62"}}``
annotation XLA attaches to statically-counted while loops (JAX scans), with a
condition-constant fallback. Unknown trips multiply by 1 (conservative).

This is structural analysis of the partitioned per-device program: dividing by
per-chip peaks gives per-chip step time directly.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_RESULT = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\w+)\[([0-9,]*)\]")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
# Operands may be bare (`dot(%a, %b)`) or carry their full type
# (`dot(f32[32,128]{1,0} %a, ...)`) depending on the XLA printer version.
_DOT_OPERANDS = re.compile(
    r"\bdot\(\s*(?:[\w\[\]{},]+\s+)?%?([\w\.\-]+)\s*,\s*"
    r"(?:[\w\[\]{},]+\s+)?%?([\w\.\-]+)\s*\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONVERT_SRC = re.compile(r"\bconvert\(\s*(?:(\w+)\[[0-9,]*\]\S*\s+)?%?([\w\.\-]+)")
_WHILE = re.compile(r"\bwhile\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COND_CONST = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_COLL_OP = re.compile(
    r"=\s*((?:\([^)]*\)|\w+\[[0-9,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(([^)]*)\)")
_OP_KIND = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([\w\-\.]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")

# View-like / control ops that move no HBM bytes of their own.
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "after-all", "custom-call"}


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d] if s else []


def _prod(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        total += _prod(_dims(dims)) * _DTYPE_BYTES[dt]
    return total


class Module:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for raw in hlo_text.splitlines():
            line = raw.strip()
            if cur is None:
                if line.endswith("{") and "->" in line:
                    m = _COMP_HEADER.match(line)
                    if m:
                        cur = m.group(2)
                        self.comps[cur] = []
                        if m.group(1):
                            self.entry = cur
                continue
            if line == "}":
                cur = None
                continue
            if line:
                self.comps[cur].append(line)

    def _symbols(self, name: str) -> Dict[str, Tuple[str, List[int]]]:
        table: Dict[str, Tuple[str, List[int]]] = {}
        for line in self.comps.get(name, ()):
            m = _RESULT.match(line)
            if m:
                table[m.group(1)] = (m.group(2), _dims(m.group(3)))
        return table

    def _local(self, name: str) -> Dict:
        flops_fp = flops_int8 = 0.0
        hbm_bytes = 0.0
        unresolved_dots = 0
        coll = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVES}
        children: List[Tuple[str, float]] = []
        table = self._symbols(name)
        # Integer dots reach the MXU/accumulator as widening converts (s8 -> s32
        # feeding the dot). Track each convert's source dtype so the dot is
        # classified by the *storage* dtype of its operands, not the accumulator.
        narrow: Dict[str, str] = {}
        for line in self.comps.get(name, ()):
            mr = _RESULT.match(line)
            if mr and " convert(" in line:
                mc = _CONVERT_SRC.search(line)
                if mc:
                    src_dt = mc.group(1) or (table.get(mc.group(2)) or ("",))[0]
                    if src_dt:
                        narrow[mr.group(1)] = src_dt
        for line in self.comps.get(name, ()):
            hbm_bytes += self._op_bytes(line, table)
            mr = _RESULT.match(line)
            if mr and " dot(" in line:
                # A dot whose operands don't parse (printer-format drift) must
                # land in unresolved_dots, never be silently dropped from flops.
                md = _DOT_OPERANDS.search(line)
                lhs = table.get(md.group(1)) if md else None
                mc = _CONTRACT.search(line)
                if md is not None and lhs is not None and mc is not None:
                    out = _prod(_dims(mr.group(3)))
                    contract = _prod([lhs[1][i] for i in _dims(mc.group(1))
                                      if i < len(lhs[1])])
                    f = 2.0 * out * contract
                    if narrow.get(md.group(1), lhs[0]) in ("s8", "u8", "s4", "u4"):
                        flops_int8 += f
                    else:
                        flops_fp += f
                else:
                    unresolved_dots += 1
            mcoll = _COLL_OP.search(line)
            if mcoll and mcoll.group(3) != "-done":
                kind = mcoll.group(2)
                b = _shape_bytes(mcoll.group(4)) or _shape_bytes(mcoll.group(1))
                coll[kind]["count"] += 1
                coll[kind]["bytes"] += b
            mw = _WHILE.search(line)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                mt = _TRIP.search(line)
                trip = int(mt.group(1)) if mt else self._trip_from_cond(cond)
                children.append((body, float(trip), "while"))
                children.append((cond, float(trip), "while"))
                continue
            for callee in _CALLS.findall(line):
                # fusion/call internals contribute FLOPs and collectives, but no
                # HBM bytes of their own — the fusion op's operands/result already
                # account for its HBM traffic.
                children.append((callee, 1.0, "call"))
        return {"flops_fp": flops_fp, "flops_int8": flops_int8, "coll": coll,
                "children": children, "unresolved_dots": unresolved_dots,
                "hbm_bytes": hbm_bytes}

    def _op_bytes(self, line: str, table) -> float:
        """HBM-traffic model for one top-level op (view/control ops are free).

        Slice-access rules keep stacked buffers honest: a dynamic-slice of the
        62-layer weight stack reads one layer per trip, not the whole stack, and a
        dynamic-update-slice writes its update slice in place (XLA aliases the
        buffer). Everything else reads its operands and writes its result once.
        """
        mk = _OP_KIND.search(line)
        if not mk or mk.group(1) in _FREE_OPS:
            return 0.0
        kind = mk.group(1)
        head = line.split(" metadata=")[0]
        mr0 = _RESULT.match(line)
        res_name = mr0.group(1) if mr0 else None
        eq = head.find("=")
        kind_pos = head.find(" " + kind + "(")
        res_bytes = _shape_bytes(head[eq + 1:kind_pos]) if 0 <= eq < kind_pos else 0
        operands: List[int] = []
        paren = head.find("(", kind_pos if kind_pos > 0 else 0)
        if paren >= 0:
            for op in _OPERAND.findall(head[paren:]):
                if op == res_name:
                    continue
                ent = table.get(op)
                if ent is not None:
                    operands.append(_prod(ent[1]) * _DTYPE_BYTES.get(ent[0], 0))

        if kind == "convert" or (kind == "fusion" and res_name
                                 and res_name.startswith("wrapped_convert")):
            # Standalone same-shape dtype casts are CPU float-normalization
            # artifacts (XLA-CPU has no native bf16 compute); on TPU casts fuse
            # into consumers and move no bytes of their own.
            if len(operands) == 1:
                return 0.0
        if kind in ("dynamic-slice",):
            return 2.0 * res_bytes
        if kind in ("gather",):
            return 2.0 * res_bytes + (min(operands) if operands else 0)
        if kind in ("dynamic-update-slice", "scatter"):
            # in-place: read + write of the update slice (smallest real operand)
            small = min((o for o in operands if o > 0), default=res_bytes)
            return 2.0 * small
        if kind == "fusion":
            callee = _CALLS.search(line)
            if callee and self._contains_dus(callee.group(1)):
                # In-place buffer update (KV cache write, scan ys stacking): the
                # aliased buffer costs nothing; traffic = the update slice (r+w)
                # plus the other (small) fusion inputs.
                upd = self._dus_update_bytes(callee.group(1))
                others = sorted(operands)[:-1] if operands else []
                return 2.0 * (upd if upd else (min(operands) if operands else 0)) \
                    + float(sum(others))
        # generic op / fusion: result write + operand reads; clamp each operand to
        # 4× the result (larger operands of small-output ops are slice accesses)
        clamp = 4 * max(res_bytes, 1)
        return float(res_bytes + sum(min(o, clamp) for o in operands))

    def _contains_dus(self, comp: str) -> bool:
        return any(" dynamic-update-slice(" in line or
                   line.startswith("ROOT %dynamic-update-slice")
                   for line in self.comps.get(comp, ()))

    def _dus_update_bytes(self, comp: str) -> int:
        table = self._symbols(comp)
        for line in self.comps.get(comp, ()):
            if " dynamic-update-slice(" in line:
                names = _OPERAND.findall(line.split("dynamic-update-slice(")[1])
                sizes = []
                for op in names:
                    ent = table.get(op)
                    if ent is not None and ent[1]:
                        sizes.append(_prod(ent[1]) * _DTYPE_BYTES.get(ent[0], 0))
                if len(sizes) >= 2:
                    return sorted(sizes)[-2]     # update = second-largest operand
        return 0

    def _trip_from_cond(self, cond_name: str) -> int:
        for line in self.comps.get(cond_name, ()):
            m = _COND_CONST.search(line)
            if m:
                return int(m.group(1))
        return 1

    def analyze(self) -> Dict:
        memo: Dict[str, Dict] = {}

        def visit(name: str, depth: int = 0) -> Dict:
            if name in memo:
                return memo[name]
            zero = {"flops_fp": 0.0, "flops_int8": 0.0, "unresolved_dots": 0,
                    "hbm_bytes": 0.0,
                    "coll": {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVES}}
            if depth > 64 or name not in self.comps:
                return zero
            memo[name] = zero            # break accidental cycles
            loc = self._local(name)
            total = {"flops_fp": loc["flops_fp"], "flops_int8": loc["flops_int8"],
                     "unresolved_dots": loc["unresolved_dots"],
                     "hbm_bytes": loc["hbm_bytes"],
                     "coll": {k: dict(v) for k, v in loc["coll"].items()}}
            for child, mult, ckind in loc["children"]:
                if child == name:
                    continue
                sub = visit(child, depth + 1)
                total["flops_fp"] += mult * sub["flops_fp"]
                total["flops_int8"] += mult * sub["flops_int8"]
                if ckind == "while":
                    total["hbm_bytes"] += mult * sub["hbm_bytes"]
                total["unresolved_dots"] += sub["unresolved_dots"]
                for k in COLLECTIVES:
                    total["coll"][k]["count"] += mult * sub["coll"][k]["count"]
                    total["coll"][k]["bytes"] += mult * sub["coll"][k]["bytes"]
            memo[name] = total
            return total

        if self.entry is None and self.comps:
            self.entry = max(self.comps, key=lambda n: len(self.comps[n]))
        if self.entry is None:
            return {"flops_fp": 0.0, "flops_int8": 0.0, "unresolved_dots": 0,
                    "hbm_bytes": 0.0,
                    "coll": {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVES}}
        return visit(self.entry)


def analyze_hlo(hlo_text: str) -> Dict:
    """{"flops_fp", "flops_int8", "coll": {kind: {count, bytes}},
    "collective_bytes", "unresolved_dots"}"""
    out = Module(hlo_text).analyze()
    out["collective_bytes"] = sum(v["bytes"] for v in out["coll"].values())
    return out
