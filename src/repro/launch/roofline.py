"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads the per-cell JSON written by ``launch/dryrun.py`` and derives the three
roofline terms per (arch × shape × mesh):

    compute term    = HLO_FLOPs_per_device      / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device      / HBM_bandwidth_per_chip
    collective term = collective_bytes_per_dev  / ICI_link_bandwidth

``cost_analysis()`` and the parsed HLO are the *per-device* program (post-SPMD), so
dividing by per-chip peaks is the per-chip time directly — equivalent to the global
formulation ``global_quantity / (chips × peak)`` since global = per_device × chips.

Also reports MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device-step, the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste), the
dominant term, and a one-line "what would move it" note.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --in results/dryrun [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get

# TPU v5e hardware constants (per chip).
PEAK_BF16 = 197e12          # FLOP/s
PEAK_INT8 = 394e12          # OP/s (MXU int8 runs at 2x bf16)
HBM_BW = 819e9              # B/s
ICI_BW = 50e9               # B/s per link


MESH_DEVICES = {"pod16x16": 256, "pod2x16x16": 512}


def model_flops_per_step(arch: str, shape_name: str, n_devices: int) -> float:
    """6·N·D (training) or 2·N·D (inference fwd) useful model FLOPs per device-step."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / n_devices   # per device


def terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    st = rec.get("static")
    if st:
        # Trip-count-aware figures (launch/hlo_static.py). int8 dots run the MXU at
        # 2× bf16 peak, so they contribute at PEAK_INT8.
        flops = st["flops_fp"] + st["flops_int8"]
        t_c = st["flops_fp"] / PEAK_BF16 + st["flops_int8"] / PEAK_INT8
        t_m = st["hbm_bytes"] / HBM_BW
        t_x = st["collective_bytes"] / ICI_BW
    else:  # legacy records (cost_analysis counts while bodies once — underestimates)
        flops = rec["cost"]["flops"]
        t_c = flops / PEAK_BF16
        t_m = rec["cost"]["bytes"] / HBM_BW
        t_x = rec["collective_bytes"] / ICI_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    n_dev = MESH_DEVICES.get(rec.get("mesh", ""), rec["dp"] * rec["tp"])
    mf = model_flops_per_step(rec["arch"], rec["shape"], n_dev)
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops > 0 else 0.0,
        # Fraction of roofline: useful model FLOP time over the bound set by the
        # dominant term — the score we hillclimb.
        "roofline_fraction": (mf / PEAK_BF16) / bound if bound > 0 else 0.0,
    }


SUGGEST = {
    "compute": "cut non-model FLOPs (remat policy, fp32->bf16 epilogues) or move the "
               "GEMMs to the int8 MXU path (2x peak)",
    "memory": "fuse quantize-dequant chains, shrink activation dtypes, or serve "
              "prepared int8/int4 weights (2-4x fewer weight bytes)",
    "collective": "reshard to cut all-gathers (stronger TP tier / EP), overlap "
                  "collectives with compute, or compress the payload (int8 grads)",
}


def load(results_dir: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def format_table(recs: List[Dict], md: bool = False) -> str:
    rows = []
    header = ("arch", "shape", "mesh", "quant", "tier", "GiB/dev", "compute_s",
              "memory_s", "collect_s", "dominant", "useful%", "roofline%")
    for rec in recs:
        t = terms(rec)
        if t is None:
            rows.append((rec["arch"], rec["shape"], rec.get("mesh", "-"),
                         rec.get("quant", "-"), rec.get("status"),
                         rec.get("reason", rec.get("error", ""))[:40],
                         "-", "-", "-", "-", "-", "-"))
            continue
        rows.append((
            rec["arch"], rec["shape"], rec["mesh"], rec["quant"], rec["tier"],
            f"{rec['per_device_bytes'] / 2**30:.2f}",
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}", t["dominant"],
            f"{100 * t['useful_ratio']:.0f}", f"{100 * t['roofline_fraction']:.1f}",
        ))
    widths = [max(len(str(r[i])) for r in [header] + rows) for i in range(len(header))]
    sep = " | " if md else "  "
    lines = [sep.join(str(h).ljust(w) for h, w in zip(header, widths))]
    if md:
        lines.insert(0, "| " + lines[0] + " |")
        lines[0] = lines[0]
        lines = ["| " + sep.join(str(h).ljust(w) for h, w in zip(header, widths)) + " |",
                 "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
        for r in rows:
            lines.append("| " + sep.join(str(c).ljust(w) for c, w in zip(r, widths)) + " |")
    else:
        for r in rows:
            lines.append(sep.join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="results", default="results/dryrun")
    ap.add_argument("--md", action="store_true", help="markdown table")
    ap.add_argument("--suggest", action="store_true", help="print per-cell next move")
    args = ap.parse_args()
    recs = load(args.results)
    print(format_table(recs, md=args.md))
    if args.suggest:
        print()
        for rec in recs:
            t = terms(rec)
            if t:
                print(f"{rec['arch']} {rec['shape']} [{t['dominant']}-bound] -> "
                      f"{SUGGEST[t['dominant']]}")


if __name__ == "__main__":
    main()
