"""HLO-level analysis for the roofline: collective-bytes parsing + cost extraction.

``compiled.cost_analysis()`` provides FLOPs / bytes-accessed but NOT collective
traffic; we parse the post-partitioning HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count + summed operand bytes (per-device view)."""
    stats: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        out_type, kind, operands = m.group(1), m.group(2), m.group(3)
        # async pairs appear as -start/-done; count the start only
        full = m.group(0)
        if "-done(" in full:
            continue
        # operand list: "bf16[1,2]{...} %name, ..." — sum operand tensor bytes
        ob = _shape_bytes(operands)
        if ob == 0:
            ob = _shape_bytes(out_type)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += ob
    return stats


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def extract_cost(compiled) -> Dict[str, float]:
    """Normalize cost_analysis() output across jax versions."""
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"flops": -1.0, "bytes": -1.0, "error": str(e)}
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", -1.0))
    bytes_accessed = float(ca.get("bytes accessed", ca.get("bytes_accessed", -1.0)))
    return {"flops": flops, "bytes": bytes_accessed}


_BF16_RE = re.compile(r"\bbf16\[([0-9,]+)\]")
_BF16_PARAM_RE = re.compile(r"bf16\[([0-9,]+)\][^=]*parameter\(")
# f32-producing converts, bare or wrapped in a kLoop convert fusion.
_F32_CONVERT_RE = re.compile(
    r"=\s*f32\[([0-9,]+)\]\S*\s+(?:convert|fusion)\(")


def cpu_bf16_artifact_bytes(hlo_text: str, lead_dim: int = -1) -> float:
    """Estimate CPU-backend float-normalization inflation.

    The CPU XLA backend cannot run bf16 dots/updates natively, so it wholesale
    ``convert``s bf16 tensors to f32 — temporaries that do not exist on the TPU
    target. We count every f32-producing convert (bare or fused) whose result dims
    exactly match

      * a bf16 *parameter* tensor (weights, KV caches fed in bf16), or
      * a bf16 tensor stacked over the layer axis (``lead_dim`` == n_blocks: the
        scan-over-layers carries/saves that the normalizer duplicates wholesale).

    Counting per convert instruction (not per distinct shape) captures same-shaped
    twins like the k and v caches. Genuine f32 buffers (softmax scores, logits,
    optimizer state) are not converts of parameter/stacked-shaped bf16 tensors and
    are never subtracted. The corrected figure is reported next to the raw one in
    §Dry-run.
    """
    bf16_param_shapes = set(_BF16_PARAM_RE.findall(hlo_text))
    bf16_shapes = set(_BF16_RE.findall(hlo_text))
    total = 0
    seen_lines = set()
    for m in _F32_CONVERT_RE.finditer(hlo_text):
        dims = m.group(1)
        # de-dup textually identical instruction occurrences (computation bodies
        # can be printed once per module section)
        key = (m.start(), dims)
        if key in seen_lines:
            continue
        seen_lines.add(key)
        stacked = (lead_dim > 0 and dims.split(",")[0] == str(lead_dim)
                   and dims in bf16_shapes)
        if dims in bf16_param_shapes or stacked:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * 4
    return float(total)


def memory_stats(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    if not out:
        out["repr"] = str(ma)
    return out
