"""Serving launcher: quantize a model post-training (the paper's deployment) and run
batched greedy decoding through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --quant fake --n-requests 8 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-coder-33b --smoke \
        --quant int8         # prepared integer weights (quantize_tree)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import calibration, qlinear as ql
from repro.data import make_train_batches
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.models.quantize import quantize_tree, quantized_bytes
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine

QUANTS = {
    "fp": ql.FP,
    "fake": ql.W8A8_CROSSQUANT,
    "fake_pt": ql.W8A8_PER_TOKEN,
    "w4a8": ql.W4A8_G128,
    "int8": ql.W8A8_INT8,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="fake", choices=QUANTS)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt-lens", default=None, metavar="L1,L2,...",
                    help="mixed-length workload: cycle prompt lengths over requests "
                         "(continuous batcher admits each into its length bucket)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="EOS token id; default: no EOS (token 0 is the PAD token, "
                         "so it is never an implicit terminator)")
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "grouped"],
                    help="continuous = slot refill mid-decode (DESIGN.md §3.6); "
                         "grouped = legacy equal-length groups, drained")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="calibration batches for the int8 static-c path")
    ap.add_argument("--path", default="ref",
                    choices=["ref", "dequant-fp", "fused-int8"],
                    help="integer execution backend (int8 quant, DESIGN.md §3.3)")
    ap.add_argument("--kv-cache", default="fp", choices=["fp", "int8"])
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve TP-sharded on a (data, model) host mesh "
                         "(DESIGN.md §3.7), e.g. --mesh 4,2. Needs data*model "
                         "devices: set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N before launching (token-exact vs the "
                         "default single-device path)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=args.smoke)
    quant = QUANTS[args.quant]
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)
    base_bytes = quantized_bytes(params)

    if args.quant == "int8":
        # Offline PTQ: calibrate column stats eagerly, fold into int8 weights.
        print("calibrating static-c column statistics ...")
        obs = calibration.Observer()
        batch_fn = make_train_batches(cfg.vocab, args.prompt_len, args.batch_size,
                                      seed=args.seed + 1)
        ctx = QuantContext(quant, observer=obs)
        for b in range(args.calib_batches):
            batch = {k: jnp.asarray(v) for k, v in batch_fn(b).items()}
            M.apply(params, batch, cfg, ctx=ctx, mode="train", unroll=True)
        params = quantize_tree(params, quant,
                               tables=calibration.stack_tables(obs.tables()))
        q_bytes = quantized_bytes(params)
        print(f"quantized weights: {base_bytes / 2**20:.1f} MiB -> "
              f"{q_bytes / 2**20:.1f} MiB ({base_bytes / q_bytes:.2f}x smaller)")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_arg
        mesh = parse_mesh_arg(args.mesh)

    path = None if (args.quant != "int8" or args.path == "ref") else args.path
    config = EngineConfig(batch_size=args.batch_size, max_len=args.max_len,
                          path=path, kv_cache=args.kv_cache,
                          eos_id=args.eos_id, scheduler=args.scheduler)
    engine = ServeEngine(cfg, params, config=config, quant=quant, mesh=mesh)
    if engine.plan is not None:
        print(f"sharded serving: mesh={dict(mesh.shape)} "
              f"plan={engine.plan.describe()}")
    rng = np.random.default_rng(args.seed)
    lens = ([int(x) for x in args.prompt_lens.split(",")] if args.prompt_lens
            else [args.prompt_len])
    prompts = [rng.integers(1, cfg.vocab, size=lens[i % len(lens)]).astype(np.int32)
               for i in range(args.n_requests)]
    engine.submit(prompts, max_new=args.max_new)
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s) quant={quant.tag()} "
          f"scheduler={args.scheduler} occupancy={engine.occupancy():.2f}")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} -> out={r.out[:8]}")


if __name__ == "__main__":
    main()
