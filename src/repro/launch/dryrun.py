"""Multi-pod dry-run: lower + compile every (architecture × input shape) cell against
the production meshes, and extract the roofline inputs from the compiled artifact.

THE FIRST TWO LINES BELOW MUST RUN BEFORE ANY OTHER IMPORT: jax locks the device
count on first init, and the dry-run needs 512 placeholder host devices to build the
(pod=2, data=16, model=16) mesh. Nothing else in the repo sets this flag — smoke
tests and benchmarks see the real single CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                     # all cells, both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --quant int8
    PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun

Per cell it writes ``<out>/<arch>__<shape>__<mesh>__<quant>.json`` with the memory
analysis (proves it fits), cost analysis (FLOPs / bytes for §Roofline), and the
parsed per-device collective traffic (§Roofline's third term).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ruff: noqa: E402  (the env var must precede every jax-touching import)
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_archs, cell_supported, get, with_padded_heads
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import qlinear as ql
from repro.launch import hlo_analysis as H
from repro.launch import hlo_static as HS
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.serving import engine
from repro.sharding import hints, planner
from repro.training import optimizer as opt_lib, trainer

HBM_PER_CHIP = 16 * 1024 ** 3          # TPU v5e: 16 GiB


import dataclasses as _dc


def default_quant(kind: str) -> ql.QuantConfig:
    """Baseline quantization per workload kind (DESIGN.md §3.1).

    Training is full-precision (the paper is *post*-training quantization);
    prefill/decode serve the paper-faithful fake-quant W8A8 CrossQuant model with
    weights fake-quantized OFFLINE (w_prequantized — that is what PTQ means; it also
    keeps stacked weight-quant temporaries out of the serving graph).
    """
    if kind == "train":
        return ql.FP
    return _dc.replace(ql.W8A8_CROSSQUANT, w_prequantized=True)


QUANT_BY_NAME = {
    "fp": ql.FP,
    "fake": ql.W8A8_CROSSQUANT,
    "fake_pt": ql.W8A8_PER_TOKEN,
    "w4a8": ql.W4A8_G128,
    "int8": ql.W8A8_INT8,
    # true-integer W4 serving: packed nibbles + static-c CrossQuant activations
    "int4": ql.QuantConfig(mode="int8", a_bits=8, w_bits=4, w_quant="group"),
}


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, quant: ql.QuantConfig,
               n_micro: Optional[int] = None, train_dtype=jnp.float32,
               force_tier: Optional[str] = None):
    """Returns (fn, example_args (SDS), in_shardings, out_shardings, donate).

    ``train_dtype=jnp.bfloat16`` enables mixed-precision training (bf16 params,
    f32 optimizer moments, f32 update math — the MaxText default): FSDP weight
    all-gathers halve in both ICI and HBM traffic (§Perf hillclimb)."""
    plan = planner.make_plan(cfg, shape, mesh, force_tier=force_tier)
    params_sds = S.param_specs(cfg, dtype=jnp.bfloat16 if shape.kind != "train"
                               else train_dtype, quant=quant)
    params_sh = planner.param_shardings(params_sds, cfg, plan, mesh)

    if shape.kind == "train":
        opt_sds = S.opt_specs(params_sds)
        opt_sh = opt_lib.OptState(
            planner.replicated(opt_sds.step, mesh),
            planner.param_shardings(opt_sds.m, cfg, plan, mesh),
            planner.param_shardings(opt_sds.v, cfg, plan, mesh))
        batch_sds = S.input_specs(cfg, shape)
        batch_sh = planner.batch_shardings(batch_sds, plan, mesh)
        nm = n_micro if n_micro is not None else trainer.pick_n_micro(
            cfg, shape.global_batch, plan.dp)
        step = trainer.make_train_step(cfg, opt_lib.AdamWConfig(), n_micro=nm,
                                       quant=quant)
        args = (params_sds, opt_sds, batch_sds)
        in_sh = (params_sh, opt_sh, batch_sh)
        out_sh = (params_sh, opt_sh, None)
        donate = (0, 1)
        return step, args, in_sh, out_sh, donate, plan, {"n_micro": nm}

    cache_sds = S.cache_specs(cfg, shape)
    cache_sh = planner.cache_shardings(cache_sds, cfg, plan, mesh)
    if shape.kind == "prefill":
        batch_sds = S.input_specs(cfg, shape)
        batch_sh = planner.batch_shardings(batch_sds, plan, mesh)
        step = engine.make_prefill_step(cfg, quant)
        args = (params_sds, batch_sds, cache_sds)
        in_sh = (params_sh, batch_sh, cache_sh)
        out_sh = (None, cache_sh)
        donate = (2,)
    else:  # decode
        tok_sds = S.input_specs(cfg, shape)["tokens"]
        tok_sh = planner.batch_shardings({"tokens": tok_sds}, plan, mesh)["tokens"]
        len_sds = jax.ShapeDtypeStruct((), jnp.int32)
        raw = engine.make_decode_step(cfg, quant)
        step = raw
        args = (params_sds, tok_sds, cache_sds, len_sds)
        in_sh = (params_sh, tok_sh, cache_sh,
                 jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        out_sh = (None, cache_sh)
        donate = (2,)
    return step, args, in_sh, out_sh, donate, plan, {}


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               quant: ql.QuantConfig, quant_name: str,
               pad_heads: bool = True, n_micro: Optional[int] = None,
               train_dtype=jnp.float32, force_tier: Optional[str] = None,
               ssm_chunk: Optional[int] = None, pad_train_heads: bool = False) -> Dict:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "quant": quant_name, "status": "skip", "reason": why}

    if ssm_chunk:
        import dataclasses
        cfg = dataclasses.replace(cfg, ssm_chunk=ssm_chunk)

    orig_heads = cfg.n_heads
    if pad_heads and (shape.kind != "train" or pad_train_heads):
        # Serving cells run the head-padded (functionally identical) layout so the
        # attention projections TP-shard; training keeps the assigned head count
        # unless --pad-train-heads opts in (§Perf: replicated attention pays the
        # full S²·H score traffic per device).
        cfg = with_padded_heads(cfg, mesh.shape["model"])

    t0 = time.time()
    step, args, in_sh, out_sh, donate, plan, extra = build_cell(
        cfg, shape, mesh, quant, n_micro=n_micro, train_dtype=train_dtype,
        force_tier=force_tier)
    ep = plan.tp_axis if plan.moe_mode == "ep" else None
    with mesh, hints.sharding_hints(ep_axis=ep, dp_axes=plan.dp_axes,
                                    tp_axis=plan.tp_axis, mesh=mesh):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = H.memory_stats(compiled)
    cost = H.extract_cost(compiled)
    hlo = compiled.as_text()
    coll = H.collective_stats(hlo)
    per_dev_bytes = sum(v for k, v in mem.items()
                        if k in ("argument_size_in_bytes", "output_size_in_bytes",
                                 "temp_size_in_bytes")) - mem.get("alias_size_in_bytes", 0.0)
    # The CPU backend converts bf16 params/caches to f32 wholesale (no native bf16
    # dots); those temporaries do not exist on the TPU target (EXPERIMENTS.md §Dry-run).
    # Floor: resident state (arguments + outputs − aliases) can never be an artifact.
    from repro.models.model import block_spec
    artifact = H.cpu_bf16_artifact_bytes(hlo, lead_dim=block_spec(cfg).n_blocks)
    resident = (mem.get("argument_size_in_bytes", 0.0)
                + mem.get("output_size_in_bytes", 0.0)
                - mem.get("alias_size_in_bytes", 0.0))
    corrected = max(per_dev_bytes - artifact, resident)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "quant": quant_name,
        "status": "ok", "tier": plan.tier, "moe_mode": plan.moe_mode,
        "dp": plan.dp, "tp": plan.tp,
        "head_pad": f"{orig_heads}->{cfg.n_heads}" if cfg.n_heads != orig_heads else "",
        **extra,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "per_device_bytes": per_dev_bytes,
        "cpu_bf16_artifact_bytes": artifact,
        "per_device_bytes_tpu": corrected,
        "fits_hbm": bool(corrected < HBM_PER_CHIP),
        "cost": cost,
        "collectives": coll,
        "collective_bytes": H.total_collective_bytes(hlo),
        # Trip-count-aware static analysis (launch/hlo_static.py):
        # cost_analysis() visits while bodies once; these figures scale by the
        # known trip counts of every scan in the program.
        "static": HS.analyze_hlo(hlo),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="auto",
                    choices=["auto", *QUANT_BY_NAME.keys()])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    ap.add_argument("--no-pad-heads", action="store_true",
                    help="disable serving head padding (paper-assigned raw counts)")
    ap.add_argument("--n-micro", type=int, default=None,
                    help="override microbatch count (train cells)")
    ap.add_argument("--train-dtype", default="f32", choices=["f32", "bf16"],
                    help="training param dtype (bf16 = mixed precision)")
    ap.add_argument("--tier", default=None,
                    choices=[None, "tp_full", "tp_kv_rep", "tp_ffn", "dp_only"],
                    help="override the planner's sharding tier")
    ap.add_argument("--ssm-chunk", type=int, default=None,
                    help="override the SSD chunk length (SSM archs)")
    ap.add_argument("--pad-train-heads", action="store_true",
                    help="apply head padding to training cells too (§Perf)")
    args = ap.parse_args()
    train_dtype = jnp.bfloat16 if args.train_dtype == "bf16" else jnp.float32

    archs = list(all_archs()) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for arch in archs:
            for shape_name in shapes:
                kind = SHAPES[shape_name].kind
                if args.quant == "auto":
                    quant = default_quant(kind)
                    quant_name = "fp" if kind == "train" else "fake"
                else:
                    quant, quant_name = QUANT_BY_NAME[args.quant], args.quant
                tag = f"__{args.tag}" if args.tag else ""
                fname = f"{arch}__{shape_name}__{mesh_name}__{quant_name}{tag}.json"
                try:
                    rec = lower_cell(arch, shape_name, mesh, mesh_name, quant,
                                     quant_name, pad_heads=not args.no_pad_heads,
                                     n_micro=args.n_micro, train_dtype=train_dtype,
                                     force_tier=args.tier, ssm_chunk=args.ssm_chunk,
                                     pad_train_heads=args.pad_train_heads)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "quant": quant_name, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc(limit=6)}
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=1)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skip"
                n_fail += st == "fail"
                line = f"[{st:4s}] {arch:26s} {shape_name:12s} {mesh_name:11s} {quant_name}"
                if st == "ok":
                    gb = rec["per_device_bytes"] / 2 ** 30
                    gbc = rec["per_device_bytes_tpu"] / 2 ** 30
                    line += (f"  tier={rec['tier']:9s} {gb:6.2f} GiB/dev "
                             f"(tpu~{gbc:.2f}) fits={rec['fits_hbm']} "
                             f"compile={rec['compile_s']}s")
                elif st == "fail":
                    line += f"  {rec['error'][:120]}"
                print(line, flush=True)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
