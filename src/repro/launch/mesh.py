"""Production mesh construction.

Defined as a FUNCTION (never a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {axes} mesh, have {len(devices)} — the dry-run must "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax "
            f"import (launch/dryrun.py does this)")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_debug_mesh(data: int = 1, model: int = 1, expert: int = 1):
    """Small (data, model[, expert]) mesh for tests and host-mesh sharded serving
    (§3.7). ``expert > 1`` appends a dedicated expert-parallel axis (§3.13) —
    stacked MoE expert trees shard on it, orthogonal to the model axis.

    Raises — with the same ``--xla_force_host_platform_device_count`` hint as
    :func:`make_production_mesh` — when the host is short of ``data*model*expert``
    devices, instead of dying in a cryptic reshape (or, for a short prefix that
    happens to reshape, silently building a wrong-shaped mesh)."""
    import numpy as np
    n = data * model * expert
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for a (data={data}, model={model}, expert={expert}) "
            f"debug mesh, have "
            f"{len(devices)} — set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before any jax import (see launch/dryrun.py), or shrink the mesh")
    if expert > 1:
        return jax.sharding.Mesh(
            np.asarray(devices[:n]).reshape(data, model, expert),
            ("data", "model", "expert"))
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(data, model),
                             ("data", "model"))


def parse_mesh_arg(spec: str):
    """``"data,model"`` or ``"data,model,expert"`` CLI string (e.g. ``"4,2"`` or
    ``"2,2,2"``) → debug mesh. Shared by the serving launchers' ``--mesh`` flags."""
    try:
        dims = [int(x) for x in spec.split(",")]
        if len(dims) not in (2, 3):
            raise ValueError(spec)
    except ValueError:
        raise SystemExit(
            f"--mesh expects DATA,MODEL[,EXPERT] (e.g. --mesh 4,2 or "
            f"--mesh 2,2,2), got {spec!r}")
    return make_debug_mesh(*dims)
