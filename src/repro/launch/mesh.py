"""Production mesh construction.

Defined as a FUNCTION (never a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {axes} mesh, have {len(devices)} — the dry-run must "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax "
            f"import (launch/dryrun.py does this)")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small (data, model) mesh for tests and host-mesh sharded serving (§3.7).

    Raises — with the same ``--xla_force_host_platform_device_count`` hint as
    :func:`make_production_mesh` — when the host is short of ``data*model``
    devices, instead of dying in a cryptic reshape (or, for a short prefix that
    happens to reshape, silently building a wrong-shaped mesh)."""
    import numpy as np
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for a (data={data}, model={model}) debug mesh, have "
            f"{len(devices)} — set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before any jax import (see launch/dryrun.py), or shrink the mesh")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(data, model),
                             ("data", "model"))


def parse_mesh_arg(spec: str):
    """``"data,model"`` CLI string (e.g. ``"4,2"``) → debug mesh. Shared by the
    serving launchers' ``--mesh`` flags."""
    try:
        data, model = (int(x) for x in spec.split(","))
    except ValueError:
        raise SystemExit(
            f"--mesh expects DATA,MODEL (e.g. --mesh 4,2), got {spec!r}")
    return make_debug_mesh(data, model)
