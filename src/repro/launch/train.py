"""Training launcher: real end-to-end driver (data → sharded train loop → checkpoints
→ fault-tolerant supervision).

On a TPU pod this builds the production mesh and pjit-shards everything via the
planner; on CPU (CI, this container) it uses the debug mesh and reduced configs. The
control flow is identical — that is the point of the launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
        --steps 100 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --production \
        --shape train_4k          # full config on a real (16,16) pod
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get
from repro.data import make_train_batches
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import model as M
from repro.runtime import FailureInjector, Supervisor
from repro.sharding import hints, planner
from repro.training import compression as comp_lib
from repro.training import optimizer as opt_lib, trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--production", action="store_true", help="(16,16) pod mesh")
    ap.add_argument("--shape", default=None, help="named shape (production)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 CrossQuant gradient compression + error feedback")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject WorkerFailure at these steps (chaos testing)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=args.smoke)
    if args.shape:
        shape = SHAPES[args.shape]
        args.global_batch, args.seq_len = shape.global_batch, shape.seq_len
    else:
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq_len,
                                    global_batch=args.global_batch)

    mesh = make_production_mesh() if args.production else make_debug_mesh()
    plan = planner.make_plan(cfg, shape, mesh)
    print(f"mesh={dict(mesh.shape)} plan={plan.describe()}")

    opt_cfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                                  total_steps=args.steps)
    compression = comp_lib.CompressionConfig() if args.compress_grads else None
    step_raw = trainer.make_train_step(cfg, opt_cfg, n_micro=args.n_micro,
                                       compression=compression)

    key = jax.random.PRNGKey(args.seed)
    with mesh, hints.sharding_hints(
            ep_axis=plan.tp_axis if plan.moe_mode == "ep" else None,
            dp_axes=plan.dp_axes, tp_axis=plan.tp_axis, mesh=mesh):
        params = M.init_params(key, cfg)
        params_sh = planner.param_shardings(params, cfg, plan, mesh)
        params = jax.tree_util.tree_map(jax.device_put, params, params_sh)
        opt_state = opt_lib.init(params)
        jit_step = jax.jit(step_raw)

        batch_fn = make_train_batches(cfg.vocab, args.seq_len, args.global_batch,
                                      seed=args.seed)
        ckpt = CheckpointManager(args.ckpt_dir, keep_n=3)
        err_state = comp_lib.init_error_state(params) if compression else None

        state = {"params": params, "opt": opt_state}
        if compression:
            state["err"] = err_state

        t_last = time.time()

        def step_fn(state, step):
            nonlocal t_last
            batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
            if compression:
                p, o, e, metrics = jit_step(state["params"], state["opt"],
                                            state["err"], batch)
                new_state = {"params": p, "opt": o, "err": e}
            else:
                p, o, metrics = jit_step(state["params"], state["opt"], batch)
                new_state = {"params": p, "opt": o}
            if step % args.log_every == 0:
                dt = time.time() - t_last
                t_last = time.time()
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"ppl={float(jnp.exp(jnp.minimum(metrics['loss'], 20))):.2f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} ({dt:.1f}s)")
            return new_state, {"loss": float(metrics["loss"])}

        sup = Supervisor(ckpt, ckpt_every=args.ckpt_every)
        injector = FailureInjector(fail_at_steps=args.fail_at) if args.fail_at else None
        start = ckpt.latest_step() or 0
        if start:
            print(f"resuming from checkpoint step {start}")
            state, start = ckpt.restore(state)
        result = sup.run(state, step_fn, args.steps, start_step=start,
                         injector=injector)
        print(f"done: step={result.step} restarts={result.restarts} "
              f"final_loss={result.metrics_history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
