from repro.training.optimizer import AdamWConfig, OptState, init as opt_init, apply_updates  # noqa: F401
