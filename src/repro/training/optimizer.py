"""AdamW with cosine schedule, built from scratch (no optax dependency).

Optimizer state mirrors the parameter pytree (same shapes → same shardings), so the
sharding planner's param specs apply verbatim to ``m``/``v``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> OptState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree_util.tree_map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics). Master weights stay fp32."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (update + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
