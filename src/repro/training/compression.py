"""Gradient compression for the data-parallel all-reduce: int8 with error feedback.

Beyond-paper transplant of CrossQuant's insight to the distributed-optimization layer
(DESIGN.md §3.5). The DP all-reduce moves every gradient matrix across ICI each step;
quantizing the payload to int8 quarters that traffic. The failure mode of per-tensor
int8 gradient quantization is exactly the paper's *quantization kernel*: most gradient
entries are tiny relative to the tensor absmax and get rounded to zero. CrossQuant
geometry — scale = rowmax^alpha × colmax^(1-alpha) per element — shrinks the kernel on
gradients the same way it does on activations (measured in
benchmarks/grad_compression.py), and **error feedback** carries what quantization
dropped into the next step, making the scheme convergent.

Usage inside a train step (see training/trainer.py ``compress="int8_crossquant"``):

    carry, grads_q = compress_grads(grads, carry, cfg)   # before the DP all-reduce
    # psum/all-reduce happens on the int8 codes' dequantized values under GSPMD; in
    # the jit'd data-parallel step the quantize→dequantize pair bounds the payload.

The compression is simulated-in-graph (quantize→dequantize around the mean), which is
how fake-quant gradient-compression studies measure convergence impact; the wire
format (codes + two scale vectors) is what a custom collective would ship.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import quantizers as Q


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8
    alpha: float = 0.5            # gradient matrices are near-isotropic → balanced mix
    scheme: str = "crossquant"    # crossquant | per_tensor | none
    error_feedback: bool = True


def _grad_scale(g2d: jax.Array, cfg: CompressionConfig) -> jax.Array:
    if cfg.scheme == "per_tensor":
        return Q.per_tensor_scale(g2d, cfg.bits)
    return Q.crossquant_scale(g2d, cfg.bits, cfg.alpha)


def compress_leaf(g: jax.Array, err: jax.Array, cfg: CompressionConfig
                  ) -> Tuple[jax.Array, jax.Array]:
    """Quantize-dequantize one gradient tensor with error feedback.

    Returns (g_hat, new_err) with g_hat = deq(quant(g + err)), new_err = (g+err) - g_hat.
    Tensors with < 2 dims (norm scales, biases) pass through uncompressed — they are a
    negligible fraction of bytes and the most precision-sensitive.
    """
    if cfg.scheme == "none" or g.ndim < 2:
        return g, err
    gf = g.astype(jnp.float32) + (err if cfg.error_feedback else 0.0)
    g2d = gf.reshape(-1, gf.shape[-1])
    scale = _grad_scale(g2d, cfg)
    qm = Q.qmax(cfg.bits)
    codes = jnp.clip(jnp.round(g2d / scale), -qm, qm)
    ghat = (codes * scale).reshape(g.shape)
    new_err = (gf - ghat) if cfg.error_feedback else err
    return ghat.astype(g.dtype), new_err


def init_error_state(params):
    """Zeros matching every compressible leaf (same shapes → same shardings)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if p.ndim >= 2
        else jnp.zeros((), jnp.float32), params)


def compress_grads(grads, err_state, cfg: CompressionConfig):
    """Apply :func:`compress_leaf` across the gradient pytree."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [compress_leaf(g, e, cfg) for g, e in zip(flat_g, flat_e)]
    ghat = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return ghat, new_err


@functools.partial(jax.jit, static_argnames=("bits", "alpha"))
def gradient_kernel_fractions(g: jax.Array, bits: int = 8, alpha: float = 0.5):
    """Diagnostic: quantization-kernel mass of a gradient matrix under per-tensor vs
    CrossQuant scaling — the paper's Definition 1 applied to gradients."""
    from repro.core import kernel_analysis as KA
    g2d = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    return {
        "per_tensor": KA.kernel_fraction(g2d, Q.per_tensor_scale(g2d, bits)),
        "crossquant": KA.kernel_fraction(g2d, Q.crossquant_scale(g2d, bits, alpha)),
    }
