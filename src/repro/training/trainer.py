"""Training step: microbatched gradient accumulation over a scanned loss, remat'd
scan-over-layers inside the model, AdamW update. The step is a pure function suitable
for ``jax.jit`` with sharded params/opt/batch (see launch/dryrun.py and launch/train.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.sharding import hints
from repro.training import compression as comp_lib
from repro.training import optimizer as opt_lib


def pick_n_micro(cfg: ModelConfig, global_batch: int, dp: int) -> int:
    """Microbatch count heuristic: keep per-replica microbatch small enough that
    (activations + fp32 logits) fit HBM. Large d_model / vocab → smaller microbatch."""
    local = max(1, global_batch // dp)
    # MoE dispatch buffers scale with the microbatch token count (E·C·d); keep the
    # per-replica microbatch at 1 sequence for MoE and for wide/huge-vocab models.
    target_local_mb = 1 if (cfg.d_model >= 4096 or cfg.vocab >= 128000
                            or cfg.n_experts) else 4
    n_micro = max(1, local // target_local_mb)
    while global_batch % (n_micro * dp) and n_micro > 1:   # keep divisibility
        n_micro -= 1
    while global_batch % n_micro:
        n_micro -= 1
    return n_micro


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.AdamWConfig, n_micro: int = 1,
                    quant: Optional[ql.QuantConfig] = None,
                    compression: Optional["comp_lib.CompressionConfig"] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``compression`` set, the signature becomes
    train_step(params, opt_state, err_state, batch) -> (params, opt_state, err_state,
    metrics): gradients are int8-compressed (CrossQuant geometry + error feedback)
    before the optimizer — the payload a compressed DP all-reduce would ship.
    """
    ctx = QuantContext(quant or cfg.quant)

    def loss(params, mb):
        return M.loss_fn(params, mb, cfg, ctx=ctx, remat=True)

    def train_step(params, opt_state, batch, err_state=None):
        if n_micro > 1:
            micro = jax.tree_util.tree_map(
                lambda x: hints.constrain_microbatches(
                    x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])), batch)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                           micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            mean_loss = lsum / n_micro
        else:
            (mean_loss, _), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)

        if compression is not None:
            grads, err_state = comp_lib.compress_grads(grads, err_state, compression)

        new_params, new_opt, om = opt_lib.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": mean_loss, **om}
        if compression is not None:
            return new_params, new_opt, err_state, metrics
        return new_params, new_opt, metrics

    if compression is not None:
        def train_step_c(params, opt_state, err_state, batch):
            return train_step(params, opt_state, batch, err_state)
        return train_step_c
    return train_step


def make_eval_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None):
    ctx = QuantContext(quant or cfg.quant)

    @jax.jit
    def eval_step(params, batch):
        loss, metrics = M.loss_fn(params, batch, cfg, ctx=ctx, remat=False)
        return metrics

    return eval_step
