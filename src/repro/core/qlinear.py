"""Quantized linear layer — the integration point between the paper's numerics and the
model zoo. Functional style: params are plain dicts (pytrees), behaviour is selected by
a static, hashable :class:`QuantConfig`.

Execution modes (DESIGN.md §3.1):

* ``fp``    — bf16/fp32 GEMM (the FP16 baseline of every paper table).
* ``fake``  — paper-faithful fake quantization: dynamic activation scales
              (per-token or CrossQuant eq. 5), per-channel / group weight scales,
              quantize→dequantize→fp GEMM. This is exactly the evaluation path of the
              paper's App. B.1 reference code.
* ``int8``  — TPU-native integer path: static-c CrossQuant. Column stats frozen from
              calibration, ``c^(1-α)`` folded into the offline weight quantization so the
              GEMM is a true int8×int8→int32 contraction with separable output-side
              dequant. Backed by the Pallas ``qgemm`` kernel on TPU; the jnp reference is
              used under jit on CPU (and for the dry-run lowering).

Weight layouts: ``w (d_in, d_out)`` or stacked experts ``(E, d_in, d_out)``.
Prepared (pre-quantized) parameter dicts replace ``{"w"}`` with
``{"qw", "sw", "bcol", ...}`` — produced by :func:`prepare_int8` / :func:`prepare_int4`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import quantizers as Q


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization behaviour for every quantized linear in a model."""

    mode: str = "fp"                 # fp | fake | int8
    a_bits: int = 8
    w_bits: int = 8
    alpha: float = 0.15              # CrossQuant activation exponent
    act_quant: str = "crossquant"    # per_token | crossquant | none
    w_quant: str = "per_channel"     # per_channel | group | crossquant_w
    w_group: int = 128               # group size for w_quant="group" (g128)
    alpha_w: float = 0.55            # CrossQuant-on-weights exponent (App. B.1)
    static_c: bool = False           # use calibrated cmax when present (fake mode)
    w_prequantized: bool = False     # weights already fake-quantized offline (PTQ):
                                     # skip in-graph weight quantization entirely
    remove_frac: float = 0.0         # act_quant="remove_kernel": fraction zeroed

    def tag(self) -> str:
        if self.mode == "fp":
            return "fp16"
        g = f"-g{self.w_group}" if self.w_quant == "group" else ""
        return f"W{self.w_bits}A{self.a_bits}{g}[{self.act_quant},a={self.alpha}]"


FP = QuantConfig(mode="fp")
W8A8_CROSSQUANT = QuantConfig(mode="fake", a_bits=8, w_bits=8)
W8A8_PER_TOKEN = QuantConfig(mode="fake", a_bits=8, w_bits=8, act_quant="per_token")
W8A8_SMOOTHQUANT = QuantConfig(mode="fake", a_bits=8, w_bits=8,
                               act_quant="smoothquant")
W4A8_G128 = QuantConfig(mode="fake", a_bits=8, w_bits=4, w_quant="group")
W4A8_G128_PER_TOKEN = QuantConfig(mode="fake", a_bits=8, w_bits=4, w_quant="group",
                                  act_quant="per_token")
# AWQ weight-only baseline (paper Table 2): per-token activations; and the paper's
# CrossQuant+AWQ combination.
W4A8_G128_AWQ = QuantConfig(mode="fake", a_bits=8, w_bits=4, w_quant="awq",
                            act_quant="per_token")
W4A8_G128_CQ_AWQ = QuantConfig(mode="fake", a_bits=8, w_bits=4, w_quant="awq")
# App. B.1 rescue: CrossQuant applied to the weights themselves at W4A4.
W4A4_CQW = QuantConfig(mode="fake", a_bits=4, w_bits=4, w_quant="crossquant_w")
W4A4 = QuantConfig(mode="fake", a_bits=4, w_bits=4)
W4A4_PER_TOKEN = QuantConfig(mode="fake", a_bits=4, w_bits=4, act_quant="per_token")
W8A8_INT8 = QuantConfig(mode="int8", a_bits=8, w_bits=8)


def remove_kernel_cfg(frac: float, w_bits: int = 8) -> QuantConfig:
    """'W8-Remove Kernel' of Fig. 6/7: quantize weights, zero the smallest ``frac``
    of activation entries, quantize nothing else."""
    return QuantConfig(mode="fake", w_bits=w_bits, act_quant="remove_kernel",
                       remove_frac=frac)


REMOVE_TRUE_KERNEL = QuantConfig(mode="fake", w_bits=8,
                                 act_quant="remove_true_kernel")


# ======================================================================================
# Init
# ======================================================================================

def init(key, d_in: int, d_out: int, *, n_stack: Optional[int] = None,
         dtype=jnp.float32, scale: Optional[float] = None) -> dict:
    shape = (d_in, d_out) if n_stack is None else (n_stack, d_in, d_out)
    s = scale if scale is not None else d_in ** -0.5
    return {"w": (jax.random.normal(key, shape) * s).astype(dtype)}


# ======================================================================================
# Fake-quant application (paper-faithful path)
# ======================================================================================

def _fake_act(x, cfg: QuantConfig, cmax):
    if cfg.act_quant == "none":
        return x
    if cfg.act_quant == "per_token":
        return Q.fake_per_token(x, cfg.a_bits)
    if cfg.act_quant == "crossquant":
        col = cmax if (cfg.static_c and cmax is not None) else None
        return Q.fake_crossquant(x, cfg.a_bits, cfg.alpha, col_max=col)
    raise ValueError(cfg.act_quant)


def _fake_weight(w, cfg: QuantConfig, cmax=None):
    if cfg.w_quant == "per_channel":
        # Paper eq. (2): reduce over the output axis -> per-input-channel scale.
        return Q.fake_per_channel(w, cfg.w_bits, axis=-1)
    if cfg.w_quant == "group":
        return Q.fake_group(w, cfg.w_bits, cfg.w_group)
    if cfg.w_quant == "crossquant_w":
        # App. B.1: CrossQuant applied to the weight matrix itself (OPT-66B W4A4 /
        # LLaMA3-70B W8A8 rescue). Rows of W are input channels.
        return Q.fake_crossquant(w, cfg.w_bits, cfg.alpha_w)
    if cfg.w_quant == "awq":
        # AWQ baseline: activation-aware salient-channel protection (core/awq.py).
        from repro.core import awq as awq_lib
        if cmax is None:
            cmax = jnp.ones(w.shape[-2], jnp.float32)
        return awq_lib.awq_weight(w, cmax, bits=cfg.w_bits, group=cfg.w_group)
    raise ValueError(cfg.w_quant)


# ======================================================================================
# int8 path: static-c CrossQuant (jnp reference; Pallas kernel dispatch in kernels/ops)
# ======================================================================================

def prepare_int8(params: dict, cfg: QuantConfig, cmax: Optional[jax.Array] = None) -> dict:
    """Offline weight preparation: fold b_j = c_j^(1-α) into W, per-output-channel
    int8 quantization. Returns a prepared parameter dict (raw ``w`` dropped)."""
    w = params["w"]
    cm = cmax if cmax is not None else params.get("cmax")
    # Without calibrated column stats, an alpha<1 row factor t^alpha no longer spans
    # the data range (massive clipping): degrade to exact per-token int8 (alpha=1).
    # The effective alpha ships as a scalar leaf so mixed calibrated/uncalibrated
    # linears coexist in one tree.
    alpha_eff = cfg.alpha if cm is not None else 1.0
    if cm is None:
        cm = jnp.ones(w.shape[-2], w.dtype)
    b = jnp.maximum(cm, Q.EPS) ** (1.0 - alpha_eff)
    # Stacked weights (L/E leading dims): bcol must carry the same leading dims so
    # scan-over-layers can slice it per layer. A calibrated table arrives as
    # (lead..., d_in) without the expert-stack dim — the dispatch buffer's column
    # stat is shared across experts — so align it by inserting singleton axes
    # before d_in ((L, d_in) -> (L, 1, d_in) against (L, E, d_in, d_out)).
    while b.ndim < w.ndim - 1:
        b = b[..., None, :]
    b = jnp.broadcast_to(b, w.shape[:-1])
    wb = w * b[..., :, None]
    sw = jnp.maximum(jnp.max(jnp.abs(wb), axis=-2, keepdims=True), Q.EPS) / Q.qmax(cfg.w_bits)
    qw = jnp.clip(jnp.round(wb / sw), -Q.qmax(cfg.w_bits), Q.qmax(cfg.w_bits)).astype(jnp.int8)
    # qalpha carries the stack's leading dims (scan/vmap slice it with the weight).
    return {"qw": qw, "sw": sw.squeeze(-2).astype(jnp.float32),
            "bcol": b.astype(jnp.float32),
            "qalpha": jnp.full(w.shape[:-2], alpha_eff, jnp.float32)}


def prepare_int4(params: dict, cfg: QuantConfig, cmax: Optional[jax.Array] = None) -> dict:
    """W4 preparation: group-quantize the b-folded weight along d_in with
    group == cfg.w_group, pack nibbles along d_in. Group scales shape (..., G, d_out)."""
    w = params["w"]
    cm = cmax if cmax is not None else params.get("cmax")
    alpha_eff = cfg.alpha if cm is not None else 1.0
    if cm is None:
        cm = jnp.ones(w.shape[-2], w.dtype)
    b = jnp.maximum(cm, Q.EPS) ** (1.0 - alpha_eff)
    while b.ndim < w.ndim - 1:          # see prepare_int8: expert-stacked weights
        b = b[..., None, :]
    b = jnp.broadcast_to(b, w.shape[:-1])
    wb = w * b[..., :, None]
    *lead, d_in, d_out = wb.shape
    g = cfg.w_group
    assert d_in % g == 0, f"d_in={d_in} not divisible by group {g}"
    grouped = wb.reshape(*lead, d_in // g, g, d_out)
    sw = jnp.maximum(jnp.abs(grouped).max(axis=-2, keepdims=True), Q.EPS) / Q.qmax(4)
    qw = jnp.clip(jnp.round(grouped / sw), -Q.qmax(4), Q.qmax(4)).astype(jnp.int8)
    qw = qw.reshape(*lead, d_in, d_out)
    return {
        "qw4": packing.pack_int4(qw, axis=-2),                  # (d_in//2, d_out) int8
        "sw": sw.squeeze(-2).astype(jnp.float32),               # (..., G, d_out)
        "bcol": b.astype(jnp.float32),
        "qalpha": jnp.full(w.shape[:-2], alpha_eff, jnp.float32),
    }


def quantize_act_int8(x: jax.Array, bcol: jax.Array, cfg: QuantConfig, alpha=None):
    """Runtime activation quantization for the int path: divide by outer(a_i, b_j).

    ``alpha`` may be a traced scalar/array from the prepared tree (``qalpha``) so
    calibrated (alpha<1) and uncalibrated (alpha=1) linears share one program."""
    alpha = cfg.alpha if alpha is None else alpha
    if isinstance(alpha, jax.Array):
        while alpha.ndim < x.ndim:       # stacked experts: (E,) -> (E, 1, 1)
            alpha = alpha[..., None]
    # stacked experts: bcol (E, d_in) broadcasts against x (E, C, d_in)
    while bcol.ndim >= 2 and bcol.ndim < x.ndim:
        bcol = jnp.expand_dims(bcol, axis=-2)
    t = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), Q.EPS)
    a = (t ** alpha) / Q.qmax(cfg.a_bits)                        # (..., T, 1)
    qx = jnp.clip(jnp.round(x / (a * bcol)), -Q.qmax(cfg.a_bits), Q.qmax(cfg.a_bits))
    return qx.astype(jnp.int8), a.astype(jnp.float32)


def _int8_pallas(params: dict, x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Fused Pallas pipeline for a 2-D prepared linear: ``act_quantize`` emits int8
    codes + row scales straight into ``qgemm_w8a8``/``w4a8`` (DESIGN.md §3.3).

    Leading batch/sequence axes are flattened to the GEMM M axis (token-parallel).
    The activation never materializes an (M, K) f32 intermediate on the way in, and
    the contraction runs on integer codes with output-side dequantization.
    """
    from repro.kernels import ops as kops

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    alpha = params.get("qalpha")
    if alpha is None:
        qx, a = kops.act_quantize(x2, params["bcol"], bits=cfg.a_bits,
                                  alpha=cfg.alpha)
    else:
        qx, a = kops.act_quantize_dyn(x2, params["bcol"],
                                      jnp.asarray(alpha, jnp.float32),
                                      bits=cfg.a_bits)
    if "qw" in params:
        mask = params.get("mask")
        if mask is not None and params["qw"].ndim == 2:
            # N:M-pruned leaf (DESIGN.md §3.12): unpack the bit-packed keep-mask
            # and let the sparse GEMM skip all-zero weight blocks. The dequant
            # and ref backends need no branch — qw already carries the zeros.
            mk = packing.unpack_mask(mask, count=params["qw"].shape[-2], axis=-2)
            y = kops.qgemm_w8a8_sparse(qx, params["qw"], a, params["sw"], mk)
        else:
            y = kops.qgemm_w8a8(qx, params["qw"], a, params["sw"])
    else:
        y = kops.qgemm_w4a8(qx, params["qw4"], a, params["sw"], group=cfg.w_group)
    return y.reshape(*lead, y.shape[-1]).astype(x.dtype)


def _int8_dequant_fp(qx, qw, a, sw):
    """Dequantize-then-fp-GEMM baseline: codes are scaled back to f32 *before* the
    contraction (xdq ≈ x/b rows, wdq ≈ w·b columns — the b factors cancel), so the
    GEMM runs at fp throughput and fp HBM traffic. Numerically it carries exactly the
    same quantization error as the integer path; it exists as the serving baseline the
    fused kernels are measured against (DESIGN.md §3.3)."""
    xdq = qx.astype(jnp.float32) * a
    wdq = qw.astype(jnp.float32)
    if qw.ndim == 3 and qx.ndim == 3:
        wdq = wdq * sw[:, None, :]
        return jnp.einsum("eci,eio->eco", xdq, wdq)
    return (xdq @ wdq) * sw


def unpack_int4_weight(qw4: jax.Array) -> jax.Array:
    """(..., d_in//2, d_out) packed nibbles → (..., d_in, d_out) int8 codes."""
    return packing.unpack_int4(qw4, axis=-2)


def dequant_int4_weight(qw4: jax.Array, sw: jax.Array, group: int) -> jax.Array:
    """Unpack nibbles and apply the (..., G, d_out) per-group scales → f32 weight
    (the b-folded ``wb``, see :func:`prepare_int4`). Single home for the qw4/sw
    layout contract shared by the dequant backend and models.quantize."""
    qw = unpack_int4_weight(qw4).astype(jnp.float32)
    *lead, d_in, d_out = qw.shape
    grouped = qw.reshape(*lead, d_in // group, group, d_out)
    return (grouped * sw[..., :, None, :]).reshape(*lead, d_in, d_out)


def _int4_dequant_fp(qx, qw4, a, sw, group: int):
    """W4 variant of :func:`_int8_dequant_fp`: unpack nibbles, apply per-group scales
    to the weight, fp GEMM."""
    xdq = qx.astype(jnp.float32) * a
    wdq = dequant_int4_weight(qw4, sw, group)
    if wdq.ndim == 3 and qx.ndim == 3:
        return jnp.einsum("eci,eio->eco", xdq, wdq)
    return xdq @ wdq


def _int8_matmul_ref(qx, qw, a, sw):
    """Reference int8 GEMM + separable dequant:  y = (qx·qw) * a_i * sw_k.

    Handles stacked experts: qx (E, C, d_in) · qw (E, d_in, d_out) batched over E,
    with sw (E, d_out) broadcast over the capacity axis.

    Under a TP-sharded serving plan the contraction dim of row-parallel layers
    (wo/down/out_proj) is split over the model axis: the accumulator is pinned
    while still int32 (hints.constrain_gemm_acc) so the cross-shard partial-sum
    reduction happens on integer values *before* the f32 dequant multiply —
    bitwise-identical to the single-device contraction (DESIGN.md §3.7)."""
    # local import: repro.sharding pulls in configs, which imports this module
    from repro.sharding import hints
    if qw.ndim == 3 and qx.ndim == 3:
        acc = jnp.einsum("eci,eio->eco", qx.astype(jnp.int32), qw.astype(jnp.int32))
        # expert_tp shards the contraction dim of down-experts: same int32-before-
        # dequant ordering requirement as the 2-D row-parallel case below
        acc = hints.constrain_gemm_acc(acc, expert_leading=True)
        return acc.astype(jnp.float32) * a * sw[:, None, :]
    acc = jax.lax.dot_general(
        qx, qw, (((qx.ndim - 1,), (qw.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc = hints.constrain_gemm_acc(acc)
    return acc.astype(jnp.float32) * a * sw


def _int4_matmul_ref(qx, qw4, a, sw, group: int):
    """Reference W4 GEMM: unpack nibbles, per-group int32 partial sums, group dequant.

    Stacked experts supported: qx (E, C, d_in), qw4 (E, d_in//2, d_out),
    sw (E, G, d_out)."""
    from repro.sharding import hints
    qw = unpack_int4_weight(qw4)                                 # (..., d_in, d_out)
    d_in = qw.shape[-2]
    ngroups = d_in // group
    if qw.ndim == 3 and qx.ndim == 3:
        E, C, _ = qx.shape
        qx_g = qx.reshape(E, C, ngroups, group)
        qw_g = qw.reshape(E, ngroups, group, qw.shape[-1])
        acc = jnp.einsum("ecgk,egko->ecgo", qx_g.astype(jnp.int32),
                         qw_g.astype(jnp.int32))                 # (E, C, G, d_out)
        acc = hints.constrain_gemm_acc(acc, expert_leading=True)
        y = (acc.astype(jnp.float32) * sw[:, None]).sum(axis=-2)
        return y * a
    qx_g = qx.reshape(*qx.shape[:-1], ngroups, group)
    qw_g = qw.reshape(ngroups, group, qw.shape[-1])
    acc = jnp.einsum("...gk,gko->...go", qx_g.astype(jnp.int32), qw_g.astype(jnp.int32))
    # Row-parallel W4 under TP splits the *group* axis: gather the int32 per-group
    # partials before the f32 group-dequant sum so the reduction order matches the
    # single-device path exactly (constrain_gemm_acc replicates interior dims).
    acc = hints.constrain_gemm_acc(acc)
    y = (acc.astype(jnp.float32) * sw).sum(axis=-2)              # group dequant + reduce
    return y * a


# ======================================================================================
# Unified apply
# ======================================================================================

def apply(params: dict, x: jax.Array, cfg: QuantConfig = FP, *,
          name: str = "", observer=None, use_pallas: bool = False,
          int_exec: Optional[str] = None) -> jax.Array:
    """y = x @ W under the configured quantization mode.

    Handles 2-D weights and stacked-expert 3-D weights ((E, d_in, d_out) with
    x (E, C, d_in)). ``observer`` (eager calibration) records column absmax.

    For *prepared* integer trees, ``int_exec`` selects the execution backend
    (DESIGN.md §3.3):

    * ``"ref"`` (default) — jnp integer GEMM (int32 accumulation under XLA).
    * ``"dequant"``       — dequantize codes to f32, fp GEMM (the dequant-fp
                            serving baseline).
    * ``"pallas"``        — fused ``act_quantize → qgemm`` Pallas kernels
                            (Mosaic on TPU, ``interpret=True`` elsewhere).

    ``use_pallas=True`` is shorthand for ``int_exec="pallas"`` (it also switches the
    attention layers to the flash kernel — see models/layers.py).
    """
    if observer is not None:
        observer.observe(name, x)

    if int_exec not in (None, "ref", "dequant", "pallas"):
        raise ValueError(f"unknown int_exec {int_exec!r}; "
                         "pick one of 'ref', 'dequant', 'pallas'")
    if "qw" in params or "qw4" in params:        # prepared integer tree
        exec_mode = "pallas" if use_pallas else (int_exec or "ref")
        wq = params.get("qw", params.get("qw4"))
        if exec_mode == "pallas" and wq.ndim == 2 and x.ndim >= 2:
            return _int8_pallas(params, x, cfg)
        qx, a = quantize_act_int8(x, params["bcol"], cfg, alpha=params.get("qalpha"))
        if "qw" in params:
            if exec_mode == "dequant":
                return _int8_dequant_fp(qx, params["qw"], a, params["sw"]).astype(x.dtype)
            return _int8_matmul_ref(qx, params["qw"], a, params["sw"]).astype(x.dtype)
        if exec_mode == "dequant":
            return _int4_dequant_fp(qx, params["qw4"], a, params["sw"],
                                    cfg.w_group).astype(x.dtype)
        return _int4_matmul_ref(qx, params["qw4"], a, params["sw"], cfg.w_group).astype(x.dtype)

    w = params["w"]
    if cfg.mode == "fp":
        pass
    elif cfg.mode == "fake":
        if cfg.act_quant == "smoothquant":
            # SmoothQuant baseline (Xiao et al. 2023): migrate difficulty to weights
            # via s_j, then per-token A-quant + per-channel W-quant. Exactness of the
            # transform: (X/s)(sW) == XW. Column stats from calibration when present,
            # else dynamic (per-batch) — both supported by the paper's framing.
            from repro.core import smoothquant as sq
            cm = params.get("cmax")
            if cm is None:
                reduce_axes = tuple(range(x.ndim - 1))
                cm = jnp.max(jnp.abs(x), axis=reduce_axes)
            w_rowmax = jnp.max(jnp.abs(w), axis=-1)
            s = sq.smoothing_scale(cm.astype(jnp.float32),
                                   w_rowmax.astype(jnp.float32), alpha=0.5)
            x = Q.fake_per_token((x / s.astype(x.dtype)), cfg.a_bits)
            w = Q.fake_per_channel(w * s[..., :, None].astype(w.dtype), cfg.w_bits,
                                   axis=-1)
        elif cfg.act_quant == "remove_kernel":
            # The paper's Fig. 6/7 ablation: zero ONLY the smallest-|x| fraction of
            # elements; quantize nothing else in the activation.
            from repro.core import kernel_analysis as KA
            x = KA.remove_kernel_fraction(x, cfg.remove_frac)
            if not cfg.w_prequantized:
                w = _fake_weight(w, cfg)
        elif cfg.act_quant == "remove_true_kernel":
            # The paper's Fig. 1/9 ablation: zero exactly K(Q) under the per-token
            # scale (|x| < 0.5·Δ_pt) and leave every other element UNQUANTIZED —
            # the causal test that the kernel, not the rounding of survivors,
            # carries the A8 accuracy drop.
            from repro.core import kernel_analysis as KA
            x = KA.remove_kernel(x, Q.per_token_scale(x, cfg.a_bits))
            if not cfg.w_prequantized:
                w = _fake_weight(w, cfg)
        else:
            x_cm = params.get("cmax")
            if cfg.w_quant == "awq" and x_cm is None:
                reduce_axes = tuple(range(x.ndim - 1))
                x_cm = jnp.max(jnp.abs(x), axis=reduce_axes)
            x = _fake_act(x, cfg, params.get("cmax"))
            if not cfg.w_prequantized:
                w = _fake_weight(w, cfg, cmax=x_cm)
    elif cfg.mode == "int8":
        # int8 mode on unprepared weights: dynamic-c preparation on the fly (column
        # stats from this batch — the paper's dynamic-c geometry as a true int8
        # GEMM). Smoke tests and eager experimentation use this path; deployments
        # prepare offline via models.quantize.quantize_tree.
        if "cmax" in params:
            cmax = params["cmax"]
        else:
            reduce_axes = tuple(range(x.ndim - 1))
            cmax = jnp.max(jnp.abs(x), axis=reduce_axes)
        prepared = prepare_int8({"w": w}, cfg, cmax=cmax)
        return apply(prepared, x, cfg, use_pallas=use_pallas, int_exec=int_exec)
    else:
        raise ValueError(cfg.mode)

    if w.ndim == 3 and x.ndim == 3:   # stacked experts
        return jnp.einsum("eci,eio->eco", x, w.astype(x.dtype))
    return x @ w.astype(x.dtype)
