"""Quantization functions: the paper's core numerics.

Implements (all symmetric, signed, round-to-nearest-even via jnp.round):

* Per-token quantization      — eq. (1): scale from per-row absmax ``t_i``.
* Per-channel quantization    — eq. (2): scale from per-row absmax of W (input-channel
  axis, as written in the paper) or per-output-channel (GEMM-friendly variant).
* Group-wise quantization     — reshape to (I*O/g, g) groups, per-group absmax.
* CrossQuant                  — eq. (5): per-element scale ``t_i^alpha * c_j^(1-alpha)``.

Every quantizer returns a :class:`QuantResult` carrying the integer codes, the scale
tensor (broadcastable against the codes) and enough metadata to dequantize, measure the
quantization kernel (Definition 1) and fake-quantize.

All functions are jit-friendly: ``bits``/``alpha``/axis arguments are static.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

# A floor on scales so rows/columns of exact zeros do not produce inf/nan.  Matches the
# smallest normal of fp16 (the paper's storage dtype) divided by qmax headroom.
EPS = 1e-8


def qmax(bits: int) -> int:
    """Largest representable magnitude: 2^(N-1) - 1 (symmetric signed grid)."""
    return 2 ** (bits - 1) - 1


def _storage_dtype(bits: int):
    # INT4 codes are stored in int8 containers (packing handled in core/packing.py).
    return jnp.int8 if bits <= 8 else jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantResult:
    """Integer codes + broadcastable scale. ``dequant() == codes * scale``."""

    codes: jax.Array       # integer grid values, same shape as input
    scale: jax.Array       # broadcastable to codes.shape
    bits: int              # static

    def dequant(self) -> jax.Array:
        return self.codes.astype(self.scale.dtype) * self.scale

    # -- pytree plumbing ------------------------------------------------------------
    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


def _quantize(x: jax.Array, scale: jax.Array, bits: int) -> QuantResult:
    q = jnp.clip(jnp.round(x / scale), -qmax(bits), qmax(bits))
    return QuantResult(q.astype(_storage_dtype(bits)), scale.astype(jnp.float32), bits)


# ======================================================================================
# Scale constructions
# ======================================================================================

def per_token_scale(x: jax.Array, bits: int) -> jax.Array:
    """Eq. (1): Δ_ij = t_i / qmax with t_i = max|X_i,:| (broadcast over last axis)."""
    t = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return jnp.maximum(t, EPS) / qmax(bits)


def per_channel_scale(w: jax.Array, bits: int, axis: int = -1) -> jax.Array:
    """Eq. (2): per-channel weight scale.

    ``axis`` is the axis *reduced over*. The paper reduces over the output axis of
    W ∈ R^{I×O} (``axis=-1``, scale per input channel). The GEMM-friendly variant
    reduces over the input axis (``axis=-2``, scale per output channel).
    """
    t = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    return jnp.maximum(t, EPS) / qmax(bits)


def per_tensor_scale(x: jax.Array, bits: int) -> jax.Array:
    t = jnp.max(jnp.abs(x))
    return jnp.maximum(t, EPS) / qmax(bits)


def crossquant_scale(
    x: jax.Array,
    bits: int,
    alpha: float = 0.15,
    col_max: Optional[jax.Array] = None,
) -> jax.Array:
    """Eq. (5): Δ̃_ij = t_i^α · c_j^(1-α) / qmax.

    ``col_max`` overrides the dynamic column absmax with calibrated statistics
    (static-c CrossQuant — the TPU int8-GEMM-compatible variant, DESIGN.md §3.1).
    Row statistics are always dynamic (they are per-token).

    x may have leading batch dims: rows = second-to-last axis, cols = last axis
    reduced over *all* leading axes (the token axes), matching the paper's
    "column of the activation matrix".
    """
    t = jnp.max(jnp.abs(x), axis=-1, keepdims=True)                     # (..., T, 1)
    if col_max is None:
        reduce_axes = tuple(range(x.ndim - 1))                          # all but channel
        c = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)        # (1, ..., I)
    else:
        c = jnp.asarray(col_max).reshape((1,) * (x.ndim - 1) + (-1,))
    t = jnp.maximum(t, EPS)
    c = jnp.maximum(c, EPS)
    return (t ** alpha) * (c ** (1.0 - alpha)) / qmax(bits)


# ======================================================================================
# Quantizers (scale + codes)
# ======================================================================================

@functools.partial(jax.jit, static_argnames=("bits",))
def per_token_quant(x: jax.Array, bits: int = 8) -> QuantResult:
    return _quantize(x, per_token_scale(x, bits), bits)


@functools.partial(jax.jit, static_argnames=("bits", "axis"))
def per_channel_quant(w: jax.Array, bits: int = 8, axis: int = -1) -> QuantResult:
    return _quantize(w, per_channel_scale(w, bits, axis=axis), bits)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def group_quant(w: jax.Array, bits: int = 4, group_size: int = 128) -> QuantResult:
    """Group-wise weight quantization (the ``g128`` in W4A8-g128).

    Reshapes W ∈ R^{I×O} to (I·O/g, g), scales per group, reshapes codes back.
    The returned ``scale`` broadcasts against the *grouped* view; dequantization is
    handled through :func:`group_dequant` (shape restored).
    """
    shape = w.shape
    grouped = w.reshape(-1, group_size)
    scale = jnp.maximum(jnp.max(jnp.abs(grouped), axis=-1, keepdims=True), EPS) / qmax(bits)
    q = jnp.clip(jnp.round(grouped / scale), -qmax(bits), qmax(bits))
    return QuantResult(
        q.astype(_storage_dtype(bits)).reshape(shape),
        scale.astype(jnp.float32),  # (I*O/g, 1)
        bits,
    )


def group_dequant(qr: QuantResult, group_size: int = 128) -> jax.Array:
    shape = qr.codes.shape
    grouped = qr.codes.reshape(-1, group_size).astype(qr.scale.dtype)
    return (grouped * qr.scale).reshape(shape)


@functools.partial(jax.jit, static_argnames=("bits", "alpha"))
def crossquant(
    x: jax.Array,
    bits: int = 8,
    alpha: float = 0.15,
    col_max: Optional[jax.Array] = None,
) -> QuantResult:
    """CrossQuant (eq. 5). ``alpha=1`` degenerates exactly to per-token quantization;
    ``alpha=0`` to per-(input-)channel quantization of the activation."""
    return _quantize(x, crossquant_scale(x, bits, alpha, col_max), bits)


# ======================================================================================
# Fake quantization (quantize-dequantize in one pass — the paper's evaluation mode)
# ======================================================================================

@functools.partial(jax.jit, static_argnames=("bits",))
def fake_per_token(x: jax.Array, bits: int = 8) -> jax.Array:
    s = per_token_scale(x, bits)
    return (jnp.clip(jnp.round(x / s), -qmax(bits), qmax(bits)) * s).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "alpha"))
def fake_crossquant(
    x: jax.Array, bits: int = 8, alpha: float = 0.15,
    col_max: Optional[jax.Array] = None,
) -> jax.Array:
    """Verbatim port of the paper's App. B.1 reference code (div by t^α then by c^(1-α),
    round, multiply back), expressed as one fused scale."""
    s = crossquant_scale(x, bits, alpha, col_max)
    return (jnp.clip(jnp.round(x / s), -qmax(bits), qmax(bits)) * s).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "axis"))
def fake_per_channel(w: jax.Array, bits: int = 8, axis: int = -1) -> jax.Array:
    s = per_channel_scale(w, bits, axis=axis)
    return (jnp.clip(jnp.round(w / s), -qmax(bits), qmax(bits)) * s).astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def fake_group(w: jax.Array, bits: int = 4, group_size: int = 128) -> jax.Array:
    return group_dequant(group_quant(w, bits, group_size), group_size).astype(w.dtype)
