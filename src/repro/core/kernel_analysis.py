"""Quantization-kernel analysis (paper §4.1, Definition 1).

The *quantization kernel* of a quantization function Q over activation matrix X is

    K(Q) = { X_ij ∈ X : Q(X_ij) = 0 }
         = { X_ij : |X_ij| < B_ij },      B_ij = 0.5 · Δ_ij   (zero bound, eq. 4)

These utilities measure kernel mass, build zero-bound tensors for any scale
construction, implement the paper's "Remove Kernel" ablation (Fig. 1/6/7/9: zero only
the kernel elements, quantize nothing else), and reproduce the Table 1 statistics
(proportion of ``c_j >= t_i`` and of ``B̃ < B``).

Counting convention: the paper's kernel is about *small but non-zero* elements being
destroyed; exact zeros carry no information, and including them only shifts every method
by the same constant. ``count_exact_zeros=False`` (default) excludes them; both modes are
exposed because Fig. 4 proportions are computed over all elements.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quantizers as Q


def zero_bound(scale: jax.Array) -> jax.Array:
    """B = 0.5 · Δ (eq. 4). ``scale`` is the broadcastable Δ tensor."""
    return 0.5 * scale


def kernel_mask(x: jax.Array, scale: jax.Array, *, count_exact_zeros: bool = False) -> jax.Array:
    """Boolean mask of elements in K(Q) under scale Δ: |x| < 0.5·Δ."""
    in_kernel = jnp.abs(x) < zero_bound(scale)
    if not count_exact_zeros:
        in_kernel = jnp.logical_and(in_kernel, x != 0)
    return in_kernel


def kernel_fraction(x: jax.Array, scale: jax.Array, *, count_exact_zeros: bool = True) -> jax.Array:
    """|K(Q)| / |X| — the quantity plotted in Fig. 4."""
    mask = kernel_mask(x, scale, count_exact_zeros=count_exact_zeros)
    return jnp.mean(mask.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("bits",))
def per_token_kernel_fraction(x: jax.Array, bits: int = 8) -> jax.Array:
    return kernel_fraction(x, Q.per_token_scale(x, bits))


@functools.partial(jax.jit, static_argnames=("bits", "alpha"))
def crossquant_kernel_fraction(x: jax.Array, bits: int = 8, alpha: float = 0.15) -> jax.Array:
    return kernel_fraction(x, Q.crossquant_scale(x, bits, alpha))


def remove_kernel(x: jax.Array, scale: jax.Array) -> jax.Array:
    """The paper's "Remove Kernel" ablation: zero the kernel, keep the rest *unquantized*.

    Fig. 1/9 show this alone reproduces essentially the whole A8 accuracy drop — the
    central empirical claim that the kernel (not the outliers directly) is the cause.
    """
    return jnp.where(kernel_mask(x, scale, count_exact_zeros=True), 0.0, x).astype(x.dtype)


def remove_kernel_fraction(x: jax.Array, fraction: float) -> jax.Array:
    """Zero the smallest-|x| ``fraction`` of elements (Fig. 6/7 threshold sweeps).

    Uses a global magnitude quantile as the zero bound so the removed proportion is
    controlled directly, matching "setting different proportion of quantization kernels
    to zero".
    """
    flat = jnp.abs(x).reshape(-1)
    thresh = jnp.quantile(flat, fraction)
    return jnp.where(jnp.abs(x) <= thresh, 0.0, x).astype(x.dtype)


# ======================================================================================
# Table 1 statistics
# ======================================================================================

@functools.partial(jax.jit, static_argnames=("bits", "alpha"))
def table1_stats(x: jax.Array, bits: int = 8, alpha: float = 0.15) -> dict:
    """Reproduces the three row-statistics of Table 1 for one activation matrix:

    * proportion of positions with ``c_j >= t_i``   (case II of the §4.2 proof),
    * proportion with ``B̃_ij < B_ij``               (kernel-shrinking positions),
    * kernel fraction of CrossQuant and of per-token quantization.
    """
    t = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), Q.EPS)   # (..., T, 1)
    reduce_axes = tuple(range(x.ndim - 1))
    c = jnp.maximum(jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True), Q.EPS)

    c_ge_t = jnp.mean((c >= t).astype(jnp.float32) * jnp.ones_like(x))
    b_pt = zero_bound(t / Q.qmax(bits))
    b_cq = zero_bound((t ** alpha) * (c ** (1 - alpha)) / Q.qmax(bits))
    b_shrunk = jnp.mean((b_cq < b_pt).astype(jnp.float32) * jnp.ones_like(x))

    return {
        "c_ge_t": c_ge_t,
        "bcq_lt_bpt": b_shrunk,
        "kernel_crossquant": kernel_fraction(x, Q.crossquant_scale(x, bits, alpha)),
        "kernel_per_token": kernel_fraction(x, Q.per_token_scale(x, bits)),
    }


# ======================================================================================
# Activation capture: measure kernel fractions inside a running model
# ======================================================================================

class KernelStats:
    """Accumulates kernel fractions over many activation matrices (host side)."""

    def __init__(self, bits: int = 8, alpha: float = 0.15):
        self.bits = bits
        self.alpha = alpha
        self.per_token: list[float] = []
        self.crossquant: list[float] = []

    def observe(self, x: jax.Array) -> None:
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        self.per_token.append(float(per_token_kernel_fraction(x2, self.bits)))
        self.crossquant.append(float(crossquant_kernel_fraction(x2, self.bits, self.alpha)))

    def summary(self) -> dict:
        import numpy as np
        return {
            "per_token_mean": float(np.mean(self.per_token)) if self.per_token else 0.0,
            "crossquant_mean": float(np.mean(self.crossquant)) if self.crossquant else 0.0,
            "n": len(self.per_token),
        }
