"""Core quantization science: the paper's contribution as composable JAX modules."""
from repro.core.quantizers import (  # noqa: F401
    QuantResult, qmax, per_token_quant, per_channel_quant, group_quant, crossquant,
    per_token_scale, per_channel_scale, crossquant_scale, group_dequant,
    fake_per_token, fake_crossquant, fake_per_channel, fake_group,
)
from repro.core.kernel_analysis import (  # noqa: F401
    zero_bound, kernel_mask, kernel_fraction, remove_kernel, remove_kernel_fraction,
    table1_stats, KernelStats,
)
from repro.core.qlinear import (  # noqa: F401
    QuantConfig, FP, W8A8_CROSSQUANT, W8A8_PER_TOKEN, W4A8_G128, W4A4, W8A8_INT8,
)
