"""AWQ baseline (Lin et al., 2024) — the paper's weight-only W4 baseline.

AWQ protects *salient* weight channels (those multiplying large activations) by
scaling them up before group quantization and dividing back after:

    W' = deq(quant_g128(W · s)) / s          s_j = cmax_j^alpha

which is exact w.r.t. the matmul when paired with X/s on the activation side — AWQ
folds the division into the previous op and serves FP16 activations, so here the
activation side stays untouched (weight-only). The per-layer exponent ``alpha`` is
grid-searched to minimize activation-weighted reconstruction error

    || diag(cmax) · (W - W') ||_F

with cmax (per-input-channel activation absmax) as the data surrogate, exactly
AWQ's search objective collapsed onto its official scale parameterization.

The paper combines CrossQuant activations with AWQ weights (Table 2,
"CrossQuant+AWQ") — reproduced in benchmarks/table2_ppl.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantizers as Q

ALPHA_GRID = (0.0, 0.25, 0.5, 0.75, 1.0)


def _fake_group_cols(w: jax.Array, bits: int, group: int) -> jax.Array:
    """Group quantization along the input axis (rows), per output column —
    the g128 layout of W4A8-g128 (matches qlinear.prepare_int4)."""
    d_in, d_out = w.shape[-2], w.shape[-1]
    g = min(group, d_in)
    if d_in % g:
        return Q.fake_group(w, bits, group)        # fallback: flat grouping
    lead = w.shape[:-2]
    grouped = w.reshape(*lead, d_in // g, g, d_out)
    scale = jnp.maximum(jnp.abs(grouped).max(axis=-2, keepdims=True), Q.EPS) / Q.qmax(bits)
    q = jnp.clip(jnp.round(grouped / scale), -Q.qmax(bits), Q.qmax(bits))
    return (q * scale).reshape(w.shape)


def awq_weight(w: jax.Array, cmax: jax.Array, *, bits: int = 4,
               group: int = 128, alphas=ALPHA_GRID) -> jax.Array:
    """Return the AWQ fake-quantized weight (best-alpha scale-protect-quantize).

    w: (..., d_in, d_out); cmax: (d_in,) activation column absmax."""
    cm = jnp.maximum(cmax.astype(jnp.float32), Q.EPS)
    cm = cm / jnp.exp(jnp.mean(jnp.log(cm)))        # normalize (AWQ convention)
    best_w, best_err = None, None
    for alpha in alphas:
        s = cm ** alpha
        wq = _fake_group_cols(w * s[..., :, None], bits, group) / s[..., :, None]
        err = jnp.sum((cm[..., :, None] * (w - wq)) ** 2)
        if best_err is None:
            best_w, best_err = wq, err
        else:
            take = err < best_err
            best_w = jnp.where(take, wq, best_w)
            best_err = jnp.minimum(err, best_err)
    return best_w.astype(w.dtype)
