"""Bit/nibble packing: N:M sparsity masks (1 bit/element) and int4 codes (2/byte).

Mask packing (DESIGN.md §3.12): the structured-sparsity ``mask`` leaf stores the
N:M keep-mask at one bit per weight element, packed along d_in so the d_out axis
keeps its dense length (column-parallel sharding splits it untouched; a
row-parallel split lands on the packed axis at byte granularity, mirroring the
int4 contract below, and degrades to replication when tp does not divide it).

INT4 nibble packing: two signed 4-bit codes per int8 byte.

Layout: element 2k goes to the low nibble, element 2k+1 to the high nibble, packed
along ``axis`` (default: the last axis, contiguous in HBM), halving weight bytes for
the W4A8-g128 and W4A4 configurations. The Pallas qgemm_w4 kernel unpacks in VMEM.

Sharding contract (DESIGN.md §3.7): a packed axis may be split over the model mesh
axis only at byte granularity — the planner checks divisibility against the *packed*
length (``d_in // 2`` for ``qw4``), so every shard holds whole bytes and unpacking
is shard-local (no nibble ever straddles two devices).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_mask(mask: jax.Array, axis: int = -2) -> jax.Array:
    """Pack a {0,1} keep-mask to one bit per element along ``axis`` (default: the
    weight's d_in axis), big-endian within each uint8 byte. The packed axis has
    length ``ceil(d_in / 8)``; trailing pad bits are zero, so a popcount of the
    packed array equals the survivor count exactly (models/quantize.py relies on
    this for deployment-size accounting)."""
    return jnp.packbits(mask.astype(jnp.uint8), axis=axis)


def unpack_mask(packed: jax.Array, count: int, axis: int = -2) -> jax.Array:
    """Inverse of :func:`pack_mask`: uint8 {0,1} mask with ``count`` rows along
    ``axis`` (the pad bits are dropped)."""
    return jnp.unpackbits(packed, axis=axis, count=count)


def pack_int4(codes: jax.Array, axis: int = -1) -> jax.Array:
    """Pack int8-held int4 codes (range [-8, 7]) pairwise along ``axis``."""
    codes = jnp.moveaxis(codes, axis, -1)
    assert codes.shape[-1] % 2 == 0, "pack axis must be even"
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    packed = ((hi.astype(jnp.int8) << 4) | (lo.astype(jnp.int8) & 0x0F)).astype(jnp.int8)
    return jnp.moveaxis(packed, -1, axis)


def unpack_int4(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_int4` (sign-extends both nibbles)."""
    packed = jnp.moveaxis(packed, axis, -1)
    lo = (packed << 4).astype(jnp.int8) >> 4          # sign-extend low nibble
    hi = packed >> 4                                   # arithmetic shift: high nibble
    out = jnp.stack([lo, hi], axis=-1)
    out = out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
    return jnp.moveaxis(out, -1, axis)
