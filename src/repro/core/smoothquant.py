"""SmoothQuant baseline (Xiao et al., 2023) — the paper's strongest W8A8 baseline.

SmoothQuant migrates quantization difficulty from activations to weights via a
per-channel smoothing factor computed offline from calibration statistics:

    s_j = max|X_:,j|^alpha / max|W_j,:|^(1-alpha)
    X' = X / s,   W' = s ⊙ W          (mathematically exact:  X'W' = XW)

after which X' is per-token quantized and W' per-channel quantized. The paper uses
alpha=0.8 for LLaMA and 0.5 for OPT (App. B.1); we default to 0.5.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quantizers as Q


def smoothing_scale(act_col_max: jax.Array, w_row_max: jax.Array, alpha: float = 0.5) -> jax.Array:
    """Per-input-channel smoothing factor s_j. Both stats are length-I vectors."""
    a = jnp.maximum(act_col_max, Q.EPS)
    w = jnp.maximum(w_row_max, Q.EPS)
    s = (a ** alpha) / (w ** (1.0 - alpha))
    return jnp.maximum(s, Q.EPS)


def smooth_pair(x: jax.Array, w: jax.Array, s: jax.Array):
    """Apply the exact-equivalence transform: returns (X/s, s·W)."""
    return x / s, w * s[:, None]


@functools.partial(jax.jit, static_argnames=("bits_a", "bits_w"))
def smoothquant_matmul_fake(
    x: jax.Array, w: jax.Array, s: jax.Array, bits_a: int = 8, bits_w: int = 8
) -> jax.Array:
    """Fake-quant SmoothQuant GEMM: smooth → per-token A-quant → per-channel W-quant."""
    xs, ws = smooth_pair(x, w, s)
    xq = Q.fake_per_token(xs, bits_a)
    wq = Q.fake_per_channel(ws, bits_w, axis=-1)
    return xq @ wq
