"""Calibration pass for static-c CrossQuant and SmoothQuant.

CrossQuant's column statistic ``c_j = max|X_:,j|`` is dynamic in the paper (computed per
batch). The int8 MXU path (DESIGN.md §3.1) freezes it from a calibration set, exactly as
SmoothQuant freezes its smoothing factors. The calibrator records running column absmax
per named linear layer during eager forward passes over calibration batches.

Observers are host-side (eager-mode only): calibration runs once, offline, on a handful
of batches — it is not a jit-path concern.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Observer:
    """Running per-channel absmax (and optional quantile) per linear-layer name."""

    def __init__(self, momentum: Optional[float] = None):
        # momentum=None -> hard max over all batches (paper-style absolute max).
        # momentum in (0,1) -> EMA of per-batch max (robust to single-batch spikes).
        self.momentum = momentum
        self.col_max: Dict[str, np.ndarray] = {}
        self.n_obs: Dict[str, int] = {}

    def observe(self, name: str, x: jax.Array) -> None:
        flat = np.asarray(jnp.abs(x).reshape(-1, x.shape[-1]).max(axis=0), dtype=np.float32)
        if name not in self.col_max:
            self.col_max[name] = flat
            self.n_obs[name] = 1
            return
        if self.momentum is None:
            self.col_max[name] = np.maximum(self.col_max[name], flat)
        else:
            m = self.momentum
            self.col_max[name] = m * self.col_max[name] + (1 - m) * flat
        self.n_obs[name] += 1

    def tables(self) -> Dict[str, np.ndarray]:
        return dict(self.col_max)


def calibrate(apply_fn, params, batches, observer: Optional[Observer] = None) -> Observer:
    """Run ``apply_fn(params, batch, observer=obs)`` eagerly over calibration batches.

    ``apply_fn`` must thread the observer down to its quantized linears (the model zoo
    does this through QuantContext). Returns the filled observer.
    """
    obs = observer or Observer()
    for batch in batches:
        apply_fn(params, batch, obs)
    return obs


def stack_tables(tables: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Convert observer names to parameter-tree paths.

    Observer names from the unroll path look like ``/L{b}/S{i}/attn/wq`` (layer b,
    sublayer i); the matching parameter lives at ``blocks/{i}/attn/wq`` as a
    *stacked* (n_blocks, ...) array — so per-layer tables are stacked along a new
    leading axis. Tail layers ``/T{i}/...`` map to ``tail/{i}/...``; the hybrid
    shared block keeps a single merged table (weight sharing)."""
    import re
    out: Dict[str, np.ndarray] = {}
    grouped: Dict[tuple, Dict[int, np.ndarray]] = {}
    for name, v in tables.items():
        m = re.match(r"^/L(\d+)/S(\d+)/(.*)$", name)
        if m:
            b, i, rest = int(m.group(1)), int(m.group(2)), m.group(3)
            grouped.setdefault((i, rest), {})[b] = v
            continue
        m = re.match(r"^/T(\d+)/(.*)$", name)
        if m:
            out[f"tail/{m.group(1)}/{m.group(2)}"] = v
            continue
        if name.startswith("/shared_attn/"):
            out["shared_attn/attn/" + name[len("/shared_attn/"):]] = v
            continue
        if name.startswith("/shared_mlp/"):
            out["shared_attn/mlp/" + name[len("/shared_mlp/"):]] = v
            continue
        out[name.lstrip("/")] = v
    for (i, rest), per_layer in grouped.items():
        n = max(per_layer) + 1
        if len(per_layer) == n:
            out[f"blocks/{i}/{rest}"] = np.stack([per_layer[b] for b in range(n)])
    return out


def attach_calibration(params, tables: Dict[str, np.ndarray]):
    """Insert ``cmax`` leaves into a params pytree of named linears.

    Params layout convention (see models/): every quantized linear owns a dict
    ``{"w": ...}`` reachable at path ``a/b/c``; the observer key is that joined path.
    """
    # Build a mutable nested copy.
    import copy
    out = copy.deepcopy(jax.tree_util.tree_map(lambda x: x, params))

    def set_path(root, path_parts, key, value):
        node = root
        for p in path_parts:
            node = node[p]
        node[key] = value

    for name, cmax in tables.items():
        parts = name.split("/")
        try:
            set_path(out, parts, "cmax", jnp.asarray(cmax))
        except (KeyError, TypeError):
            continue
    return out
