"""Runtime substrate: supervised training with checkpoint/restart fault tolerance,
straggler mitigation via deadline barriers, and elastic mesh rebuild."""
from repro.runtime.supervisor import (  # noqa: F401
    FailureInjector, ReplicaHealth, RestartTracker, Supervisor, WorkerFailure)
from repro.runtime.straggler import DeadlineBarrier, HeartbeatTracker  # noqa: F401
