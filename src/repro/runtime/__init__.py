"""Runtime substrate: supervised training with checkpoint/restart fault tolerance,
straggler mitigation via deadline barriers, and elastic mesh rebuild."""
from repro.runtime.supervisor import Supervisor, WorkerFailure, FailureInjector  # noqa: F401
from repro.runtime.straggler import DeadlineBarrier, HeartbeatTracker  # noqa: F401
