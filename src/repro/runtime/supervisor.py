"""Supervised training loop: failure detection → restore → (possibly elastic) rebuild
→ continue.

The deployable control flow is exactly what a 1000-node cluster controller runs; this
module keeps it in one process so integration tests can exercise it end-to-end:

  1. the train loop body is a *worker function* the supervisor calls per step;
  2. a :class:`FailureInjector` raises :class:`WorkerFailure` at configured steps —
     the stand-in for a real node loss / preemption signal;
  3. on failure, the supervisor (a) waits for outstanding async checkpoint writes,
     (b) restores the last committed step, (c) asks its ``rebuild`` callback for a new
     mesh + resharded state (elastic: the surviving-host count may have shrunk or
     grown), and (d) resumes from the restored step;
  4. a bounded retry budget prevents crash loops (real controllers page a human).

Determinism contract tested in tests/test_runtime.py: a run with injected failures
produces bitwise-identical params to an uninterrupted run, because (seed, step)
reproduces batches and the checkpoint restores exact optimizer state.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.supervisor")


class WorkerFailure(RuntimeError):
    """A worker (host/device) failed — node loss, preemption, ICI link error."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically raise WorkerFailure at the given steps (test/chaos hook)."""
    fail_at_steps: Sequence[int] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class RestartTracker:
    """Bounded-restart accounting shared by the training supervisor and the
    serving front end's replica manager (DESIGN.md §3.11): ``record(err)``
    counts one failure and raises once the budget is exhausted — real
    controllers page a human at that point instead of crash-looping."""

    max_restarts: int = 8
    restarts: int = 0

    def record(self, err: BaseException, what: str = "worker") -> None:
        self.restarts += 1
        log.warning("%s failure (%s); restart %d/%d", what, err,
                    self.restarts, self.max_restarts)
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"restart budget exhausted ({self.max_restarts})") from err

    @property
    def exhausted(self) -> bool:
        return self.restarts > self.max_restarts


@dataclasses.dataclass
class ReplicaHealth:
    """One serving replica's health record (serving/server.py): lifecycle
    ``state`` (``"live"`` / ``"restarting"`` / ``"dead"``), restart count,
    engine steps driven since the last restart, and the last failure seen."""

    state: str = "live"
    restarts: int = 0
    steps: int = 0
    last_error: Optional[str] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RunResult:
    state: Any
    step: int
    restarts: int
    metrics_history: List[Dict[str, float]]


class Supervisor:
    """Drives ``step_fn`` from ``start_step`` to ``total_steps`` with fault tolerance.

    step_fn(state, step) -> (state, metrics)        pure training step + data fetch
    rebuild(state_template) -> state                 restore-time re-layout hook
                                                     (elastic mesh change); receives
                                                     the host-restored pytree.
    """

    def __init__(self, ckpt: CheckpointManager, *, ckpt_every: int = 10,
                 max_restarts: int = 8):
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Tuple[Any, Dict[str, float]]],
        total_steps: int,
        *,
        start_step: int = 0,
        injector: Optional[FailureInjector] = None,
        rebuild: Optional[Callable[[Any], Any]] = None,
        save_initial: bool = True,
    ) -> RunResult:
        tracker = RestartTracker(max_restarts=self.max_restarts)
        step = start_step
        history: List[Dict[str, float]] = []
        if save_initial:
            self.ckpt.save(step, state, blocking=True)

        while step < total_steps:
            try:
                if injector is not None:
                    injector.check(step)
                state, metrics = step_fn(state, step)
                history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_every == 0 or step == total_steps:
                    self.ckpt.save(step, state)
            except WorkerFailure as e:
                tracker.record(e, what=f"worker at step {step}")
                # Synchronize outstanding async writes, then restore the last commit.
                self.ckpt.wait()
                state, step = self.ckpt.restore(state)
                if rebuild is not None:
                    state = rebuild(state)
                # Truncate history past the restore point (those steps re-run).
                history = [h for h in history if h["step"] < step]
        self.ckpt.wait()
        return RunResult(state=state, step=step, restarts=tracker.restarts,
                         metrics_history=history)
