"""Straggler mitigation: per-step host heartbeats + a p-quantile deadline barrier.

At pod scale, a single slow host (thermal throttling, a bad HBM stack, a noisy
neighbour on shared NICs) serializes every synchronous collective. The deployable
mechanism:

  * every host reports a per-step heartbeat duration;
  * the barrier computes a deadline = quantile(history, p) × slack;
  * hosts exceeding the deadline are marked *suspect*; ``k`` consecutive misses
    escalates to the supervisor, which triggers an elastic reconfiguration that
    excludes the host (runtime/elastic.py + Supervisor.rebuild).

The single-process edition drives it with simulated per-host durations (injected
delays in tests); the accounting, thresholds and escalation logic are the deployable
part — on a real cluster the durations come from the coordinator's RPC layer.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class HeartbeatTracker:
    """Sliding window of per-host step durations."""
    n_hosts: int
    window: int = 64

    def __post_init__(self):
        self._hist: List[collections.deque] = [
            collections.deque(maxlen=self.window) for _ in range(self.n_hosts)]

    def report(self, host: int, duration: float) -> None:
        self._hist[host].append(duration)

    def all_durations(self) -> np.ndarray:
        flat = [d for h in self._hist for d in h]
        return np.asarray(flat if flat else [0.0])


class DeadlineBarrier:
    """p99-style deadline barrier with consecutive-miss escalation.

    ``step(durations)`` ingests one step's per-host durations and returns the set of
    hosts to evict (those with ≥ ``evict_after`` consecutive deadline misses).
    """

    def __init__(self, n_hosts: int, *, quantile: float = 0.99, slack: float = 1.5,
                 evict_after: int = 3, min_history: int = 8):
        self.tracker = HeartbeatTracker(n_hosts)
        self.quantile = quantile
        self.slack = slack
        self.evict_after = evict_after
        self.min_history = min_history
        self.misses = np.zeros(n_hosts, np.int32)
        self.suspect: set = set()

    def deadline(self) -> Optional[float]:
        hist = self.tracker.all_durations()
        if hist.size < self.min_history:
            return None                       # not enough signal yet
        return float(np.quantile(hist, self.quantile) * self.slack)

    def step(self, durations: Sequence[float]) -> Dict[str, object]:
        dl = self.deadline()
        evict: List[int] = []
        for host, dur in enumerate(durations):
            late = dl is not None and dur > dl
            if late:
                self.misses[host] += 1
                self.suspect.add(host)
            else:
                self.misses[host] = 0
                self.suspect.discard(host)
            if self.misses[host] >= self.evict_after:
                evict.append(host)
            # Late hosts' durations poison the quantile if recorded raw; record the
            # deadline instead (standard winsorization).
            self.tracker.report(host, min(dur, dl) if dl is not None else dur)
        return {"deadline": dl, "suspect": set(self.suspect), "evict": evict}
