"""Elastic mesh rebuild: shrink/grow the device mesh and reshard live state.

When the supervisor evicts a straggler or loses a host, the surviving device count
changes; training continues on a *smaller* (or, after repair, larger) mesh. The moving
parts:

  * ``plan_mesh(n_devices, model_parallel)`` — choose the largest (data, model) grid
    over the surviving devices, holding the model axis fixed (TP degree is a property
    of the weight layout; DP absorbs elasticity, as in production systems).
  * ``reshard(tree, old → new shardings)`` — device_put against the new mesh; with the
    checkpoint manager the same path handles restore-time elasticity.

The assigned production mesh is (data=16, model=16); losing one host of 8 chips drops
data 16 → 15 if 15 divides the batch, else to the largest divisor — ``usable_dp``
encodes that global-batch divisibility rule.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def usable_dp(n_avail_dp: int, global_batch: int) -> int:
    """Largest dp ≤ n_avail_dp dividing global_batch (keeps per-replica batch whole)."""
    for dp in range(min(n_avail_dp, global_batch), 0, -1):
        if global_batch % dp == 0:
            return dp
    return 1


def plan_mesh_shape(n_devices: int, model_parallel: int,
                    global_batch: Optional[int] = None) -> Tuple[int, int]:
    """(data, model) for the surviving device count; model axis held fixed."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep tp={model_parallel} with {n_devices} devices — "
            f"weight layout requires at least one full model-parallel group")
    dp = n_devices // model_parallel
    if global_batch is not None:
        dp = usable_dp(dp, global_batch)
    return dp, model_parallel


def make_elastic_mesh(devices, model_parallel: int,
                      global_batch: Optional[int] = None) -> Mesh:
    dp, tp = plan_mesh_shape(len(devices), model_parallel, global_batch)
    grid = np.asarray(devices[: dp * tp]).reshape(dp, tp)
    return Mesh(grid, ("data", "model"))


def reshard(tree, shardings):
    """Lay out ``tree`` (host or device arrays) against new shardings (new mesh)."""
    return jax.tree_util.tree_map(lambda a, s: jax.device_put(a, s), tree, shardings)
