"""Pallas TPU kernel: fused CrossQuant activation quantization (static-c path).

Computes, in a single kernel over x (M, K):

    t_i = max_j |x_ij|                         (row absmax)
    a_i = t_i^alpha / qmax                     (CrossQuant row dequant factor)
    q_ij = clip(round(x_ij / (a_i · qmax? no — a_i) / bcol_j))   int8 codes

where ``bcol_j = c_j^(1-alpha)`` comes from calibration (DESIGN.md §3.1). Per-token
quantization is the ``alpha=1, bcol=1`` special case — the kernel covers both.

Two-phase grid: the K axis is swept twice per row block — phase 0 reduces the row
absmax into a VMEM scratch column, phase 1 re-reads the same x blocks and emits codes.
The phase axis is the middle grid dimension so (row, 0, k0..kn, 1, k0..kn) revisits the
scratch in order. One extra HBM read of x versus an unfused XLA reduction+divide chain,
but no (M, K) f32 intermediate is ever materialized — the codes leave VMEM as int8,
which is the whole point on a memory-bound layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _act_quant_kernel(x_ref, bcol_ref, *refs,
                      n_k: int, alpha, qmax: int, eps: float):
    """``alpha`` is either a static float or ``None`` — in the latter case the exponent
    arrives as a (1, 1) SMEM scalar input (``alpha_ref``), so one compiled kernel
    serves every linear in a scanned layer stack even when the prepared tree carries
    per-layer ``qalpha`` leaves (DESIGN.md §3.3)."""
    if alpha is None:
        alpha_ref, q_ref, a_ref, t_ref = refs
    else:
        q_ref, a_ref, t_ref = refs
    phase = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((phase == 0) & (k == 0))
    def _init():
        t_ref[...] = jnp.full_like(t_ref, eps)

    @pl.when(phase == 0)
    def _reduce():
        blk_max = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)), axis=1,
                          keepdims=True)
        t_ref[...] = jnp.maximum(t_ref[...], blk_max)

    @pl.when(phase == 1)
    def _quantize():
        a_exp = alpha_ref[0, 0] if alpha is None else alpha
        a = (t_ref[...] ** a_exp) / qmax                    # (bm, 1)
        x = x_ref[...].astype(jnp.float32)
        q = jnp.round(x / (a * bcol_ref[...]))
        q_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int8)

        @pl.when(k == n_k - 1)
        def _emit_scale():
            a_ref[...] = a


def act_quantize_pallas(
    x: jax.Array, bcol: jax.Array, *, bits: int = 8, alpha=0.15,
    bm: int = 256, bk: int = 512, interpret: bool = False,
):
    """x (M, K) float → (codes (M, K) int8, a (M, 1) f32). M % bm == K % bk == 0.

    ``alpha`` may be a python float (baked into the kernel) or a jax scalar array
    (runtime SMEM input — the fused serving path threads the prepared tree's
    per-layer ``qalpha`` leaf through here).
    """
    M, K = x.shape
    assert M % bm == 0 and K % bk == 0, (M, K, bm, bk)
    qmax = 2 ** (bits - 1) - 1
    n_k = K // bk
    grid = (M // bm, 2, n_k)
    dyn_alpha = isinstance(alpha, jax.Array)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda m, p, k: (m, k)),
        pl.BlockSpec((1, bk), lambda m, p, k: (0, k)),
    ]
    operands = [x, bcol.reshape(1, K)]
    if dyn_alpha:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        operands.append(jnp.asarray(alpha, jnp.float32).reshape(1, 1))
    return pl.pallas_call(
        functools.partial(_act_quant_kernel, n_k=n_k,
                          alpha=None if dyn_alpha else alpha, qmax=qmax,
                          eps=1e-8),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, bk), lambda m, p, k: (m, k)),
            pl.BlockSpec((bm, 1), lambda m, p, k: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.int8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
    )(*operands)
