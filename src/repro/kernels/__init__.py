"""Pallas TPU kernels for CrossQuant's compute hot-spots.

  qgemm.py         int8/int4 MXU GEMMs with fused output-side dequant
  act_quantize.py  fused row-absmax + CrossQuant quantization (one HBM pass)
  ops.py           jit'd public wrappers (padding, backend dispatch)
  ref.py           pure-jnp oracles — the semantic ground truth for every kernel

Kernels are validated on CPU with ``interpret=True`` against ``ref.py`` (shape/dtype
sweeps + hypothesis, tests/test_kernels.py). The dry-run lowers the reference path:
CPU cannot lower Mosaic, and HLO cost analysis is identical for the same semantics.
"""
