"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes and
asserts allclose against the function here. They are also the path the multi-pod
dry-run lowers (the CPU backend cannot lower TPU Mosaic kernels; HLO cost analysis is
identical for the reference semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import quantizers as Q


def qgemm_w8a8_ref(qx: jax.Array, qw: jax.Array, a: jax.Array, sw: jax.Array) -> jax.Array:
    """int8 GEMM with separable dequant.

    qx: (M, K) int8 codes; qw: (K, N) int8 codes;
    a:  (M, 1) f32 row dequant scale (CrossQuant t_i^alpha / qmax);
    sw: (N,)  f32 col dequant scale (per-output-channel weight scale, b-folded).
    Returns (M, N) f32:  (qx · qw) * a * sw.
    """
    acc = jax.lax.dot_general(
        qx, qw, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * a * sw


def qgemm_w8a8_sparse_ref(qx: jax.Array, qw: jax.Array, a: jax.Array, sw: jax.Array,
                          mask: jax.Array) -> jax.Array:
    """N:M block-sparse int8 GEMM oracle: the masked dense GEMM.

    mask: (K, N) {0,1} keep-mask (unpacked). Semantic ground truth for the sparse
    kernel at *any* block size: the kernel only ever skips weight blocks whose
    mask is entirely zero, and a zero int8 block contributes exactly 0 to the
    int32 accumulator — so masking the operand is the whole contract. ``qw`` is
    already zero where the mask is (prepare-time pruning); the multiply here
    makes the oracle robust to deliberately inconsistent test inputs.
    """
    return qgemm_w8a8_ref(qx, qw * mask.astype(qw.dtype), a, sw)


def qgemm_w4a8_ref(qx: jax.Array, qw4: jax.Array, a: jax.Array, sw: jax.Array,
                   group: int = 128) -> jax.Array:
    """W4A8 grouped GEMM.

    qx:  (M, K) int8; qw4: (K//2, N) int8 (two int4 codes per byte, packed along K);
    a:   (M, 1) f32; sw: (K//group, N) f32 per-group weight scales.
    Per-group int32 partial sums dequantized by sw[g] then reduced over groups.
    """
    K = qx.shape[-1]
    qw = packing.unpack_int4(qw4, axis=-2)              # (K, N) int8 in [-8, 7]
    ngroups = K // group
    qx_g = qx.reshape(*qx.shape[:-1], ngroups, group)
    qw_g = qw.reshape(ngroups, group, qw.shape[-1])
    acc = jnp.einsum("mgk,gkn->mgn", qx_g.astype(jnp.int32),
                     qw_g.astype(jnp.int32))            # (M, G, N)
    y = (acc.astype(jnp.float32) * sw).sum(axis=-2)
    return y * a


def act_quantize_ref(x: jax.Array, bcol: jax.Array, bits: int = 8,
                     alpha: float = 0.15):
    """Fused CrossQuant activation quantization (static-c path).

    x: (M, K) float; bcol: (K,) f32 = c_j^(1-alpha) from calibration.
    Returns (codes (M,K) int8, a (M,1) f32) with codes = clip(round(x / (a·qmax·bcol))).
    Exactly `qlinear.quantize_act_int8`.
    """
    qm = Q.qmax(bits)
    t = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), Q.EPS)
    a = (t.astype(jnp.float32) ** alpha) / qm
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / (a * bcol)), -qm, qm)
    return q.astype(jnp.int8), a


def paged_decode_attention_ref(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, kv_len: jax.Array, *,
    k_scale_pages: jax.Array | None = None,
    v_scale_pages: jax.Array | None = None,
    window: int | None = None, softcap: float | None = None) -> jax.Array:
    """Paged single-token decode attention oracle (DESIGN.md §3.8).

    q: (B, Hkv, G, D) grouped query heads; k/v pages: (P, ps, Hkv, D) physical
    pools; page_table: (B, maxP) int32 logical→physical map (entries ≥ P are
    invalid: clamped here, masked by kv_len); kv_len: (B,) valid lengths with
    the newest token at kv_len - 1. Gathers the logical (B, maxP·ps, Hkv, D)
    view and runs plain-softmax attention in f32 → (B, Hkv, G, D).

    ``k_scale_pages``/``v_scale_pages`` (P, ps, Hkv, 1) f32: the pools hold
    int8 codes and per-token scales. The gathered scale view multiplies the
    score column / probability row exactly as the dense
    ``layers.decode_attention`` int8 path does (scale → softcap → mask →
    softmax) — this *is* the dense int8-KV numerics on the logical view, the
    semantic ground truth the fused in-kernel dequant must match.
    """
    P, ps = k_pages.shape[0], k_pages.shape[1]
    B, maxP = page_table.shape
    D = q.shape[-1]
    gidx = jnp.clip(page_table[:, :, None] * ps + jnp.arange(ps)[None, None, :],
                    0, P * ps - 1).reshape(B, maxP * ps)
    kf = k_pages.reshape(P * ps, *k_pages.shape[2:])[gidx].astype(jnp.float32)
    vf = v_pages.reshape(P * ps, *v_pages.shape[2:])[gidx].astype(jnp.float32)

    def score_scales(pool):        # (P, ps, Hkv, 1) → (B, Hkv, 1, T) broadcast
        flat = pool.reshape(P * ps, pool.shape[2])[gidx]          # (B, T, Hkv)
        return jnp.transpose(flat, (0, 2, 1))[:, :, None, :]

    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32), kf) * (D ** -0.5)
    if k_scale_pages is not None:
        s = s * score_scales(k_scale_pages)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    t_pos = jnp.arange(maxP * ps)[None, None, None, :]
    cl = kv_len.reshape(-1, 1, 1, 1)
    valid = t_pos < cl
    if window is not None:
        valid &= (cl - 1 - t_pos) < window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale_pages is not None:
        p = p * score_scales(v_scale_pages)
    return jnp.einsum("bhgt,bthd->bhgd", p, vf).astype(q.dtype)


def paged_verify_attention_ref(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, kv_len: jax.Array, q_len: jax.Array, *,
    k_scale_pages: jax.Array | None = None,
    v_scale_pages: jax.Array | None = None,
    window: int | None = None, softcap: float | None = None) -> jax.Array:
    """Draft-window verify attention oracle (DESIGN.md §3.9).

    q: (B, Hkv, W, G, D) — W window tokens per slot, already scattered into the
    pools; kv_len: (B,) total post-scatter length; q_len: (B,) valid window
    rows (1 ≤ q_len ≤ W), window token i at absolute position
    ``kv_len - q_len + i``. Per-row causal mask over the gathered logical view,
    otherwise exactly :func:`paged_decode_attention_ref` — W == 1 with
    q_len == 1 is bitwise the decode oracle. Rows ≥ q_len clamp to the newest
    valid position (garbage-but-finite, discarded by callers).
    → (B, Hkv, W, G, D).
    """
    P, ps = k_pages.shape[0], k_pages.shape[1]
    B, maxP = page_table.shape
    W, D = q.shape[2], q.shape[-1]
    gidx = jnp.clip(page_table[:, :, None] * ps + jnp.arange(ps)[None, None, :],
                    0, P * ps - 1).reshape(B, maxP * ps)
    kf = k_pages.reshape(P * ps, *k_pages.shape[2:])[gidx].astype(jnp.float32)
    vf = v_pages.reshape(P * ps, *v_pages.shape[2:])[gidx].astype(jnp.float32)

    def score_scales(pool):    # (P, ps, Hkv, 1) → (B, Hkv, 1, 1, T) broadcast
        flat = pool.reshape(P * ps, pool.shape[2])[gidx]          # (B, T, Hkv)
        return jnp.transpose(flat, (0, 2, 1))[:, :, None, None, :]

    s = jnp.einsum("bhwgd,bthd->bhwgt", q.astype(jnp.float32), kf) * (D ** -0.5)
    if k_scale_pages is not None:
        s = s * score_scales(k_scale_pages)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kvl = kv_len.astype(jnp.int32)
    qln = q_len.astype(jnp.int32)
    q_pos = ((kvl - qln)[:, None]
             + jnp.minimum(jnp.arange(W)[None, :], (qln - 1)[:, None]))  # (B, W)
    t_pos = jnp.arange(maxP * ps)[None, None, None, None, :]
    qp = q_pos[:, None, :, None, None]
    valid = t_pos <= qp
    if window is not None:
        valid &= (qp - t_pos) < window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale_pages is not None:
        p = p * score_scales(v_scale_pages)
    return jnp.einsum("bhwgt,bthd->bhwgd", p, vf).astype(q.dtype)


def ragged_prefill_attention_ref(
    q: jax.Array, k_new: jax.Array, v_new: jax.Array,
    k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, q_start: jax.Array, q_len: jax.Array,
    kv_len: jax.Array, *, chunk_cap: int,
    k_scale_pages: jax.Array | None = None,
    v_scale_pages: jax.Array | None = None,
    window: int | None = None, softcap: float | None = None) -> jax.Array:
    """Ragged chunked-prefill attention oracle (DESIGN.md §3.10).

    q: (N, Hkv, G, D) — a *packed* ragged query block: slot ``b`` owns rows
    ``[q_start[b], q_start[b] + q_len[b])`` (``q_len[b] ≤ chunk_cap``; rows no
    slot owns are ignored and zero in the output). ``kv_len`` (B,) is each
    slot's total visible length *after* this chunk's scatter, so the chunk
    starts at absolute position ``cs = kv_len - q_len`` and chunk token i sits
    at ``cs + i`` — the causal mask is ``k_pos <= cs + i``, which covers cold
    prefill (cs == 0), warm radix-hit suffix prefill (cs == prefix_len), later
    chunks of the same prompt (cs == tokens already chunked in), and the
    decode degenerate (q_len == 1, cs == kv_len - 1) in one launch with no
    bucket padding.

    ``k_new``/``v_new`` (N, Hkv, D) carry the chunk tokens' *floating-point*
    K/V in the same packed layout: positions ``[cs, kv_len)`` read these rows
    instead of the pool (and, int8-KV, bypass the per-token scales), exactly
    the in-flight fp-suffix overlay of ``layers.paged_prefill_attention`` —
    the chunk attends its own tokens unquantized, matching dense-prefill
    numerics. Everything before ``cs`` reads the pool through the page table
    with the decode oracle's scale application. → (N, Hkv, G, D).
    """
    P, ps = k_pages.shape[0], k_pages.shape[1]
    B, maxP = page_table.shape
    N, Hkv, G, D = q.shape
    C = chunk_cap
    T = maxP * ps
    gidx = jnp.clip(page_table[:, :, None] * ps + jnp.arange(ps)[None, None, :],
                    0, P * ps - 1).reshape(B, T)
    kf = k_pages.reshape(P * ps, *k_pages.shape[2:])[gidx].astype(jnp.float32)
    vf = v_pages.reshape(P * ps, *v_pages.shape[2:])[gidx].astype(jnp.float32)

    qs = q_start.astype(jnp.int32)
    qln = q_len.astype(jnp.int32)
    kvl = kv_len.astype(jnp.int32)
    cs = kvl - qln
    t_pos = jnp.arange(T)
    in_chunk = (t_pos[None] >= cs[:, None]) & (t_pos[None] < kvl[:, None])
    ov = jnp.clip(qs[:, None] + t_pos[None] - cs[:, None], 0, N - 1)   # (B, T)
    kf = jnp.where(in_chunk[..., None, None], k_new[ov].astype(jnp.float32), kf)
    vf = jnp.where(in_chunk[..., None, None], v_new[ov].astype(jnp.float32), vf)

    def score_scales(pool):    # (P, ps, Hkv, 1) → (B, Hkv, 1, 1, T) broadcast
        flat = pool.reshape(P * ps, pool.shape[2])[gidx]          # (B, T, Hkv)
        flat = jnp.where(in_chunk[..., None], 1.0, flat)          # fp overlay
        return jnp.transpose(flat, (0, 2, 1))[:, :, None, None, :]

    ridx = jnp.clip(qs[:, None] + jnp.arange(C)[None], 0, N - 1)  # (B, C)
    qb = jnp.transpose(q[ridx], (0, 2, 1, 3, 4))                  # (B,Hkv,C,G,D)
    s = jnp.einsum("bhcgd,bthd->bhcgt", qb.astype(jnp.float32), kf) * (D ** -0.5)
    if k_scale_pages is not None:
        s = s * score_scales(k_scale_pages)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = cs[:, None] + jnp.minimum(jnp.arange(C)[None],
                                      jnp.maximum(qln - 1, 0)[:, None])  # (B, C)
    qp = q_pos[:, None, :, None, None]
    valid = t_pos[None, None, None, None, :] <= qp
    if window is not None:
        valid &= (qp - t_pos[None, None, None, None, :]) < window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale_pages is not None:
        p = p * score_scales(v_scale_pages)
    ob = jnp.einsum("bhcgt,bthd->bhcgd", p, vf)                   # (B,Hkv,C,G,D)
    ob = jnp.transpose(ob, (0, 2, 1, 3, 4)).astype(q.dtype)       # (B,C,Hkv,G,D)
    rvalid = jnp.arange(C)[None] < qln[:, None]                   # (B, C)
    tgt = jnp.where(rvalid, qs[:, None] + jnp.arange(C)[None], N)
    return jnp.zeros((N, Hkv, G, D), q.dtype).at[tgt.reshape(-1)].set(
        ob.reshape(B * C, Hkv, G, D), mode="drop")


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, softcap: float | None = None) -> jax.Array:
    """Plain softmax attention oracle. q: (B,H,S,D); k/v: (B,H,S,D). f32 math."""
    D = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        Sq, Sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
