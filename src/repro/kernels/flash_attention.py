"""Pallas TPU kernel: fused flash attention (forward).

Motivation (EXPERIMENTS.md §Perf): the XLA-level blockwise attention materializes
every (q_block × kv_block) score/probability tile through HBM — on deepseek-33b
train_4k those tiles are 87% of the projected HBM traffic (memory term 242 s vs
34 s of compute). A fused kernel keeps the tiles in VMEM: HBM traffic drops to the
q/k/v streams + the output, turning attention from memory-bound into MXU-bound.

Design (TPU-native, GQA-aware):
  grid = (B·H, Sq/bq, Skv/bk), kv innermost. Running max/denominator/accumulator
  live in VMEM scratch across the kv axis (online softmax). k/v BlockSpecs index the
  kv head h // G directly — the (B, Skv, H, D)-broadcasted kv tensor is never
  materialized. Causal/window masking from absolute positions; fully-masked tiles
  short-circuit via pl.when. Logit softcap (gemma2) supported.

Tiling: (bq, bk) = (512, 512) at D ≤ 256 keeps the working set
(q 512·D·4 + k/v 2·512·D·4 + scores 512·512·4 ≈ 2.6 MB at D=128) well inside VMEM
with room for double buffering; all matmul dims are 128-multiples (MXU-aligned).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, *refs,
               n_k: int, bq: int, bk: int, scale: float, causal: bool,
               window: Optional[int], softcap: Optional[float],
               n_heads: Optional[int] = None):
    """``n_heads`` is set iff a per-slot kv-length vector is present: ``refs`` then
    leads with ``kvlen_ref``, a (B,) int32 SMEM input indexed by the batch element
    ``program_id(0) // n_heads`` — keys at or beyond that slot's valid length are
    masked (right-padded serving prefill, DESIGN.md §3.6)."""
    if n_heads is not None:
        kvlen_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # Tiles strictly above the causal diagonal contribute nothing; skip the matmul.
    live = True
    if causal:
        live = (ik * bk) <= (iq * bq + bq - 1)
    if n_heads is not None:
        kvl = kvlen_ref[pl.program_id(0) // n_heads]
        # tiles entirely beyond this slot's valid kv length are dead as well
        live = jnp.logical_and(live, ik * bk < kvl)

    @pl.when(live)
    def _tile():
        q = q_ref[0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        if n_heads is not None:
            mask &= k_pos < kvl
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(tab_ref, kvlen_ref, *rest,
                         ps: int, n_pages_max: int, n_kv_heads: int,
                         scale: float, window: Optional[int],
                         softcap: Optional[float], kv_int8: bool,
                         q_win: int = 1):
    """Decode / draft-verify attention through a page table (DESIGN.md §3.8/§3.9).

    grid = (B,). The K/V pools (and, int8-KV, the per-token scale pools) stay
    resident in HBM (``memory_space=ANY``): the kernel walks each slot's *live*
    pages with a double-buffered async-copy pipeline — while page ``j`` computes,
    page ``j+1``'s (ps, Hkv, D) code tile (plus its (Hkv, ps) scale tiles) is
    already in flight into the spare VMEM slot. Page indices come from the
    scalar-prefetched flattened (B·max_pages,) page table in SMEM, so each
    logical page's physical tile is DMA'd straight from the pool — the dense
    (B, T, Hkv, D) view is never materialized, and dead/sentinel pages past the
    (B,) ``kv_len`` are never fetched at all (the loop bound is
    ``ceil(kv_len / ps)``).

    Online softmax across the page loop; the kv-head axis is a static unrolled
    loop (decode tiles are small — one (G, ps) score tile per head per page).
    In-page tail positions past ``kv_len`` — and, with ``window``, positions
    that have slid out — mask through the probability row:
    ``p = where(mask, exp(s - m), 0)`` zeroes their l/acc contribution exactly
    (bitwise equal to a -1e30 score mask, whose exp underflows to 0.0 in f32)
    without per-position control flow.

    ``kv_int8=True``: the K scale multiplies the score column and the V scale
    folds into the probability row — the exact application points of the dense
    ``layers.decode_attention`` int8 path, so the fused kernel shares its
    quantization numerics (scale → softcap → mask → softmax).

    ``q_win > 1`` (speculative verify, DESIGN.md §3.9): q carries a draft
    window of ``q_win`` tokens per slot — rows ordered (window, group), so row
    ``r`` of the (q_win·G, ps) score tile belongs to window position
    ``r // G``. A third scalar-prefetch vector ``q_len`` (B,) gives each slot's
    *valid* window length (1 ≤ q_len ≤ q_win); ``kv_len`` counts the slot's
    total post-scatter length, window token i sitting at absolute position
    ``kv_len - q_len + i``. The causal mask is per-row: window token i attends
    keys ≤ its own position, so the same page pipeline serves every window row
    in one pass. Rows past ``q_len`` clamp to the last valid position —
    finite-but-garbage output the engine discards. ``q_win == 1`` (no q_len
    input) degenerates bitwise to single-token decode."""
    if q_win > 1:
        qlen_ref, q_ref, k_hbm, v_hbm, *refs = rest
    else:
        qlen_ref, q_ref, k_hbm, v_hbm, refs = None, *rest[:3], rest[3:]
    if kv_int8:
        ks_hbm, vs_hbm, o_ref = refs
    else:
        o_ref, = refs
    b = pl.program_id(0)
    kvl = kvlen_ref[b]
    n_live = pl.cdiv(kvl, ps)
    R, D = q_ref.shape[2], q_ref.shape[3]    # R = q_win * G score-tile rows
    G = R // q_win
    P = k_hbm.shape[0]
    if q_win > 1:
        qln = qlen_ref[b]
        # absolute position of each score-tile row's window token (clamped to
        # the newest valid token for rows past q_len)
        win_idx = jax.lax.broadcasted_iota(jnp.int32, (R, ps), 0) // G
        q_pos = (kvl - qln) + jnp.minimum(win_idx, qln - 1)

    def body(kbuf, vbuf, sbuf, sem):
        def dmas(slot, j):
            # sentinel entries (≥ P) clamp to a valid page. For live rows the
            # clamp is unreachable below kv_len (the engine maps every valid
            # position to a real page), so the fetched bytes never contribute;
            # a row whose table is *all* sentinel (a retired slot decoding in
            # lock-step with kv_len ≥ 1) attends the clamped page and produces
            # garbage-but-finite output — the engine discards it, and the
            # oracle's (differently-)clamped gather is equally arbitrary there
            # (pinned in tests/test_paged_serving.py).
            page = jnp.minimum(
                tab_ref[b * n_pages_max + jnp.minimum(j, n_pages_max - 1)], P - 1)
            copies = [
                pltpu.make_async_copy(k_hbm.at[page], kbuf.at[slot],
                                      sem.at[slot, 0]),
                pltpu.make_async_copy(v_hbm.at[page], vbuf.at[slot],
                                      sem.at[slot, 1]),
            ]
            if kv_int8:
                copies += [
                    pltpu.make_async_copy(ks_hbm.at[page], sbuf.at[slot, 0],
                                          sem.at[slot, 2]),
                    pltpu.make_async_copy(vs_hbm.at[page], sbuf.at[slot, 1],
                                          sem.at[slot, 3]),
                ]
            return copies

        @pl.when(n_live > 0)
        def _warmup():
            for c in dmas(0, 0):
                c.start()

        def page_step(j, carry):
            slot = jax.lax.rem(j, 2)

            @pl.when(j + 1 < n_live)
            def _prefetch():
                for c in dmas(1 - slot, j + 1):
                    c.start()

            for c in dmas(slot, j):
                c.wait()
            k_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (R, ps), 1)
            if q_win > 1:
                # per-row causality: window token i attends keys ≤ its own
                # absolute position (row 0 ≡ the single-token decode mask)
                mask = k_pos <= q_pos
                if window is not None:
                    mask &= (q_pos - k_pos) < window
            else:
                mask = k_pos < kvl
                if window is not None:
                    # decode window semantics (layers.decode_attention): the
                    # newest token sits at kvl - 1
                    mask &= (kvl - 1 - k_pos) < window
            scales = sbuf[slot] if kv_int8 else None          # (2, Hkv, ps)
            out = []
            for h in range(n_kv_heads):        # static unroll over kv heads
                m_prev, l_prev, acc_prev = carry[h]
                q = q_ref[0, h].astype(jnp.float32)           # (R, D)
                k = kbuf[slot, :, h, :].astype(jnp.float32)   # (ps, D)
                s = jax.lax.dot_general(
                    q, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale
                if kv_int8:
                    # per-token K scale on the score column: one multiply per
                    # (t, kv head) instead of dequantizing the (ps, D) tile
                    s = s * scales[0, h:h + 1]                # (R, ps) * (1, ps)
                if softcap is not None:
                    s = softcap * jnp.tanh(s / softcap)
                m_new = jnp.maximum(
                    m_prev, jnp.max(jnp.where(mask, s, NEG_INF), axis=1))
                p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
                corr = jnp.exp(m_prev - m_new)
                v = vbuf[slot, :, h, :].astype(jnp.float32)   # (ps, D)
                pv = p * scales[1, h:h + 1] if kv_int8 else p  # V scale → probs
                out.append((m_new, l_prev * corr + jnp.sum(p, axis=1),
                            acc_prev * corr[:, None] + jax.lax.dot_general(
                                pv, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)))
            return tuple(out)

        init = tuple((jnp.full((R,), NEG_INF, jnp.float32),
                      jnp.zeros((R,), jnp.float32),
                      jnp.zeros((R, D), jnp.float32))
                     for _ in range(n_kv_heads))
        state = jax.lax.fori_loop(0, n_live, page_step, init)
        for h in range(n_kv_heads):
            _, l, acc = state[h]
            o_ref[0, h] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)

    pl.run_scoped(
        body,
        kbuf=pltpu.VMEM((2,) + k_hbm.shape[1:], k_hbm.dtype),
        vbuf=pltpu.VMEM((2,) + v_hbm.shape[1:], v_hbm.dtype),
        sbuf=pltpu.VMEM((2, 2, n_kv_heads, ps), jnp.float32),
        sem=pltpu.SemaphoreType.DMA((2, 4)),
    )


def paged_decode_attention_pallas(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, kv_len: jax.Array, *,
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
    q_win: int = 1, q_len: Optional[jax.Array] = None,
    window: Optional[int] = None, softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hkv, q_win·G, D); k/v pages: (P, ps, Hkv, D); page_table:
    (B, maxP) int32 (entries ≥ P are invalid — clamped in the kernel and
    masked by ``kv_len``); kv_len: (B,) int32 with kv_len ≤ maxP·ps
    → (B, Hkv, q_win·G, D). The pools stay in HBM; the kernel DMAs each live
    page's tile on demand (double-buffered — see ``_paged_decode_kernel``).

    ``k_scale``/``v_scale`` (both or neither): int8-KV per-token scales in the
    kernel-native (P, Hkv, ps) row layout — ``ops.paged_decode_attention``
    transposes the engine's (P, ps, Hkv, 1) scale pools, D× smaller than the
    code pools. Their tiles ride the same per-page DMA pipeline as the code
    tiles and apply in-kernel at the score/prob level (dense
    ``decode_attention`` numerics) — the int8 path never materializes a dense
    (B, T, ...) view either.

    ``q_win > 1`` + ``q_len`` (B,) int32: draft-window verify (DESIGN.md
    §3.9). q's third axis carries ``q_win`` window tokens × G group heads in
    (window, group) row order; ``kv_len`` counts each slot's total
    post-scatter length so window token i sits at ``kv_len - q_len + i``, and
    rows past ``q_len`` produce garbage-but-finite output the engine discards.

    TPU notes: ps should be a multiple of 8 and D of 128 for native tiling
    (int8 code pools want ps ≥ 32 sublanes); CI and the oracle-parity tests run
    ``interpret=True`` on any backend.
    """
    B, Hkv, R, D = q.shape
    assert R % q_win == 0, (R, q_win)
    P, ps = k_pages.shape[0], k_pages.shape[1]
    maxP = page_table.shape[1]
    assert page_table.shape == (B, maxP) and kv_len.shape == (B,)
    assert (q_len is not None) == (q_win > 1), "q_len iff q_win > 1"
    kv_int8 = k_scale is not None
    assert kv_int8 == (v_scale is not None), "pass both scale pools or neither"

    kernel = functools.partial(
        _paged_decode_kernel, ps=ps, n_pages_max=maxP, n_kv_heads=Hkv,
        scale=D ** -0.5, window=window, softcap=softcap, kv_int8=kv_int8,
        q_win=q_win)
    n_pref = 2 if q_win == 1 else 3
    qmap = lambda b, *pref: (b, 0, 0, 0)
    in_specs = [
        pl.BlockSpec((1, Hkv, R, D), qmap),
        pl.BlockSpec(memory_space=pltpu.ANY),        # k pool, paged via DMA
        pl.BlockSpec(memory_space=pltpu.ANY),        # v pool
    ]
    args = [q, k_pages, v_pages]
    if kv_int8:
        assert k_scale.shape == v_scale.shape == (P, Hkv, ps), (
            k_scale.shape, (P, Hkv, ps))
        in_specs += [pl.BlockSpec(memory_space=pltpu.ANY)] * 2
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_pref,
        grid=(B,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hkv, R, D), qmap),
    )
    pref = [page_table.reshape(-1).astype(jnp.int32), kv_len.astype(jnp.int32)]
    if q_win > 1:
        assert q_len.shape == (B,), q_len.shape
        pref.append(q_len.astype(jnp.int32))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, D), q.dtype),
        interpret=interpret,
    )(*pref, *args)


def _ragged_prefill_kernel(tab_ref, qstart_ref, qlen_ref, kvlen_ref,
                           q_ref, kn_ref, vn_ref, k_hbm, v_hbm, *refs,
                           ps: int, n_pages_max: int, n_kv_heads: int,
                           n_groups: int, chunk_cap: int, scale: float,
                           window: Optional[int], softcap: Optional[float],
                           kv_int8: bool):
    """Ragged chunked-prefill attention over the paged pool (DESIGN.md §3.10).

    grid = (B,) over slots of a *packed* ragged query block: slot ``b`` owns
    packed rows ``[q_start[b], q_start[b] + q_len[b])`` (``q_len ≤ chunk_cap``),
    all three per-slot extents riding as scalar-prefetch vectors alongside the
    flattened page table. The K/V pools stay in HBM and each slot's live pages
    stream through the identical double-buffered async-copy pipeline as
    ``_paged_decode_kernel`` — int8-KV scale tiles included — so warm
    (radix-hit) suffix prefill, cold prefill, later chunks of the same prompt,
    and the q_len == 1 decode degenerate share one launch with no bucket
    padding.

    The chunk starts at absolute position ``cs = kv_len - q_len`` (cs ==
    prefix_len for the first chunk); chunk token i sits at ``cs + i`` and the
    causal mask is per score-tile row: ``k_pos <= cs + row // G``. Key
    positions inside ``[cs, kv_len)`` — the chunk's own tokens, already
    scattered into the pool before the launch — are *overlaid* with the packed
    floating-point ``k_new``/``v_new`` rows (and their int8-KV scale columns
    neutralized to 1.0): the chunk attends itself unquantized, exactly the
    in-flight fp-suffix overlay of ``layers.paged_prefill_attention``, so
    chunked numerics match the bucketed warm path. The packed buffers carry
    ``ps`` leading pad rows so the per-page overlay offset
    ``q_start + j·ps - cs`` stays in-bounds when a chunk starts mid-page.

    The output block is shared by every grid step (zeroed at b == 0; the TPU
    grid is sequential, so the read-modify-write blend below is ordered):
    each slot blends exactly its ``q_len`` valid rows back into
    ``[q_start, q_start + chunk_cap)`` and rows past ``q_len`` keep their
    previous contents — packed rows no slot owns stay zero, and a dead slot
    (q_len == 0) skips its page loop entirely (``n_live = 0``)."""
    if kv_int8:
        ks_hbm, vs_hbm, o_ref = refs
    else:
        o_ref, = refs
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    qs = qstart_ref[b]
    qn = qlen_ref[b]
    kvl = kvlen_ref[b]
    cs = kvl - qn                               # chunk's first absolute position
    C, G = chunk_cap, n_groups
    R = C * G
    D = q_ref.shape[-1]
    P = k_hbm.shape[0]
    Npad = kn_ref.shape[0]
    n_live = jnp.where(qn > 0, pl.cdiv(kvl, ps), 0)
    win_idx = jax.lax.broadcasted_iota(jnp.int32, (R, ps), 0) // G
    q_pos = cs + jnp.minimum(win_idx, jnp.maximum(qn - 1, 0))

    def body(kbuf, vbuf, sbuf, sem):
        def dmas(slot, j):
            # sentinel clamp exactly as _paged_decode_kernel: unreachable below
            # kv_len for live rows, garbage-but-finite on all-sentinel rows
            page = jnp.minimum(
                tab_ref[b * n_pages_max + jnp.minimum(j, n_pages_max - 1)], P - 1)
            copies = [
                pltpu.make_async_copy(k_hbm.at[page], kbuf.at[slot],
                                      sem.at[slot, 0]),
                pltpu.make_async_copy(v_hbm.at[page], vbuf.at[slot],
                                      sem.at[slot, 1]),
            ]
            if kv_int8:
                copies += [
                    pltpu.make_async_copy(ks_hbm.at[page], sbuf.at[slot, 0],
                                          sem.at[slot, 2]),
                    pltpu.make_async_copy(vs_hbm.at[page], sbuf.at[slot, 1],
                                          sem.at[slot, 3]),
                ]
            return copies

        @pl.when(n_live > 0)
        def _warmup():
            for c in dmas(0, 0):
                c.start()

        def page_step(j, carry):
            slot = jax.lax.rem(j, 2)

            @pl.when(j + 1 < n_live)
            def _prefetch():
                for c in dmas(1 - slot, j + 1):
                    c.start()

            for c in dmas(slot, j):
                c.wait()
            k_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (R, ps), 1)
            mask = k_pos <= q_pos
            if window is not None:
                mask &= (q_pos - k_pos) < window
            # fp overlay of the chunk's own tokens: in-page rows at absolute
            # positions >= cs read the packed fp k_new/v_new instead of the
            # pool (and skip the int8 scales). The dynamic-slice start clamps
            # so pure-history pages (offset < 0) stay in-bounds — their rows
            # all fail the >= cs test, so the fetched bytes never contribute.
            row_pos = jax.lax.broadcasted_iota(jnp.int32, (ps, D), 0) + j * ps
            icd = row_pos >= cs                                   # (ps, D)
            ic2 = (jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
                   + j * ps) >= cs                                # (1, ps)
            off = jnp.clip(qs + (j * ps - cs), 0, Npad - ps)
            ov_k = kn_ref[pl.ds(off, ps)]                         # (ps, Hkv, D)
            ov_v = vn_ref[pl.ds(off, ps)]
            scales = sbuf[slot] if kv_int8 else None              # (2, Hkv, ps)
            out = []
            for h in range(n_kv_heads):        # static unroll over kv heads
                m_prev, l_prev, acc_prev = carry[h]
                q = q_ref[h, pl.ds(qs, C)].reshape(R, D).astype(jnp.float32)
                k = jnp.where(icd, ov_k[:, h, :].astype(jnp.float32),
                              kbuf[slot, :, h, :].astype(jnp.float32))
                s = jax.lax.dot_general(
                    q, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale
                if kv_int8:
                    s = s * jnp.where(ic2, 1.0, scales[0, h:h + 1])
                if softcap is not None:
                    s = softcap * jnp.tanh(s / softcap)
                m_new = jnp.maximum(
                    m_prev, jnp.max(jnp.where(mask, s, NEG_INF), axis=1))
                p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
                corr = jnp.exp(m_prev - m_new)
                v = jnp.where(icd, ov_v[:, h, :].astype(jnp.float32),
                              vbuf[slot, :, h, :].astype(jnp.float32))
                pv = (p * jnp.where(ic2, 1.0, scales[1, h:h + 1])
                      if kv_int8 else p)
                out.append((m_new, l_prev * corr + jnp.sum(p, axis=1),
                            acc_prev * corr[:, None] + jax.lax.dot_general(
                                pv, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)))
            return tuple(out)

        init = tuple((jnp.full((R,), NEG_INF, jnp.float32),
                      jnp.zeros((R,), jnp.float32),
                      jnp.zeros((R, D), jnp.float32))
                     for _ in range(n_kv_heads))
        state = jax.lax.fori_loop(0, n_live, page_step, init)
        tok = jax.lax.broadcasted_iota(jnp.int32, (C, G, D), 0)
        for h in range(n_kv_heads):
            _, l, acc = state[h]
            new = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
            old = o_ref[h, pl.ds(qs, C)]                          # (C, G, D)
            o_ref[h, pl.ds(qs, C)] = jnp.where(tok < qn,
                                               new.reshape(C, G, D), old)

    pl.run_scoped(
        body,
        kbuf=pltpu.VMEM((2,) + k_hbm.shape[1:], k_hbm.dtype),
        vbuf=pltpu.VMEM((2,) + v_hbm.shape[1:], v_hbm.dtype),
        sbuf=pltpu.VMEM((2, 2, n_kv_heads, ps), jnp.float32),
        sem=pltpu.SemaphoreType.DMA((2, 4)),
    )


def ragged_prefill_attention_pallas(
    q: jax.Array, k_new: jax.Array, v_new: jax.Array,
    k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, q_start: jax.Array, q_len: jax.Array,
    kv_len: jax.Array, *, chunk_cap: int,
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
    window: Optional[int] = None, softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (Hkv, Npad, G, D) packed ragged queries (``ops`` pads ``ps`` leading
    + ``chunk_cap`` trailing zero rows and adds the leading pad to
    ``q_start``); k_new/v_new: (Npad, Hkv, D) the chunk tokens' fp K/V in the
    same packed layout; pools/page_table/scales exactly as
    :func:`paged_decode_attention_pallas`; q_start/q_len/kv_len: (B,) int32
    per-slot packed offset, chunk length (≤ chunk_cap; 0 ⇒ dead slot) and
    total post-scatter visible length → (Hkv, Npad, G, D) with slot b's rows
    at ``[q_start[b], q_start[b] + q_len[b])`` and every other row zero.

    One launch serves cold prefill, warm suffix prefill, mid-prompt chunks
    and single-token decode rows (see ``_ragged_prefill_kernel``); the pools
    never materialize a dense view and dead slots skip their page walk.
    """
    Hkv, Npad, G, D = q.shape
    P, ps = k_pages.shape[0], k_pages.shape[1]
    B, maxP = page_table.shape
    assert k_new.shape == v_new.shape == (Npad, Hkv, D), (k_new.shape, q.shape)
    assert q_start.shape == q_len.shape == kv_len.shape == (B,)
    assert chunk_cap >= 1 and Npad >= ps + max(ps, chunk_cap), (Npad, ps, chunk_cap)
    kv_int8 = k_scale is not None
    assert kv_int8 == (v_scale is not None), "pass both scale pools or neither"

    kernel = functools.partial(
        _ragged_prefill_kernel, ps=ps, n_pages_max=maxP, n_kv_heads=Hkv,
        n_groups=G, chunk_cap=chunk_cap, scale=D ** -0.5, window=window,
        softcap=softcap, kv_int8=kv_int8)
    full = lambda shape: pl.BlockSpec(shape, lambda b, *pref: (0,) * len(shape))
    in_specs = [
        full((Hkv, Npad, G, D)),                     # packed q, VMEM-resident
        full((Npad, Hkv, D)),                        # packed fp k_new overlay
        full((Npad, Hkv, D)),                        # packed fp v_new overlay
        pl.BlockSpec(memory_space=pltpu.ANY),        # k pool, paged via DMA
        pl.BlockSpec(memory_space=pltpu.ANY),        # v pool
    ]
    args = [q, k_new, v_new, k_pages, v_pages]
    if kv_int8:
        assert k_scale.shape == v_scale.shape == (P, Hkv, ps), (
            k_scale.shape, (P, Hkv, ps))
        in_specs += [pl.BlockSpec(memory_space=pltpu.ANY)] * 2
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B,),
        in_specs=in_specs,
        out_specs=full((Hkv, Npad, G, D)),
    )
    pref = [page_table.reshape(-1).astype(jnp.int32),
            q_start.astype(jnp.int32), q_len.astype(jnp.int32),
            kv_len.astype(jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, Npad, G, D), q.dtype),
        interpret=interpret,
    )(*pref, *args)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    kv_len: Optional[jax.Array] = None, *,
    causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None, bq: int = 512, bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Skv, D) with H % Hkv == 0 → (B, H, Sq, D).

    Sq % bq == Skv % bk == 0 (ops.py pads). Positions are 0-based on both axes
    (prefill self-attention; for q_offset semantics pre-slice the kv).

    ``kv_len`` (B,) int32 masks, per batch element, keys at positions ≥ kv_len[b]
    — the per-slot valid prompt length of right-padded continuous-batching prefill
    (DESIGN.md §3.6). It rides in SMEM so the mask is one scalar compare per tile.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    n_q, n_k = Sq // bq, Sk // bk
    grid = (B * H, n_q, n_k)
    scale = D ** -0.5

    kernel = functools.partial(
        _fa_kernel, n_k=n_k, bq=bq, bk=bk, scale=scale, causal=causal,
        window=window, softcap=softcap,
        n_heads=H if kv_len is not None else None)
    q3 = q.reshape(B * H, Sq, D)
    in_specs = [
        pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        # kv head = (bh % H) // G: GQA indexing, no (B,H,Skv,D) broadcast
        pl.BlockSpec((1, 1, bk, D),
                     lambda bh, iq, ik: (bh // H, (bh % H) // G, ik, 0)),
        pl.BlockSpec((1, 1, bk, D),
                     lambda bh, iq, ik: (bh // H, (bh % H) // G, ik, 0)),
    ]
    args = [q3, k, v]
    if kv_len is not None:
        assert kv_len.shape == (B,), kv_len.shape
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        args.append(kv_len.astype(jnp.int32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(*args).reshape(B, H, Sq, D)
