"""Pallas TPU kernel: fused flash attention (forward).

Motivation (EXPERIMENTS.md §Perf): the XLA-level blockwise attention materializes
every (q_block × kv_block) score/probability tile through HBM — on deepseek-33b
train_4k those tiles are 87% of the projected HBM traffic (memory term 242 s vs
34 s of compute). A fused kernel keeps the tiles in VMEM: HBM traffic drops to the
q/k/v streams + the output, turning attention from memory-bound into MXU-bound.

Design (TPU-native, GQA-aware):
  grid = (B·H, Sq/bq, Skv/bk), kv innermost. Running max/denominator/accumulator
  live in VMEM scratch across the kv axis (online softmax). k/v BlockSpecs index the
  kv head h // G directly — the (B, Skv, H, D)-broadcasted kv tensor is never
  materialized. Causal/window masking from absolute positions; fully-masked tiles
  short-circuit via pl.when. Logit softcap (gemma2) supported.

Tiling: (bq, bk) = (512, 512) at D ≤ 256 keeps the working set
(q 512·D·4 + k/v 2·512·D·4 + scores 512·512·4 ≈ 2.6 MB at D=128) well inside VMEM
with room for double buffering; all matmul dims are 128-multiples (MXU-aligned).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, *refs,
               n_k: int, bq: int, bk: int, scale: float, causal: bool,
               window: Optional[int], softcap: Optional[float],
               n_heads: Optional[int] = None):
    """``n_heads`` is set iff a per-slot kv-length vector is present: ``refs`` then
    leads with ``kvlen_ref``, a (B,) int32 SMEM input indexed by the batch element
    ``program_id(0) // n_heads`` — keys at or beyond that slot's valid length are
    masked (right-padded serving prefill, DESIGN.md §3.6)."""
    if n_heads is not None:
        kvlen_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # Tiles strictly above the causal diagonal contribute nothing; skip the matmul.
    live = True
    if causal:
        live = (ik * bk) <= (iq * bq + bq - 1)
    if n_heads is not None:
        kvl = kvlen_ref[pl.program_id(0) // n_heads]
        # tiles entirely beyond this slot's valid kv length are dead as well
        live = jnp.logical_and(live, ik * bk < kvl)

    @pl.when(live)
    def _tile():
        q = q_ref[0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        if n_heads is not None:
            mask &= k_pos < kvl
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(tab_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         ps: int, n_pages_max: int, scale: float,
                         window: Optional[int], softcap: Optional[float]):
    """Single-token decode attention through a page table (DESIGN.md §3.8).

    grid = (B, Hkv, max_pages), page axis innermost. ``tab_ref`` is the
    flattened (B·max_pages,) page table and ``kvlen_ref`` the (B,) valid
    lengths — both scalar-prefetch inputs, so the k/v BlockSpecs gather each
    logical page's physical tile straight from the pool (no (B, T, Hkv, D)
    materialization). Online softmax state lives in VMEM scratch across the
    page axis; pages at or beyond the valid length are dead (skipped), and the
    in-page tail past ``kv_len`` masks by absolute position."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kvl = kvlen_ref[b]

    @pl.when(j * ps < kvl)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (ps, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kvl
        if window is not None:
            # decode window semantics (layers.decode_attention): the newest
            # token sits at kvl - 1
            mask &= (kvl - 1 - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        v = v_ref[0, :, 0].astype(jnp.float32)            # (ps, D)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_pages_max - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, kv_len: jax.Array, *,
    window: Optional[int] = None, softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hkv, G, D); k/v pages: (P, ps, Hkv, D); page_table: (B, maxP)
    int32 (entries ≥ P are invalid — clamped in the index map and masked by
    ``kv_len``); kv_len: (B,) int32 → (B, Hkv, G, D).

    TPU notes: ps should be a multiple of 8 and D of 128 for native tiling;
    CI and the oracle-parity tests run ``interpret=True`` on any backend.
    """
    B, Hkv, G, D = q.shape
    P, ps = k_pages.shape[0], k_pages.shape[1]
    maxP = page_table.shape[1]
    assert page_table.shape == (B, maxP) and kv_len.shape == (B,)

    kernel = functools.partial(
        _paged_decode_kernel, ps=ps, n_pages_max=maxP, scale=D ** -0.5,
        window=window, softcap=softcap)
    # scalar-prefetch index maps: (grid..., *scalar_refs); clamp sentinel
    # entries to a valid page — they are masked by kv_len inside the kernel
    page_of = lambda b, j, tab: jnp.minimum(tab[b * maxP + j], P - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, maxP),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, tab, kvl: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, tab, kvl: (page_of(b, j, tab), 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, tab, kvl: (page_of(b, j, tab), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, tab, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(page_table.reshape(-1).astype(jnp.int32), kv_len.astype(jnp.int32),
      q, k_pages, v_pages)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    kv_len: Optional[jax.Array] = None, *,
    causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None, bq: int = 512, bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Skv, D) with H % Hkv == 0 → (B, H, Sq, D).

    Sq % bq == Skv % bk == 0 (ops.py pads). Positions are 0-based on both axes
    (prefill self-attention; for q_offset semantics pre-slice the kv).

    ``kv_len`` (B,) int32 masks, per batch element, keys at positions ≥ kv_len[b]
    — the per-slot valid prompt length of right-padded continuous-batching prefill
    (DESIGN.md §3.6). It rides in SMEM so the mask is one scalar compare per tile.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    n_q, n_k = Sq // bq, Sk // bk
    grid = (B * H, n_q, n_k)
    scale = D ** -0.5

    kernel = functools.partial(
        _fa_kernel, n_k=n_k, bq=bq, bk=bk, scale=scale, causal=causal,
        window=window, softcap=softcap,
        n_heads=H if kv_len is not None else None)
    q3 = q.reshape(B * H, Sq, D)
    in_specs = [
        pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        # kv head = (bh % H) // G: GQA indexing, no (B,H,Skv,D) broadcast
        pl.BlockSpec((1, 1, bk, D),
                     lambda bh, iq, ik: (bh // H, (bh % H) // G, ik, 0)),
        pl.BlockSpec((1, 1, bk, D),
                     lambda bh, iq, ik: (bh // H, (bh % H) // G, ik, 0)),
    ]
    args = [q3, k, v]
    if kv_len is not None:
        assert kv_len.shape == (B,), kv_len.shape
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        args.append(kv_len.astype(jnp.int32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(*args).reshape(B, H, Sq, D)
