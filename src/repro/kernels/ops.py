"""Public jit'd wrappers for the Pallas kernels.

Handles (a) padding to block multiples (zero padding is exact for integer GEMMs and
for row-absmax quantization), (b) backend dispatch: real Mosaic lowering on TPU,
``interpret=True`` everywhere else (CPU CI and the correctness tests) — and, for
the paged serving kernels, ``REPRO_KERNEL_EXEC=ref`` routes off-TPU calls to the
pure-jnp oracle instead (:func:`_exec_mode`: interpret emulation is a correctness
harness, not an execution backend), (c) block-size selection for small shapes,
(d) the custom-kernel boundary under a TP-sharded serving plan (DESIGN.md §3.7):
each wrapper body runs as a GSPMD-*manual* region (``hints.manual_kernel``) so
every device computes the exact single-device kernel result on gathered operands
— a no-op outside a hinted mesh.

The hinted mesh is threaded into the jitted wrappers as a *static* argument: jit's
trace cache does not key on contextvars, so reading the hint inside the traced body
would silently reuse whichever of the manual/plain lowerings was traced first.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import act_quantize as _aq
from repro.kernels import flash_attention as _fa
from repro.kernels import qgemm as _qg
from repro.kernels import ref as _ref
from repro.sharding import hints


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _exec_mode() -> str:
    """Execution backend for the paged serving kernels: ``pallas`` (real
    Mosaic lowering on TPU, ``interpret=True`` emulation elsewhere) or
    ``ref`` (the pure-jnp oracle from :mod:`repro.kernels.ref`, XLA-compiled).

    ``REPRO_KERNEL_EXEC=ref`` routes off-TPU calls to the oracle: interpret
    emulation exists to *test* the kernels (it lowers the per-page DMA
    pipeline to per-step dynamic slices), and its overhead is emulator cost,
    not a serving signal — the serving benchmark opts in so CPU rows measure
    the XLA execution of the same math. On TPU the Mosaic kernels always run;
    the variable is read at call time and threaded into the jitted wrappers
    as a static argument (like ``mesh``: jit's trace cache does not key on
    environment reads inside the traced body)."""
    mode = os.environ.get("REPRO_KERNEL_EXEC", "pallas")
    assert mode in ("pallas", "ref"), f"REPRO_KERNEL_EXEC={mode!r}"
    return "pallas" if jax.default_backend() == "tpu" else mode


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_block(dim: int, preferred: int, align: int = 128) -> int:
    """Largest multiple of ``align`` ≤ preferred that is reasonable for ``dim``."""
    if dim <= align:
        return align
    return min(preferred, ((dim + align - 1) // align) * align, preferred)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "mesh"))
def _qgemm_w8a8(qx, qw, a, sw, *, bm, bn, bk, mesh):
    M, K = qx.shape
    N = qw.shape[1]
    bm = _pick_block(M, bm)
    bn = _pick_block(N, bn)
    bk = _pick_block(K, bk)

    def body(qx, qw, a, sw):
        qxp = _pad_to(_pad_to(qx, 0, bm), 1, bk)
        qwp = _pad_to(_pad_to(qw, 0, bk), 1, bn)
        ap = _pad_to(a.astype(jnp.float32), 0, bm)
        swp = _pad_to(sw.reshape(1, -1).astype(jnp.float32), 1, bn)
        out = _qg.qgemm_w8a8_pallas(qxp, qwp, ap, swp, bm=bm, bn=bn, bk=bk,
                                    interpret=_interpret())
        return out[:M, :N]

    return hints.manual_kernel(body, (qx, qw, a, sw), mesh=mesh)


def qgemm_w8a8(qx: jax.Array, qw: jax.Array, a: jax.Array, sw: jax.Array,
               *, bm: int = 256, bn: int = 256, bk: int = 512) -> jax.Array:
    """int8 GEMM + separable dequant. qx (M,K) int8; qw (K,N) int8; a (M,1); sw (N,)."""
    return _qgemm_w8a8(qx, qw, a, sw, bm=bm, bn=bn, bk=bk,
                       mesh=hints.current_mesh())


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "mesh", "exec_mode"))
def _qgemm_w8a8_sparse(qx, qw, a, sw, mask, *, bm, bn, bk, mesh, exec_mode):
    M, K = qx.shape
    N = qw.shape[1]
    bm = _pick_block(M, bm)
    bn = _pick_block(N, bn)
    bk = _pick_block(K, bk)

    def body(qx, qw, a, sw, mask):
        if exec_mode == "ref":
            return _ref.qgemm_w8a8_sparse_ref(qx, qw, a, sw, mask)
        qxp = _pad_to(_pad_to(qx, 0, bm), 1, bk)
        qwp = _pad_to(_pad_to(qw, 0, bk), 1, bn)
        ap = _pad_to(a.astype(jnp.float32), 0, bm)
        swp = _pad_to(sw.reshape(1, -1).astype(jnp.float32), 1, bn)
        mp = _pad_to(_pad_to(mask.astype(jnp.int32), 0, bk), 1, bn)
        Kp, Np = qwp.shape
        occ = mp.reshape(Kp // bk, bk, Np // bn, bn).sum(axis=(1, 3))
        dense_args = (qxp, qwp, ap, swp)
        # Dense fallback when occupancy is full: the sparse kernel is bitwise
        # identical there but pays an SMEM gate per grid step for nothing. Both
        # branches produce the same values (skipping all-zero int8 blocks is
        # exact), so the runtime switch cannot perturb token parity.
        out = jax.lax.cond(
            jnp.all(occ > 0),
            lambda ops: _qg.qgemm_w8a8_pallas(
                *ops, bm=bm, bn=bn, bk=bk, interpret=_interpret()),
            lambda ops: _qg.qgemm_w8a8_sparse_pallas(
                *ops, occ, bm=bm, bn=bn, bk=bk, interpret=_interpret()),
            dense_args)
        return out[:M, :N]

    return hints.manual_kernel(body, (qx, qw, a, sw, mask), mesh=mesh)


def qgemm_w8a8_sparse(qx: jax.Array, qw: jax.Array, a: jax.Array, sw: jax.Array,
                      mask: jax.Array, *, bm: int = 256, bn: int = 256,
                      bk: int = 512) -> jax.Array:
    """Block-sparse int8 GEMM over N:M-pruned weights (DESIGN.md §3.12).

    mask (K, N) uint8 {0,1}: the *unpacked* keep-mask whose zeros already zero
    ``qw`` (models/quantize.py sparsify_tree). The wrapper reduces it to per-
    (bk, bn)-block occupancy for the kernel's scalar-prefetch gate; with every
    block occupied it dispatches the plain dense kernel instead.
    """
    return _qgemm_w8a8_sparse(qx, qw, a, sw, mask, bm=bm, bn=bn, bk=bk,
                              mesh=hints.current_mesh(), exec_mode=_exec_mode())


@functools.partial(jax.jit, static_argnames=("group", "bm", "bn", "mesh"))
def _qgemm_w4a8(qx, qw4, a, sw, *, group, bm, bn, mesh):
    M, K = qx.shape
    N = qw4.shape[1]
    assert K % group == 0, f"K={K} must divide group={group} (pad offline)"
    bm = _pick_block(M, bm)
    bn = _pick_block(N, bn)

    def body(qx, qw4, a, sw):
        qxp = _pad_to(qx, 0, bm)
        qw4p = _pad_to(qw4, 1, bn)
        ap = _pad_to(a.astype(jnp.float32), 0, bm)
        swp = _pad_to(sw.astype(jnp.float32), 1, bn)
        out = _qg.qgemm_w4a8_pallas(qxp, qw4p, ap, swp, group=group, bm=bm, bn=bn,
                                    interpret=_interpret())
        return out[:M, :N]

    return hints.manual_kernel(body, (qx, qw4, a, sw), mesh=mesh)


def qgemm_w4a8(qx: jax.Array, qw4: jax.Array, a: jax.Array, sw: jax.Array,
               *, group: int = 128, bm: int = 256, bn: int = 256) -> jax.Array:
    """W4A8 grouped GEMM. qx (M,K) int8; qw4 (K//2,N) packed; sw (K//group,N)."""
    return _qgemm_w4a8(qx, qw4, a, sw, group=group, bm=bm, bn=bn,
                       mesh=hints.current_mesh())


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "mesh"))
def _flash_attention(q, k, v, kv_len, *, causal, window, softcap, bq, bk, mesh):
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(bq, max(128, 1 << (Sq - 1).bit_length()))
    bk = min(bk, max(128, 1 << (Sk - 1).bit_length()))
    if ((-Sk) % bk) and not causal and kv_len is None:
        # non-causal paths must not attend to padded keys: window trick can't help,
        # so mask by giving padded keys a -inf-producing value via a huge negative
        # bias channel is fragile — instead run causal=False only on block-aligned
        # inputs (encoder S=4096 aligns; assert keeps this honest). A kv_len bound
        # subsumes this: it masks the block padding along with the slot padding.
        raise ValueError("non-causal flash_attention requires Skv % bk == 0")

    def body(q, k, v, kv_len):
        qp = _pad_to(q, 2, bq)
        kp = _pad_to(k, 2, bk)
        vp = _pad_to(v, 2, bk)
        kvl = None
        if kv_len is not None:
            kvl = jnp.broadcast_to(
                jnp.clip(jnp.reshape(kv_len, (-1,)).astype(jnp.int32), 0, Sk), (B,))
        out = _fa.flash_attention_pallas(qp, kp, vp, kvl, causal=causal,
                                         window=window, softcap=softcap,
                                         bq=bq, bk=bk, interpret=_interpret())
        return out[:, :, :Sq]

    return hints.manual_kernel(body, (q, k, v, kv_len), mesh=mesh)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, kv_len=None, *,
                    causal: bool = True, window=None, softcap=None,
                    bq: int = 512, bk: int = 512) -> jax.Array:
    """Fused flash attention. q (B,H,Sq,D); k/v (B,Hkv,Skv,D) → (B,H,Sq,D).

    ``kv_len`` (scalar or (B,) int32) masks keys at positions ≥ kv_len[b] per batch
    element — the per-slot valid length of right-padded continuous-batching prefill
    (DESIGN.md §3.6).

    Pads Sq/Skv to block multiples; padded keys are masked by position (the kernel
    masks k_pos ≥ true Skv via the window/causal machinery — here by pre-masking:
    padded kv rows are zeroed AND excluded through an explicit Skv bound below)."""
    return _flash_attention(q, k, v, kv_len, causal=causal, window=window,
                            softcap=softcap, bq=bq, bk=bk,
                            mesh=hints.current_mesh())


@functools.partial(jax.jit, static_argnames=("window", "softcap", "mesh",
                                             "exec_mode"))
def _paged_decode_attention(q, k_pages, v_pages, page_table, kv_len,
                            k_scale_pages, v_scale_pages, *,
                            window, softcap, mesh, exec_mode):
    B, S, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv

    def body(q, k_pages, v_pages, page_table, kv_len, k_scale_pages,
             v_scale_pages):
        qg = q.reshape(B, Hkv, G, D)
        kvl = jnp.broadcast_to(
            jnp.reshape(kv_len, (-1,)).astype(jnp.int32), (B,))
        if exec_mode == "ref":
            out = _ref.paged_decode_attention_ref(
                qg, k_pages, v_pages, page_table, kvl,
                k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
                window=window, softcap=softcap)
            return out.reshape(B, 1, H, D)
        ks = vs = None
        if k_scale_pages is not None:
            # (P, ps, Hkv, 1) scale pools → the kernel's (P, Hkv, ps) row
            # tiles. The transpose touches scale bytes only (D× less than the
            # code pools) and runs inside the manual region, so the partitioner
            # never sees it (DESIGN.md §3.7 interpret-emulation caveat).
            ks = jnp.transpose(k_scale_pages[..., 0], (0, 2, 1))
            vs = jnp.transpose(v_scale_pages[..., 0], (0, 2, 1))
        out = _fa.paged_decode_attention_pallas(
            qg, k_pages, v_pages, page_table, kvl,
            k_scale=ks, v_scale=vs,
            window=window, softcap=softcap, interpret=_interpret())
        return out.reshape(B, 1, H, D)

    return hints.manual_kernel(
        body, (q, k_pages, v_pages, page_table, kv_len, k_scale_pages,
               v_scale_pages), mesh=mesh)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           page_table: jax.Array, kv_len: jax.Array, *,
                           k_scale_pages=None, v_scale_pages=None,
                           window=None, softcap=None) -> jax.Array:
    """Paged single-token decode attention (DESIGN.md §3.8): q (B,1,H,D) against
    (P, ps, Hkv, D) pools addressed through a (B, maxP) int32 page table with
    per-slot valid lengths ``kv_len`` (scalar or (B,)) → (B,1,H,D).

    The kernel gathers each logical page's physical K/V tile via scalar-prefetch
    page indices — the dense (B, T, Hkv, D) view is never materialized. With
    ``k_scale_pages``/``v_scale_pages`` ((P, ps, Hkv, 1) f32) the pools hold
    int8 codes: the per-token scale tiles ride the same prefetched page indices
    and apply in-kernel at the score/prob level, the exact application points of
    the dense ``layers.decode_attention`` int8 path — every paged decode path
    (fp, int8-KV) serves through this kernel. Under a TP-sharded serving plan
    the body (scale-pool relayout included) runs as one GSPMD-manual region."""
    return _paged_decode_attention(q, k_pages, v_pages, page_table, kv_len,
                                   k_scale_pages, v_scale_pages,
                                   window=window, softcap=softcap,
                                   mesh=hints.current_mesh(),
                                   exec_mode=_exec_mode())


@functools.partial(jax.jit, static_argnames=("window", "softcap", "mesh",
                                             "exec_mode"))
def _paged_verify_attention(q, k_pages, v_pages, page_table, kv_len, q_len,
                            k_scale_pages, v_scale_pages, *,
                            window, softcap, mesh, exec_mode):
    B, W, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv

    def body(q, k_pages, v_pages, page_table, kv_len, q_len, k_scale_pages,
             v_scale_pages):
        if exec_mode == "ref":
            out = _ref.paged_verify_attention_ref(
                jnp.transpose(q.reshape(B, W, Hkv, G, D), (0, 2, 1, 3, 4)),
                k_pages, v_pages, page_table,
                jnp.broadcast_to(
                    jnp.reshape(kv_len, (-1,)).astype(jnp.int32), (B,)),
                jnp.broadcast_to(
                    jnp.reshape(q_len, (-1,)).astype(jnp.int32), (B,)),
                k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
                window=window, softcap=softcap)
            return jnp.transpose(out, (0, 2, 1, 3, 4)).reshape(B, W, H, D)
        # (B, W, H, D) → the kernel's (window, group)-ordered score-tile rows
        qg = jnp.transpose(q.reshape(B, W, Hkv, G, D),
                           (0, 2, 1, 3, 4)).reshape(B, Hkv, W * G, D)
        ks = vs = None
        if k_scale_pages is not None:
            ks = jnp.transpose(k_scale_pages[..., 0], (0, 2, 1))
            vs = jnp.transpose(v_scale_pages[..., 0], (0, 2, 1))
        # W == 1 forces q_len == 1 everywhere, and the verify mask at
        # q_len == 1 reduces exactly to the decode mask — dispatch to the
        # plain decode launch (no q_len prefetch operand)
        qw = dict(q_win=W, q_len=jnp.broadcast_to(
            jnp.reshape(q_len, (-1,)).astype(jnp.int32), (B,))) if W > 1 else {}
        out = _fa.paged_decode_attention_pallas(
            qg, k_pages, v_pages, page_table,
            jnp.broadcast_to(jnp.reshape(kv_len, (-1,)).astype(jnp.int32), (B,)),
            k_scale=ks, v_scale=vs, **qw,
            window=window, softcap=softcap, interpret=_interpret())
        return jnp.transpose(out.reshape(B, Hkv, W, G, D),
                             (0, 2, 1, 3, 4)).reshape(B, W, H, D)

    return hints.manual_kernel(
        body, (q, k_pages, v_pages, page_table, kv_len, q_len, k_scale_pages,
               v_scale_pages), mesh=mesh)


def paged_verify_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           page_table: jax.Array, kv_len: jax.Array,
                           q_len: jax.Array, *,
                           k_scale_pages=None, v_scale_pages=None,
                           window=None, softcap=None) -> jax.Array:
    """Paged draft-window verify attention (DESIGN.md §3.9): q (B, W, H, D) —
    W window tokens per slot, already scattered into the (P, ps, Hkv, D) pools
    — against the same page table / pools as ``paged_decode_attention``, with
    per-slot total post-scatter length ``kv_len`` and valid window rows
    ``q_len`` (window token i sits at ``kv_len - q_len + i``; rows ≥ q_len are
    garbage-but-finite) → (B, W, H, D).

    Same kernel, same double-buffered page DMA pipeline, same in-kernel int8-KV
    dequant points as decode — the only change is the per-row causal mask, so
    W == 1 is bitwise the decode step. Runs as one GSPMD-manual region under a
    TP-sharded plan: window rows ride the same replicated-q / sharded-kv-heads
    placement as decode queries."""
    return _paged_verify_attention(q, k_pages, v_pages, page_table, kv_len,
                                   q_len, k_scale_pages, v_scale_pages,
                                   window=window, softcap=softcap,
                                   mesh=hints.current_mesh(),
                                   exec_mode=_exec_mode())


@functools.partial(jax.jit, static_argnames=("chunk_cap", "window", "softcap",
                                             "mesh", "exec_mode"))
def _ragged_prefill_attention(q, k_new, v_new, k_pages, v_pages, page_table,
                              q_start, q_len, kv_len, k_scale_pages,
                              v_scale_pages, *, chunk_cap, window, softcap,
                              mesh, exec_mode):
    Nt, H, D = q.shape
    Hkv, ps = k_pages.shape[2], k_pages.shape[1]
    G = H // Hkv

    def body(q, k_new, v_new, k_pages, v_pages, page_table, q_start, q_len,
             kv_len, k_scale_pages, v_scale_pages):
        B = page_table.shape[0]
        if exec_mode == "ref":
            out = _ref.ragged_prefill_attention_ref(
                q.reshape(Nt, Hkv, G, D), k_new, v_new, k_pages, v_pages,
                page_table,
                jnp.reshape(q_start, (-1,)).astype(jnp.int32),
                jnp.broadcast_to(
                    jnp.reshape(q_len, (-1,)).astype(jnp.int32), (B,)),
                jnp.broadcast_to(
                    jnp.reshape(kv_len, (-1,)).astype(jnp.int32), (B,)),
                chunk_cap=chunk_cap, k_scale_pages=k_scale_pages,
                v_scale_pages=v_scale_pages, window=window, softcap=softcap)
            return out.reshape(Nt, H, D)
        # packed (Nt, H, D) → the kernel's head-major (Hkv, Npad, G, D) with
        # ps leading pad rows (mid-page chunk-start overlay offsets stay
        # in-bounds) and max(ps, chunk_cap) trailing pad rows (the per-page
        # overlay slice is ps rows wide and the blend writes chunk_cap rows —
        # both must stay in-bounds past the last slot); q_start shifts by the
        # leading pad
        trail = max(ps, chunk_cap)
        qg = jnp.transpose(q.reshape(Nt, Hkv, G, D), (1, 0, 2, 3))
        qp = jnp.pad(qg, ((0, 0), (ps, trail), (0, 0), (0, 0)))
        knp = jnp.pad(k_new, ((ps, trail), (0, 0), (0, 0)))
        vnp = jnp.pad(v_new, ((ps, trail), (0, 0), (0, 0)))
        ks = vs = None
        if k_scale_pages is not None:
            ks = jnp.transpose(k_scale_pages[..., 0], (0, 2, 1))
            vs = jnp.transpose(v_scale_pages[..., 0], (0, 2, 1))
        out = _fa.ragged_prefill_attention_pallas(
            qp, knp, vnp, k_pages, v_pages, page_table,
            jnp.reshape(q_start, (-1,)).astype(jnp.int32) + ps,
            jnp.broadcast_to(jnp.reshape(q_len, (-1,)).astype(jnp.int32), (B,)),
            jnp.broadcast_to(jnp.reshape(kv_len, (-1,)).astype(jnp.int32), (B,)),
            chunk_cap=chunk_cap, k_scale=ks, v_scale=vs,
            window=window, softcap=softcap, interpret=_interpret())
        return jnp.transpose(out[:, ps:ps + Nt], (1, 0, 2, 3)).reshape(Nt, H, D)

    return hints.manual_kernel(
        body, (q, k_new, v_new, k_pages, v_pages, page_table, q_start, q_len,
               kv_len, k_scale_pages, v_scale_pages), mesh=mesh)


def ragged_prefill_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                             k_pages: jax.Array, v_pages: jax.Array,
                             page_table: jax.Array, q_start: jax.Array,
                             q_len: jax.Array, kv_len: jax.Array, *,
                             chunk_cap: int, k_scale_pages=None,
                             v_scale_pages=None, window=None,
                             softcap=None) -> jax.Array:
    """Ragged chunked-prefill attention over the paged pool (DESIGN.md §3.10):
    q (Nt, H, D) — a packed ragged query block whose slot b owns rows
    ``[q_start[b], q_start[b] + q_len[b])`` (q_len ≤ ``chunk_cap``; 0 marks a
    dead slot) — against the same (P, ps, Hkv, D) pools / (B, maxP) page
    table / optional (P, ps, Hkv, 1) int8-KV scale pools as
    ``paged_decode_attention`` → (Nt, H, D), rows no slot owns zeroed.

    ``kv_len`` (B,) counts each slot's total visible tokens *after* this
    chunk's scatter, so the chunk spans absolute positions
    ``[kv_len - q_len, kv_len)`` and the causal mask is
    ``k_pos <= (kv_len - q_len) + i`` per chunk token i — cold prefill, warm
    radix-hit suffix prefill, mid-prompt chunks and single-token decode rows
    (q_len == 1) all serve through this one launch, with the chunk's own
    tokens read from the packed fp ``k_new``/``v_new`` (N, Hkv, D) instead of
    their freshly scattered (possibly int8) pool pages — the
    ``paged_prefill_attention`` fp-suffix overlay, in-kernel. Same
    double-buffered per-page DMA pipeline and in-kernel int8-KV dequant
    points as decode; runs as one GSPMD-manual region under a TP-sharded
    plan."""
    return _ragged_prefill_attention(q, k_new, v_new, k_pages, v_pages,
                                     page_table, q_start, q_len, kv_len,
                                     k_scale_pages, v_scale_pages,
                                     chunk_cap=chunk_cap, window=window,
                                     softcap=softcap,
                                     mesh=hints.current_mesh(),
                                     exec_mode=_exec_mode())


@functools.partial(jax.jit, static_argnames=("bits", "alpha", "bm", "bk", "mesh"))
def _act_quantize_padded(x, bcol, dyn_alpha, *, bits, alpha, bm, bk, mesh):
    """Shared pad → kernel → slice for the static- and traced-alpha wrappers.

    Zero row padding is exact (padded rows produce a = eps^alpha scale, sliced
    away); K padding pads bcol with 1 to avoid division by zero. Exactly one of
    ``alpha`` (static float) and ``dyn_alpha`` (traced scalar) is set.
    """
    M, K = x.shape
    bm = _pick_block(M, bm)
    bk = _pick_block(K, bk)

    def body(x, bcol, dyn_alpha):
        xp = _pad_to(x, 0, bm)
        xp = _pad_to(xp, 1, bk)
        pad_k = xp.shape[1] - K
        bcolp = jnp.concatenate([bcol.astype(jnp.float32),
                                 jnp.ones((pad_k,), jnp.float32)]) if pad_k else bcol
        al = alpha if dyn_alpha is None else dyn_alpha
        q, a = _aq.act_quantize_pallas(xp, bcolp, bits=bits, alpha=al, bm=bm, bk=bk,
                                       interpret=_interpret())
        return q[:M, :K], a[:M]

    return hints.manual_kernel(body, (x, bcol, dyn_alpha), mesh=mesh)


def act_quantize(x: jax.Array, bcol: jax.Array, *, bits: int = 8,
                 alpha: float = 0.15, bm: int = 256, bk: int = 512):
    """Fused CrossQuant activation quantization. x (M,K); bcol (K,) = c^(1-alpha).

    Returns (codes (M,K) int8, a (M,1) f32).
    """
    return _act_quantize_padded(x, bcol, None, bits=bits, alpha=alpha, bm=bm, bk=bk,
                                mesh=hints.current_mesh())


def act_quantize_dyn(x: jax.Array, bcol: jax.Array, alpha: jax.Array, *,
                     bits: int = 8, bm: int = 256, bk: int = 512):
    """:func:`act_quantize` with a *traced* CrossQuant exponent.

    The fused serving path slices ``qalpha`` out of a scanned prepared tree, so the
    exponent is a runtime scalar: it enters the kernel through SMEM instead of being
    baked into the lowering (one compiled kernel for all layers, DESIGN.md §3.3).
    """
    return _act_quantize_padded(x, bcol, jnp.asarray(alpha, jnp.float32),
                                bits=bits, alpha=None, bm=bm, bk=bk,
                                mesh=hints.current_mesh())
