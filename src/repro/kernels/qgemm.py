"""Pallas TPU kernels: quantized GEMMs with fused output-side dequantization.

These are the compute hot-spots of CrossQuant deployment (DESIGN.md §3.2):

* ``qgemm_w8a8`` — int8 × int8 → int32 MXU GEMM; the int32 accumulator lives in a VMEM
  scratch tile across the K grid axis and is dequantized once at the last K step by the
  separable scales ``a_i · sw_k`` (CrossQuant row factor × b-folded weight scale).
* ``qgemm_w4a8`` — same contraction with weights stored two int4 nibbles per byte,
  unpacked *in VMEM* (halving the weight HBM traffic — the paper's W4A8-g128 setting);
  per-group scales are applied per K-block so the K grid axis walks one g128 group per
  step and accumulates in f32.
* ``qgemm_w8a8_sparse`` — the int8 GEMM over N:M-pruned weights (DESIGN.md §3.12): a
  block-occupancy table rides scalar prefetch into SMEM and k-steps over all-zero
  weight blocks skip their MXU dot (skipping zeros is exact in integer arithmetic).

Tiling: MXU-aligned (multiples of 128 on M/N; K blocks of 256–512). The int8 tiles are
small (bm·bk + bk·bn bytes), so the working set stays well under the ~16 MB/core VMEM:
with (bm, bn, bk) = (256, 256, 512) the tiles are 128 KB + 128 KB + 256 KB accumulator.

Grid iteration order is (m, n, k) with k innermost — the accumulator scratch is
revisited by consecutive grid steps, the canonical TPU matmul pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------------------
# W8A8
# --------------------------------------------------------------------------------------

def _w8a8_kernel(qx_ref, qw_ref, a_ref, sw_ref, out_ref, acc_ref, *, n_k: int):
    """One (m, n, k) grid step: acc += qx_blk · qw_blk; dequant+write at k == n_k-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        qx_ref[...], qw_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _dequant():
        a = a_ref[...]                     # (bm, 1) f32
        sw = sw_ref[...]                   # (1, bn) f32
        out_ref[...] = acc_ref[...].astype(jnp.float32) * a * sw


def qgemm_w8a8_pallas(
    qx: jax.Array, qw: jax.Array, a: jax.Array, sw: jax.Array, *,
    bm: int = 256, bn: int = 256, bk: int = 512, interpret: bool = False,
) -> jax.Array:
    """qx (M,K) int8 · qw (K,N) int8 → (M,N) f32, dequant by a (M,1) · sw (1,N).

    M, K, N must be multiples of (bm, bk, bn) — the ops.py wrapper pads (zero padding
    is exact for integer GEMM).
    """
    M, K = qx.shape
    K2, N = qw.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"unpadded shapes M={M} K={K} N={N} for blocks {(bm, bk, bn)}")
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_w8a8_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bm, 1), lambda m, n, k: (m, 0)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(qx, qw, a, sw)


# --------------------------------------------------------------------------------------
# W8A8, block-sparse (N:M-pruned weights — DESIGN.md §3.12)
# --------------------------------------------------------------------------------------

def _w8a8_sparse_kernel(occ_ref, qx_ref, qw_ref, a_ref, sw_ref, out_ref, acc_ref,
                        *, n_k: int):
    """Dense kernel + one scalar gate: the (K//bk, N//bn) block-occupancy table is
    scalar-prefetched into SMEM, and a k-step whose weight block holds no surviving
    values skips its MXU dot entirely. Skipping is exact (an all-zero int8 block
    contributes 0 to the int32 accumulator), and with an all-ones table the step
    sequence is identical to :func:`_w8a8_kernel` — the bitwise-parity contract the
    tests pin. Init and dequant stay unconditional so fully-empty (m, n) tiles
    still write their (zero) output."""
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[k, n] > 0)
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            qx_ref[...], qw_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _dequant():
        out_ref[...] = acc_ref[...].astype(jnp.float32) * a_ref[...] * sw_ref[...]


def qgemm_w8a8_sparse_pallas(
    qx: jax.Array, qw: jax.Array, a: jax.Array, sw: jax.Array, occ: jax.Array, *,
    bm: int = 256, bn: int = 256, bk: int = 512, interpret: bool = False,
) -> jax.Array:
    """:func:`qgemm_w8a8_pallas` with a block-occupancy gate.

    occ: (K//bk, N//bn) int32, nonzero ⇔ the corresponding qw block holds at least
    one surviving weight. The caller (ops.py) derives it from the N:M mask leaf and
    guarantees qw is zero wherever the mask is — an occupancy of 0 over a nonzero
    block would silently drop its contribution.
    """
    M, K = qx.shape
    K2, N = qw.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"unpadded shapes M={M} K={K} N={N} for blocks {(bm, bk, bn)}")
    n_k = K // bk
    assert occ.shape == (n_k, N // bn) and occ.dtype == jnp.int32, (
        occ.shape, occ.dtype, (n_k, N // bn))
    grid = (M // bm, N // bn, n_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k, occ: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k, occ: (k, n)),
            pl.BlockSpec((bm, 1), lambda m, n, k, occ: (m, 0)),
            pl.BlockSpec((1, bn), lambda m, n, k, occ: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, occ: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_w8a8_sparse_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(occ, qx, qw, a, sw)


# --------------------------------------------------------------------------------------
# W4A8 (grouped scales, in-VMEM nibble unpack)
# --------------------------------------------------------------------------------------

def _w4a8_kernel(qx_ref, qw4_ref, a_ref, sw_ref, out_ref, acc_ref, *, n_k: int):
    """K grid axis walks one quantization group per step.

    qw4 block is (bk//2, bn) packed int4; unpack in VMEM (sign-extend both nibbles),
    contract in int8→int32 on the MXU, dequant the *group* partial sum by sw[g] and
    accumulate in f32 (per-group scales cannot be folded after the contraction).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = qw4_ref[...]                                   # (bk//2, bn) int8
    lo = jnp.left_shift(packed, 4)
    lo = jnp.right_shift(lo, 4)                             # sign-extended low nibble
    hi = jnp.right_shift(packed, 4)                         # arithmetic shift
    # interleave rows: unpacked row 2r = lo[r], row 2r+1 = hi[r]
    bk2, bn = packed.shape
    qw = jnp.stack([lo, hi], axis=1).reshape(2 * bk2, bn).astype(jnp.int8)

    part = jax.lax.dot_general(
        qx_ref[...], qw, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                   # (bm, bn) int32
    acc_ref[...] += part.astype(jnp.float32) * sw_ref[...]  # group dequant

    @pl.when(k == n_k - 1)
    def _finish():
        out_ref[...] = acc_ref[...] * a_ref[...]


def qgemm_w4a8_pallas(
    qx: jax.Array, qw4: jax.Array, a: jax.Array, sw: jax.Array, *,
    group: int = 128, bm: int = 256, bn: int = 256, interpret: bool = False,
) -> jax.Array:
    """qx (M,K) int8 · packed qw4 (K//2,N) int4-pairs → (M,N) f32.

    sw: (K//group, N) f32 per-group scales. K block == group size (one group per
    grid step, scales applied on the partial sum). K must divide by group; M, N padded
    by the wrapper.
    """
    M, K = qx.shape
    N = qw4.shape[1]
    assert qw4.shape[0] * 2 == K, (qw4.shape, K)
    assert K % group == 0 and M % bm == 0 and N % bn == 0
    n_k = K // group
    assert sw.shape == (n_k, N), (sw.shape, n_k, N)
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_w4a8_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, group), lambda m, n, k: (m, k)),
            pl.BlockSpec((group // 2, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bm, 1), lambda m, n, k: (m, 0)),
            pl.BlockSpec((1, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(qx, qw4, a, sw)
