"""deepseek-coder-33b [dense] — llama-arch. arXiv:2401.14196.
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab=32256,
    act="silu_glu", norm="rmsnorm", rope_theta=100000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    act="silu_glu", tie_embeddings=False,
)

register(FULL, SMOKE)
