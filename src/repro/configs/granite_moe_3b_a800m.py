"""granite-moe-3b-a800m [moe] — 40 experts top-8. hf:ibm-granite (granite-3.0 family).
32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40e top-8."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8, d_ff_expert=512, n_shared_experts=0,
    act="silu_glu", norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=256,
    n_experts=8, top_k=2, d_ff_expert=64,
    act="silu_glu",
)

register(FULL, SMOKE)
