"""Config system: model + shape + run configuration, with an arch registry.

Every assigned architecture is a :class:`ModelConfig` in its own module under
``repro/configs`` and registers itself (full config + reduced smoke config).
Shapes are global (the LM-family shape set of the assignment).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.core.qlinear import QuantConfig, FP


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    act: str = "silu_glu"             # silu_glu | gelu_glu | gelu | relu2
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    use_rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None      # sliding window for local layers
    layer_pattern: str = "global"     # global | local_global (gemma2 alternation)
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    embed_scale: bool = False         # gemma: x *= sqrt(d_model)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # hybrid (zamba2): one shared attention+MLP block applied every `attn_every` layers
    attn_every: int = 0

    # modality frontend stubs
    frontend: str = "none"            # none | vision_stub | audio_stub
    frontend_dim: int = 0
    n_patches: int = 0

    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    quant: QuantConfig = FP

    # -- derived ----------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Embedding/lm-head rows padded to a multiple of 256 so the vocab dimension
        divides every production TP degree (16/32/64); logits shard over the model
        axis instead of replicating (a 16× memory cliff on 50k-vocab models —
        EXPERIMENTS.md §Perf). Padded ids are masked to -1e9 in the lm head."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: no full-attention layer whose cost is O(S^2) over the
        whole 500k context at prefill, and decode state is O(1) or O(T) linear."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model FLOPs and memory estimates)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        n = V * d                                     # embedding
        if not self.tie_embeddings:
            n += V * d
        per_layer = 0
        if self.family in ("ssm", "hybrid"):
            di, N, G = self.d_inner, self.ssm_state, self.ssm_groups
            conv_ch = di + 2 * G * N
            per_layer = d * (2 * di + 2 * G * N + self.ssm_heads)   # in_proj
            per_layer += conv_ch * self.ssm_conv                     # conv
            per_layer += di * d                                      # out_proj
            per_layer += 3 * self.ssm_heads                          # A, D, dt_bias
            n += per_layer * L
            if self.family == "hybrid" and self.attn_every:
                hd = self.n_heads * self.head_dim
                kv = self.n_kv_heads * self.head_dim
                n += d * (hd + 2 * kv) + hd * d + 2 * d * self.d_ff  # one shared block
            return n
        hd = self.n_heads * self.head_dim
        kv = self.n_kv_heads * self.head_dim
        attn = d * (hd + 2 * kv) + hd * d
        if self.n_experts:
            dff = self.d_ff_expert or self.d_ff
            gate_mult = 3 if self.act.endswith("_glu") else 2
            mlp = d * self.n_experts * dff * gate_mult / (1 if True else 1)
            mlp = self.n_experts * (gate_mult * d * dff)
            mlp += d * self.n_experts                                # router
            mlp += self.n_shared_experts * (gate_mult * d * self.d_ff)
        else:
            gate_mult = 3 if self.act.endswith("_glu") else 2
            mlp = gate_mult * d * self.d_ff
        n += L * (attn + mlp)
        return n

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (for 6·N_active·D)."""
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.n_heads * self.head_dim
        kv = self.n_kv_heads * self.head_dim
        attn = d * (hd + 2 * kv) + hd * d
        gate_mult = 3 if self.act.endswith("_glu") else 2
        dff = self.d_ff_expert or self.d_ff
        mlp = self.top_k * gate_mult * d * dff + d * self.n_experts
        mlp += self.n_shared_experts * (gate_mult * d * self.d_ff)
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        return n + L * (attn + mlp)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_MODULES = [
    "mamba2_130m", "llama4_scout_17b_a16e", "granite_moe_3b_a800m", "nemotron_4_15b",
    "deepseek_coder_33b", "gemma2_9b", "starcoder2_7b", "zamba2_1_2b", "pixtral_12b",
    "hubert_xlarge",
]

_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> None:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke


def _load_all() -> None:
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get(name: str, smoke: bool = False) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    reg = _SMOKE if smoke else _REGISTRY
    key = name.replace("-", "_")
    for k, v in reg.items():
        if k.replace("-", "_") == key:
            return v
    raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")


def all_archs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs or is a documented skip."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention (DESIGN.md §6)"
    return True, ""


def with_quant(cfg: ModelConfig, quant: QuantConfig) -> ModelConfig:
    return dataclasses.replace(cfg, quant=quant)


def with_padded_heads(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad query heads up to a multiple of the TP degree (56 → 64 at tp=16, etc.).

    The padded model is *functionally identical* when the padded ``wo`` rows are zero
    (padded heads contribute exactly nothing — property-tested in tests/test_sharding);
    what changes is that attention projections become TP-shardable instead of
    replicated, the fix that makes 33B-class serving fit HBM (EXPERIMENTS.md §Perf).
    KV heads are left unpadded (padding them would inflate the KV cache); the GQA
    grouping stays integral because head counts and tp are powers-of-two-friendly.
    """
    if cfg.family in ("ssm",) or cfg.n_heads % tp == 0:
        return cfg
    nh = -(-cfg.n_heads // tp) * tp
    if nh % max(cfg.n_kv_heads, 1) != 0:
        return cfg          # padded grouping would not be integral — keep as-is
    return dataclasses.replace(cfg, n_heads=nh)
