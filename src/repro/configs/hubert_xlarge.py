"""hubert-xlarge [audio] — encoder-only transformer backbone (w2v2 arch), conv feature
extractor is a STUB (precomputed frame features). arXiv:2106.07447.
48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (target cluster classes).

Deviation note (DESIGN.md §5): positions via RoPE instead of the conv positional
embedding of the original — the backbone dims are the assignment's contract."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504,
    act="gelu", norm="layernorm", causal=False,
    frontend="audio_stub", frontend_dim=512, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=32,
    act="gelu", norm="layernorm", causal=False,
    frontend="audio_stub", frontend_dim=32, tie_embeddings=False,
)

register(FULL, SMOKE)
