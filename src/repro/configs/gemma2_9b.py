"""gemma2-9b [dense] — local+global alternating attention, logit softcaps. arXiv:2408.00118.
42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000,
    act="gelu_glu", norm="rmsnorm", layer_pattern="local_global", window=4096,
    attn_softcap=50.0, final_softcap=30.0, embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    act="gelu_glu", layer_pattern="local_global", window=16,
    attn_softcap=50.0, final_softcap=30.0, embed_scale=True,
)

register(FULL, SMOKE)
