"""mamba2-130m [ssm] — SSD (state-space duality), arXiv:2405.21060.
24L d_model=768, attention-free, vocab=50280, ssm_state=128."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    use_rope=False, norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab=256,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
    use_rope=False,
)

register(FULL, SMOKE)
