"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB: precomputed patch embeddings) +
mistral-nemo text backbone. hf:mistralai/Pixtral-12B-2409.
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072,
    act="silu_glu", norm="rmsnorm", rope_theta=1000000000.0,
    frontend="vision_stub", frontend_dim=1024, n_patches=256, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    act="silu_glu",
    frontend="vision_stub", frontend_dim=32, n_patches=8, tie_embeddings=False,
)

register(FULL, SMOKE)
