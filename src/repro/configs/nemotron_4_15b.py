"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP. arXiv:2402.16819.
32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000,
    act="relu2", norm="layernorm", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    act="relu2", norm="layernorm", tie_embeddings=False,
)

register(FULL, SMOKE)
