"""starcoder2-7b [dense] — GQA, RoPE. arXiv:2402.19173.
32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab=49152,
    act="gelu", norm="layernorm", rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    act="gelu", norm="layernorm",
)

register(FULL, SMOKE)
