"""Arch registry: repro.configs.get(name) / all_archs() / SHAPES."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, get, all_archs, register, cell_supported,
    with_quant, with_padded_heads,
)
