"""Self-drafting prompt-lookup (n-gram) drafter for speculative decoding.

DESIGN.md §3.9: the draft model *is* the request's own token history. To
propose a continuation the drafter takes the longest n-gram ending at the
history's tail (the pending token is always history[-1] — it was sampled but
not yet fed through the model), finds that n-gram's most recent *earlier*
occurrence, and proposes the tokens that followed it. No second model, no
extra device state: draft quality comes entirely from repetition in the
prompt + generated stream, which is exactly the regime (templated prompts,
code, retrieval-stuffed contexts) where speculative decoding pays.

The proposal is free to be wrong — the verify step scores the whole window
and the engine's greedy acceptance rule keeps output token-exact vs
non-speculative decode (tests/test_speculative.py) — so the drafter never
needs probabilities, only cheap host-side token matching.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_EMPTY = np.zeros((0,), np.int32)


@dataclasses.dataclass
class NGramDrafter:
    """Prompt-lookup drafter: longest-suffix n-gram match over the history.

    ``max_ngram`` bounds the suffix pattern length tried (longest first — a
    longer match is a stronger continuation signal); ``draft`` returns at most
    ``n`` tokens and degrades to an empty proposal on a miss, so the engine
    falls back to plain single-token decode for that slot.
    """
    max_ngram: int = 3

    def draft(self, history: np.ndarray, n: int) -> np.ndarray:
        """Propose ≤ n tokens continuing ``history`` (1-D int array; the last
        element is the pending token). Empty on a miss or degenerate input."""
        history = np.asarray(history)
        L = len(history)
        if n <= 0 or L < 2:
            return _EMPTY
        for size in range(min(self.max_ngram, L - 1), 0, -1):
            # all earlier occurrences of the tail n-gram at once (the drafter
            # runs on the host once per slot per verify step — a python scan
            # over starts costs as much as the step itself on small models)
            windows = np.lib.stride_tricks.sliding_window_view(history, size)
            pat = history[L - size:]
            starts = np.flatnonzero((windows[:L - size] == pat).all(axis=1))
            if starts.size == 0:
                continue
            # most recent occurrence *with a full n-token continuation*;
            # occurrences near the tail have their continuation truncated by
            # the end of the history — on a loop of period p < n the nearest
            # match is only p back and would cap every draft at p tokens,
            # while an occurrence one period earlier proposes the same loop at
            # full window length. Falls back to the most recent occurrence
            # (start + size ≤ L - 1, so at least one continuation token
            # always follows) when no full one exists.
            full = starts[starts + size + n <= L]
            best = int(full[-1] if full.size else starts[-1])
            return np.asarray(history[best + size: best + size + n], np.int32)
        return _EMPTY
