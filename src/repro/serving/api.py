"""Typed request/streaming objects for the serving API (DESIGN.md §3.11).

The async front end (``serving/server.py``) and the engine share this small
vocabulary: a user-facing :class:`Request`, per-token :class:`StreamEvent`
frames, a :class:`FinishReason` enum (also stamped by the engine on its
internal request records), per-request :class:`RequestMetrics`, and the typed
:class:`AdmissionError` the bounded admission queue raises when backpressure
holds past the deadline. Kept dependency-free (no jax import) so the engine
can import it without cycles.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class FinishReason(enum.Enum):
    """Why a sequence stopped emitting."""

    LENGTH = "length"          # hit max_new
    EOS = "eos"                # sampled the EOS token
    CACHE_FULL = "cache_full"  # per-slot KV cache exhausted (pos hit max_len)

    def __str__(self) -> str:  # json/csv friendly
        return self.value


class AdmissionError(RuntimeError):
    """Raised by ``AsyncServer.submit`` when admission backpressure holds past
    the deadline: the request is *rejected*, not queued — see DESIGN.md §3.11
    (rejecting beats LRU-thrashing the radix cache).

    ``reason`` types the rejection: ``"queue_full"`` (in-flight count at the
    bound) or ``"pool_pressure"`` (paged layouts: no alive replica's page pool
    can cover the request's worst-case page reservation — including requests
    whose reservation exceeds the pool outright, which no amount of waiting
    could ever serve)."""

    def __init__(self, msg: str, queue_wait_s: float = 0.0,
                 reason: str = "queue_full"):
        super().__init__(msg)
        self.queue_wait_s = queue_wait_s
        self.reason = reason


@dataclasses.dataclass
class Request:
    """One user-facing generation request for :class:`AsyncServer.submit`.

    ``prompt`` is a list of token ids (the repo serves token-level; tokenizers
    live outside). ``rid`` is optional — the server assigns a unique one when
    unset. ``replica_hint`` pins routing for tests/debugging; normal traffic
    leaves it ``None`` and lets the prefix-affinity router place the request.
    """

    prompt: List[int]
    max_new: int
    rid: Optional[str] = None
    replica_hint: Optional[int] = None

    def __post_init__(self):
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    """Per-request serving metrics, attached to the final ``finished`` event.

    ``ttft_s`` counts from admission to first token, ``tpot_s`` is the mean
    inter-token gap after the first, ``queue_wait_s`` is time spent in the
    admission queue before a replica picked the request up. ``prefix_reused``
    is the §3.8 radix hit length (prompt tokens served from cache), and
    ``kernel_proportion`` is the paper's §4.1 quantization-kernel proportion
    |S⊥|/|S| measured over this request's served activations (``None`` unless
    the server runs with ``kernel_stats=True``). ``requeues`` counts replica-
    failure migrations this request survived (0 on the happy path).
    """

    queue_wait_s: float = 0.0
    ttft_s: float = 0.0
    tpot_s: float = 0.0
    n_tokens: int = 0
    prefix_reused: int = 0
    replica: int = -1
    requeues: int = 0
    kernel_proportion: Optional[float] = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One frame of the ``submit()`` async stream.

    ``kind`` is ``"token"`` (carries ``token``), ``"finished"`` (carries
    ``finish_reason`` + ``metrics``; terminal), or ``"error"`` (carries
    ``error``; terminal — only emitted when no survivor replica could finish
    the request)."""

    kind: str
    rid: str
    token: Optional[int] = None
    finish_reason: Optional[FinishReason] = None
    metrics: Optional[RequestMetrics] = None
    error: Optional[str] = None

    @property
    def terminal(self) -> bool:
        return self.kind in ("finished", "error")
