"""Host-side page bookkeeping for the paged KV cache (DESIGN.md §3.8).

Two pieces, both pure numpy/python (no JAX): the device side of the paged cache
is just two arrays per layer (a page pool and a page table — models/model.py::
init_cache(layout="paged")), so all allocation policy lives here where it is
cheap to test exhaustively.

* :class:`PagePool` — a ref-counted free-list allocator over ``n_pages`` physical
  pages. A page is held by every active sequence whose page table references it
  plus (optionally) the radix index retaining it as a cached prefix; it returns
  to the free list when the last reference drops.

* :class:`RadixIndex` — a radix tree over *page-sized token chunks*: node =
  one full page of prompt tokens, child edges keyed by the exact chunk content.
  Admission walks the tree to find the longest previously-prefilled prefix;
  matched pages are mapped into the new request's page table **copy-free** (the
  pool just increfs). A partially matching tail chunk is reported separately so
  the engine can copy-on-write the first ``j`` token rows into a fresh page
  instead of re-prefilling them. Retained prefixes are evicted LRU-leaf-first
  under pool pressure.

Why sharing is exact (not approximate): CrossQuant / per-token KV quantization
is deterministic — identical prefix tokens produce identical K/V, hence
bit-identical int8 codes and scale rows — so a shared page is byte-for-byte the
page a cold prefill would have written (DESIGN.md §3.8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class PagePool:
    """Ref-counted allocator over ``n_pages`` physical KV pages.

    ``refs[p] == 0``  ⇔  page ``p`` is on the free list. Sequences and the radix
    index each hold one reference per page they retain.
    """

    def __init__(self, n_pages: int):
        assert n_pages > 0
        self.n_pages = n_pages
        self.refs = np.zeros(n_pages, np.int32)
        # stack: pop() hands out low page ids first (easier to read in tests)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` fresh pages with refcount 1, or None if the pool can't cover it
        (caller decides whether to evict cached prefixes and retry)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.refs[pages] = 1
        return pages

    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            assert self.refs[p] > 0, f"incref on free page {p}"
            self.refs[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; pages reaching zero return to the free
        list (returned for the caller's stats)."""
        freed = []
        for p in pages:
            assert self.refs[p] > 0, f"decref on free page {p}"
            self.refs[p] -= 1
            if self.refs[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def check(self) -> None:
        """Invariants (tests): free list and refcounts partition the pool."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate pages on the free list"
        for p in range(self.n_pages):
            if p in free:
                assert self.refs[p] == 0, f"page {p} free with refs {self.refs[p]}"
            else:
                assert self.refs[p] > 0, f"page {p} leaked (refs 0, not free)"


@dataclasses.dataclass
class _Node:
    chunk: bytes                       # the page's token content (ps int32 tokens)
    page: int                          # physical page id holding this chunk's KV
    parent: Optional["_Node"]
    children: Dict[bytes, "_Node"] = dataclasses.field(default_factory=dict)
    touch: int = 0                     # LRU clock at last match/insert


@dataclasses.dataclass
class PartialHit:
    """The tail chunk of a match that extends ``tokens`` only partially: the
    first ``length`` token rows of cached page ``page`` can be copy-on-write'd
    into a fresh page instead of re-prefilled."""
    page: int
    length: int


class RadixIndex:
    """Radix tree over page-sized prompt chunks (see module docstring)."""

    def __init__(self, page_size: int):
        self.ps = page_size
        self._root = _Node(chunk=b"", page=-1, parent=None)
        self._clock = 0
        self.n_nodes = 0

    # ------------------------------------------------------------------ match

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: np.ndarray) -> Tuple[List[int], int, Optional[PartialHit]]:
        """Longest cached prefix of ``tokens`` at full-page granularity.

        Returns ``(pages, matched_tokens, partial)``: the physical pages of every
        fully matched chunk (``matched_tokens == len(pages) * page_size``), plus
        an optional :class:`PartialHit` when some child chunk of the deepest node
        shares a further proper prefix with the remaining tokens. Matched nodes
        are LRU-touched. The caller caps the usable prefix (a request must keep
        at least one suffix token to prefill).
        """
        tokens = np.asarray(tokens, np.int32)
        node, pages, off = self._root, [], 0
        now = self._tick()
        while off + self.ps <= len(tokens):
            child = node.children.get(tokens[off: off + self.ps].tobytes())
            if child is None:
                break
            child.touch = now
            pages.append(child.page)
            node, off = child, off + self.ps
        partial = None
        rest = tokens[off:]
        if len(rest) > 0:
            best = 0
            for child in node.children.values():
                chunk = np.frombuffer(child.chunk, np.int32)
                n = min(len(rest), len(chunk))
                eq = chunk[:n] == rest[:n]
                lcp = int(n if eq.all() else int(np.argmin(eq)))
                if 0 < lcp < self.ps and lcp > best:
                    best = lcp
                    partial = PartialHit(page=child.page, length=lcp)
                    child.touch = now
        return pages, off, partial

    # ----------------------------------------------------------------- insert

    def insert(self, tokens: np.ndarray, pages: Sequence[int], pool: PagePool) -> int:
        """Register every full-page chunk of ``tokens`` along one root path.

        ``pages[k]`` is the physical page holding chunk ``k``'s KV. Chunks
        already present keep their existing page (the new request mapped it
        copy-free anyway); new nodes take one pool reference — the index's own
        retain — released on eviction. Returns the number of nodes created.
        """
        tokens = np.asarray(tokens, np.int32)
        node, created, now = self._root, 0, self._tick()
        for k in range(min(len(tokens) // self.ps, len(pages))):
            key = tokens[k * self.ps: (k + 1) * self.ps].tobytes()
            child = node.children.get(key)
            if child is None:
                child = _Node(chunk=key, page=pages[k], parent=node)
                node.children[key] = child
                pool.incref([pages[k]])
                self.n_nodes += 1
                created += 1
            child.touch = now
            node = child
        return created

    # ------------------------------------------------------------------ evict

    def _leaves(self) -> List[_Node]:
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def evict(self, pool: PagePool, n_needed: int) -> int:
        """Drop LRU cached prefixes until ``n_needed`` pages are free (or no
        evictable node remains). Only *unreferenced* prefixes are evictable: a
        leaf whose page is held solely by the index (``refs == 1``). Evicting a
        leaf may expose its parent; the scan repeats until dry. Returns the
        number of pages actually freed."""
        freed = 0
        while pool.free_count < n_needed:
            cands = [n for n in self._leaves() if pool.refs[n.page] == 1]
            if not cands:
                break
            victim = min(cands, key=lambda n: n.touch)
            del victim.parent.children[victim.chunk]
            self.n_nodes -= 1
            freed += len(pool.decref([victim.page]))
        return freed

    def held_pages(self) -> List[int]:
        """Every page currently retained by the index (tests/invariants)."""
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            out.append(n.page)
            stack.extend(n.children.values())
        return out
