"""Validated serving configuration + unified engine statistics (DESIGN.md §3.11).

``EngineConfig`` is the single typed surface for every serving knob that used to
live in ``ServeEngine.__init__``'s 20-kwarg sprawl: a frozen dataclass whose
``__post_init__`` holds all cross-field validation (the chunked/paged/
token-budget/speculate checks), so an invalid combination fails the same way
whether it arrives through ``ServeEngine(cfg, params, config=...)``, the legacy
kwarg shim, a JSON file (``from_json``), or a CLI (``add_config_args`` derives
the flag set from the dataclass fields — new fields appear in every CLI
automatically). Model-dependent checks live in
:meth:`EngineConfig.check_model`, called by the engine once it knows the
``ModelConfig``: SSM/hybrid families serve through the continuous slot-table
scheduler like everyone else (DESIGN.md §3.13), and only the combinations that
genuinely cannot work on recurrent state are rejected — each with its own
:class:`UnsupportedModelError` subclass so callers (and the async server's
error mapping) can branch on the reason instead of parsing messages.

``EngineStats`` unifies the engine's scattered stats accessors (``occupancy()``,
``prefix_hit_rate()``, ``accept_rate()``, ``tokens_per_step()``) behind one
``ServeEngine.stats()`` call with a stable ``to_dict()`` schema shared by
``benchmarks/serving_bench.py`` and the async server's metrics endpoint.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

import numpy as np

#: serving path → QuantContext wiring (DESIGN.md §3.3). ``None`` keeps the legacy
#: behaviour: whatever the params tree + quant config imply, on the jnp ref
#: backend. The engine turns these into QuantContext kwargs; the config only
#: validates membership.
SERVE_PATHS: Dict[Optional[str], Dict[str, Any]] = {
    None: {},
    "fp": {},
    "fake": {},
    "dequant-fp": {"int_exec": "dequant"},
    "fused-int8": {"int_exec": "pallas", "use_pallas": True},
}


# ==========================================================================
# Typed model-compatibility rejections (DESIGN.md §3.13)
# ==========================================================================

class UnsupportedModelError(ValueError):
    """An :class:`EngineConfig` combination this model family cannot serve.

    Subclasses carry the *reason*; all are ``ValueError`` so pre-§3.13
    callers that caught that keep working."""


class SpeculativeStateError(UnsupportedModelError):
    """``speculate > 1`` on an SSM/hybrid family: the recurrence advances
    destructively per scattered token, so rejected draft tokens cannot be
    rewound (DESIGN.md §3.9)."""


class PrefixReuseStateError(UnsupportedModelError):
    """``prefix_reuse`` on a paged SSM/hybrid family: radix reuse restarts a
    prompt from a mid-sequence page boundary, which position-indexed KV pages
    support but a single end-of-prefix state checkpoint does not (DESIGN.md
    §3.8/§3.13)."""


class ChunkedStateError(UnsupportedModelError):
    """``chunked=True`` on an SSM/hybrid family: the packed ragged step
    scatters interleaved chunks of many slots, which needs position-indexed
    cache writes the recurrent state does not have (DESIGN.md §3.10)."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen, JSON-serializable serving configuration (DESIGN.md §3.11).

    Required: ``batch_size`` (slot-table width) and ``max_len`` (per-slot cache
    length). Everything else defaults to the dense continuous batcher with
    greedy sampling. ``cache_dtype`` is stored as a canonical dtype *name*
    (``"bfloat16"``) so configs round-trip losslessly through JSON; ``None``
    means "follow the params dtype". ``prefill_buckets`` is a tuple (JSON lists
    convert on the way in).
    """

    batch_size: int
    max_len: int
    eos_id: Optional[int] = None
    path: Optional[str] = None
    kv_cache: str = "fp"
    cache_layout: str = "dense"
    page_size: int = 8
    n_pages: Optional[int] = None
    prefix_reuse: bool = True
    cache_dtype: Optional[str] = None
    scheduler: str = "continuous"
    prefill_buckets: Optional[Tuple[int, ...]] = None
    chunked: bool = False
    token_budget: int = 64
    speculate: int = 1
    drafter_ngram: int = 3
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    sparsity: str = "none"

    def __post_init__(self):
        # normalize before validating: JSON hands lists/np dtypes through the
        # same constructor the engine shim uses
        if self.prefill_buckets is not None:
            object.__setattr__(self, "prefill_buckets",
                               tuple(int(b) for b in self.prefill_buckets))
        if self.cache_dtype is not None:
            object.__setattr__(self, "cache_dtype",
                               np.dtype(self.cache_dtype).name)
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.path not in SERVE_PATHS:
            raise ValueError(f"unknown serving path {self.path!r}; "
                             f"pick one of {sorted(k for k in SERVE_PATHS if k)}")
        if self.kv_cache not in ("fp", "int8"):
            raise ValueError(f"kv_cache must be 'fp' or 'int8', got "
                             f"{self.kv_cache!r}")
        if self.cache_layout not in ("dense", "paged"):
            raise ValueError(f"cache_layout must be 'dense' or 'paged', got "
                             f"{self.cache_layout!r}")
        if self.scheduler not in ("continuous", "grouped"):
            raise ValueError(f"scheduler must be 'continuous' or 'grouped', "
                             f"got {self.scheduler!r}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.cache_layout == "paged" and self.scheduler != "continuous":
            raise ValueError("the paged layout serves through the continuous "
                             "scheduler (the grouped baseline stays dense)")
        if self.speculate < 1:
            raise ValueError(f"speculate must be >= 1, got {self.speculate}")
        if self.chunked:
            if self.cache_layout != "paged":
                raise ValueError("chunked=True needs cache_layout='paged' "
                                 "(chunks scatter through the page table)")
            if self.token_budget < self.batch_size * self.speculate:
                raise ValueError(
                    f"token_budget {self.token_budget} < batch_size*speculate "
                    f"{self.batch_size * self.speculate}: every generating "
                    f"slot's decode row (or draft window) must fit each step")
        if self.sparsity != "none":
            from repro.models.quantize import parse_nm
            parse_nm(self.sparsity)          # raises on malformed N:M
        if self.speculate > 1:
            if self.temperature > 0.0:
                raise ValueError("speculate > 1 requires greedy sampling "
                                 "(temperature <= 0): acceptance is token-"
                                 "exact only under deterministic sampling")
            if self.scheduler != "continuous":
                raise ValueError("speculate > 1 requires the continuous "
                                 "scheduler (per-slot draft windows)")

    # ----------------------------------------------------------- model checks

    def check_model(self, cfg) -> None:
        """Model-dependent validation the pure config cannot do (§3.13).

        SSM / hybrid families serve continuous, paged, sharded and grouped
        exactly like attention families — only the combinations their
        recurrent state genuinely cannot support are rejected, each with a
        typed :class:`UnsupportedModelError` subclass per reason."""
        stateful = cfg.family in ("ssm", "hybrid")
        if not stateful:
            return
        if self.speculate > 1:
            raise SpeculativeStateError(
                f"speculate > 1 cannot serve family {cfg.family!r}: the SSM "
                f"recurrence cannot rewind rejected draft tokens (§3.9)")
        if self.cache_layout == "paged" and self.prefix_reuse:
            raise PrefixReuseStateError(
                f"radix prefix reuse cannot serve family {cfg.family!r}: a "
                f"state checkpoint cannot restart a prompt from a mid-"
                f"sequence page boundary — pass prefix_reuse=False (§3.13)")
        if self.chunked:
            raise ChunkedStateError(
                f"chunked serving cannot serve family {cfg.family!r}: packed "
                f"ragged chunks need position-indexed cache writes, which "
                f"the recurrent state does not have (§3.10)")

    # ------------------------------------------------------------------- JSON

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d["prefill_buckets"] is not None:
            d["prefill_buckets"] = list(d["prefill_buckets"])
        return d

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "EngineConfig":
        return cls.from_kwargs(**d)

    @classmethod
    def from_json(cls, blob) -> "EngineConfig":
        """Build from a JSON string / parsed dict. Round-trip lossless:
        ``EngineConfig.from_json(cfg.to_json()) == cfg``."""
        if isinstance(blob, str):
            blob = json.loads(blob)
        return cls.from_dict(blob)

    @classmethod
    def from_kwargs(cls, **kw) -> "EngineConfig":
        """The legacy-kwarg shim's constructor: reject unknown keys with the
        TypeError a direct ``ServeEngine(**kw)`` call used to raise."""
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(kw) - fields)
        if unknown:
            raise TypeError(f"unknown engine config field(s): {unknown}; "
                            f"valid fields: {sorted(fields)}")
        return cls(**kw)


# ==========================================================================
# CLI derivation: flags come from the dataclass fields, not hand-kept lists
# ==========================================================================

#: fields whose argparse help benefits from a one-liner; anything not listed
#: still gets a flag (the point: new config fields appear in every CLI
#: automatically, DESIGN.md §3.11)
_FIELD_HELP = {
    "batch_size": "slot-table width (concurrent sequences)",
    "max_len": "per-slot KV cache length",
    "eos_id": "EOS token id; default: no EOS (token 0 is PAD)",
    "path": "integer execution backend (DESIGN.md §3.3)",
    "kv_cache": "decode K/V storage: fp or int8 codes + per-token scales",
    "cache_layout": "dense slot table (§3.6) or paged pool + radix reuse (§3.8)",
    "page_size": "tokens per KV page (paged layout)",
    "n_pages": "page-pool capacity; default batch_size*max_len/page_size",
    "prefix_reuse": "radix prefix reuse on the paged layout",
    "cache_dtype": "fp KV-cache dtype name; default: params dtype",
    "scheduler": "continuous (slot refill mid-decode) or grouped baseline",
    "prefill_buckets": "comma-separated padded-prefill lengths",
    "chunked": "chunked prefill + prefill-decode interleaving (§3.10)",
    "token_budget": "per-step token budget for chunked serving",
    "speculate": "draft-window size K for speculative decoding (§3.9)",
    "drafter_ngram": "max n-gram length of the prompt-lookup drafter",
    "temperature": "sampling temperature; 0 = greedy",
    "top_k": "top-k sampling cutoff; 0 = disabled",
    "seed": "sampling PRNG seed",
    "sparsity": "N:M structured weight sparsity applied at engine build (§3.12)",
}

_FIELD_CHOICES = {
    "path": [p for p in SERVE_PATHS if p],
    "kv_cache": ["fp", "int8"],
    "cache_layout": ["dense", "paged"],
    "scheduler": ["continuous", "grouped"],
    "sparsity": ["none", "2:4", "4:8"],
}


def _base_type(f: dataclasses.Field):
    t = f.type if not isinstance(f.type, str) else f.type
    s = str(t)
    for name, py in (("int", int), ("float", float), ("bool", bool),
                     ("str", str)):
        if name in s:
            return py
    return str


def add_config_args(parser: argparse.ArgumentParser,
                    prefix: str = "") -> None:
    """Add one ``--<field>`` flag per :class:`EngineConfig` field (underscores
    become dashes). Every flag defaults to *unset* so layering works:
    ``--config file.json`` values win unless the flag is given explicitly
    (:func:`config_from_args`). Bools get ``--x/--no-x`` pairs."""
    group = parser.add_argument_group("engine config (serving/config.py)")
    for f in dataclasses.fields(EngineConfig):
        flag = f"--{prefix}{f.name.replace('_', '-')}"
        helptext = _FIELD_HELP.get(f.name, f.name)
        ftype = _base_type(f)
        if ftype is bool:
            group.add_argument(flag, default=None, help=helptext,
                               action=argparse.BooleanOptionalAction)
        elif f.name == "prefill_buckets":
            group.add_argument(flag, default=None, metavar="B1,B2,...",
                               type=lambda s: tuple(int(x)
                                                    for x in s.split(",")),
                               help=helptext)
        else:
            group.add_argument(flag, default=None, type=ftype,
                               choices=_FIELD_CHOICES.get(f.name),
                               help=helptext)


def config_from_args(args: argparse.Namespace,
                     base: Optional[EngineConfig] = None,
                     **defaults) -> EngineConfig:
    """Layer CLI flags over ``base`` (usually ``--config file.json``) over
    ``defaults`` (the calling script's choices) to build the final config.
    Only flags the user actually passed override the layers below."""
    merged: Dict[str, Any] = dict(defaults)
    if base is not None:
        merged.update(base.to_dict())
    for f in dataclasses.fields(EngineConfig):
        v = getattr(args, f.name, None)
        if v is not None:
            merged[f.name] = v
    return EngineConfig.from_kwargs(**merged)


# ==========================================================================
# Unified engine statistics
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class EngineStats:
    """One snapshot of a ``ServeEngine``'s derived rates + raw counters.

    The derived fields are exactly what the legacy accessors returned
    (``occupancy()`` etc., now thin delegates); ``counters`` is a copy of the
    engine's raw counter dict. ``to_dict()`` flattens both into the stable
    schema ``serving_bench`` rows and the async server's ``metrics()``
    endpoint share — derived rates first, counters after, all floats/ints.
    """

    occupancy: float
    prefix_hit_rate: float
    accept_rate: float
    tokens_per_step: float
    counters: Dict[str, int]

    def to_dict(self) -> dict:
        return {"occupancy": self.occupancy,
                "prefix_hit_rate": self.prefix_hit_rate,
                "accept_rate": self.accept_rate,
                "tokens_per_step": self.tokens_per_step,
                **self.counters}

    @classmethod
    def from_counters(cls, counters: Dict[str, int],
                      batch_size: int) -> "EngineStats":
        c = dict(counters)
        steps = c.get("decode_steps", 0)
        occ = c.get("active_slot_steps", 0) / (steps * batch_size) if steps else 0.0
        prompt = c.get("prompt_tokens", 0)
        hit = c.get("prefix_tokens_reused", 0) / prompt if prompt else 0.0
        drafted = c.get("spec_drafted", 0)
        acc = c.get("spec_accepted", 0) / drafted if drafted else 0.0
        sss = c.get("spec_slot_steps", 0)
        tps = c.get("spec_emitted", 0) / sss if sss else 0.0
        return cls(occupancy=occ, prefix_hit_rate=hit, accept_rate=acc,
                   tokens_per_step=tps, counters=c)
