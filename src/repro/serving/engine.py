"""Serving engine: prefill / decode step builders + a host-side continuous batcher.

Step functions are pure and jit/pjit-ready: the dry-run lowers exactly these. The
engine serves raw-fp params (fp or fake-quant CrossQuant activations — the
paper-faithful W8A8 evaluation path) or a prepared integer tree from
``models.quantize.quantize_tree``, executed through one of three integer backends
(``path`` — DESIGN.md §3.3):

* ``"fake"``       — fp weights, fake-quant activations (accuracy-evaluation path).
* ``"dequant-fp"`` — prepared tree, codes dequantized to f32 before an fp GEMM
                     (weight-storage savings only; the serving baseline).
* ``"fused-int8"`` — prepared tree through the Pallas ``act_quantize → qgemm``
                     kernels: true int8×int8→int32 contractions per layer
                     (Mosaic on TPU, ``interpret=True`` off-TPU so CI runs it).

``kv_cache="int8"`` additionally stores decode K/V as int8 codes + per-token scales
(models.layers.kv_quantize), cutting decode-step cache HBM traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext

#: serving path → QuantContext wiring (DESIGN.md §3.3). ``None`` keeps the legacy
#: behaviour: whatever the params tree + quant config imply, on the jnp ref backend.
SERVE_PATHS = {
    None: {},
    "fp": {},
    "fake": {},
    "dequant-fp": {"int_exec": "dequant"},
    "fused-int8": {"int_exec": "pallas", "use_pallas": True},
}


def _make_ctx(cfg: ModelConfig, quant: Optional[ql.QuantConfig],
              path: Optional[str]) -> QuantContext:
    if path not in SERVE_PATHS:
        raise ValueError(f"unknown serving path {path!r}; "
                         f"pick one of {sorted(k for k in SERVE_PATHS if k)}")
    return QuantContext(quant or cfg.quant, **SERVE_PATHS[path])


def make_prefill_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                      *, path: Optional[str] = None):
    ctx = _make_ctx(cfg, quant, path)

    def prefill_step(params, batch, caches):
        """batch tokens (B, S) → (last-position logits (B,1,V), filled caches)."""
        S = (batch["frames"].shape[1] if "frames" in batch else batch["tokens"].shape[1])
        if cfg.is_encoder_only:
            logits, _ = M.apply(params, batch, cfg, ctx=ctx, mode="train")
            return logits[:, -1:], caches
        logits, ex = M.apply(params, batch, cfg, ctx=ctx, mode="prefill",
                             caches=caches, cur_len=jnp.asarray(S, jnp.int32))
        return logits, ex["caches"]

    return prefill_step


def make_decode_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                     *, path: Optional[str] = None):
    ctx = _make_ctx(cfg, quant, path)

    def decode_step(params, tokens, caches, cur_len):
        """tokens (B,1) + caches + cur_len (scalar int32, post-append length)
        → (logits (B,1,V), updated caches)."""
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx, mode="decode",
                             caches=caches, cur_len=cur_len)
        return logits, ex["caches"]

    return decode_step


# ======================================================================================
# Host-side continuous batcher (end-to-end serving example / integration tests)
# ======================================================================================

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Batched greedy serving over a fixed-size slot table.

    Requests with equal prompt lengths are prefetched together (the batcher groups by
    length); decode advances all active slots in lock-step, retiring finished requests
    and refilling slots — the standard continuous-batching loop, single-host edition.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int, max_len: int,
                 quant: Optional[ql.QuantConfig] = None, eos_id: int = 0,
                 path: Optional[str] = None, kv_cache: str = "fp"):
        assert kv_cache in ("fp", "int8"), kv_cache
        self.cfg, self.params = cfg, params
        self.B, self.T = batch_size, max_len
        self.eos = eos_id
        self.kv_int8 = kv_cache == "int8"
        self.prefill = jax.jit(make_prefill_step(cfg, quant, path=path))
        self.decode = jax.jit(make_decode_step(cfg, quant, path=path))
        self.queue: List[Request] = []

    def submit(self, prompts: List[np.ndarray], max_new: int = 16) -> List[Request]:
        reqs = [Request(i, np.asarray(p, np.int32), max_new)
                for i, p in enumerate(prompts)]
        self.queue.extend(reqs)
        return reqs

    def run(self) -> List[Request]:
        done: List[Request] = []
        while self.queue:
            group_len = len(self.queue[0].prompt)
            group = [r for r in self.queue if len(r.prompt) == group_len][: self.B]
            self.queue = [r for r in self.queue if r not in group]
            done.extend(self._serve_group(group, group_len))
        return done

    def _serve_group(self, group: List[Request], plen: int) -> List[Request]:
        B = self.B
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(group):
            toks[i] = r.prompt
        caches = M.init_cache(self.cfg, B, self.T, dtype=jnp.float32,
                              kv_int8=self.kv_int8)
        logits, caches = self.prefill(self.params, {"tokens": jnp.asarray(toks)}, caches)
        cur = plen
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in group)
        for step in range(max_new):
            for i, r in enumerate(group):
                if not r.done and step < r.max_new:
                    t = int(next_tok[i])
                    r.out.append(t)
                    if t == self.eos:
                        r.done = True
            cur += 1
            if cur >= self.T or all(r.done for r in group):
                break
            logits, caches = self.decode(self.params, next_tok[:, None], caches,
                                         jnp.asarray(cur, jnp.int32))
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for r in group:
            r.done = True
        return group
