"""Serving engine: prefill / decode step builders + a slot-table continuous batcher.

Step functions are pure and jit/pjit-ready: the dry-run lowers exactly these. The
engine serves raw-fp params (fp or fake-quant CrossQuant activations — the
paper-faithful W8A8 evaluation path) or a prepared integer tree from
``models.quantize.quantize_tree``, executed through one of three integer backends
(``path`` — DESIGN.md §3.3):

* ``"fake"``       — fp weights, fake-quant activations (accuracy-evaluation path).
* ``"dequant-fp"`` — prepared tree, codes dequantized to f32 before an fp GEMM
                     (weight-storage savings only; the serving baseline).
* ``"fused-int8"`` — prepared tree through the Pallas ``act_quantize → qgemm``
                     kernels: true int8×int8→int32 contractions per layer
                     (Mosaic on TPU, ``interpret=True`` off-TPU so CI runs it).

``kv_cache="int8"`` additionally stores decode K/V as int8 codes + per-token scales
(models.layers.kv_quantize), cutting decode-step cache HBM traffic.

Continuous batching (DESIGN.md §3.6): ``ServeEngine`` keeps a fixed slot table of
``batch_size`` sequences with **per-slot lengths** — ``cur_len`` is a ``(B,)`` int32
vector all the way down to the attention masks and cache scatter positions. New
requests are admitted into free slots mid-decode via length-bucketed padded prefill
(a small static set of prefill shapes bounds recompilation); finished requests retire
and free their slot immediately. The decode step is a single jit'd function that
folds greedy/temperature/top-k sampling in on-device, so the host loop only moves
int32 token ids.

Paged KV cache + radix prefix reuse (DESIGN.md §3.8): ``cache_layout="paged"``
swaps the dense per-slot cache rows for a physical page pool addressed through a
page table, with a host-side ref-counted allocator and a radix index over prompt
chunks (serving/paging.py). Previously prefilled prefixes map into new requests
copy-free (CrossQuant codes+scales are deterministic, so int8 pages share
bit-exactly), partial tail pages copy-on-write, only the suffix prefills, and
LRU-unreferenced cached prefixes evict under pool pressure.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import qlinear as ql
from repro.models import model as M, state as state_lib
from repro.models.layers import QuantContext
from repro.serving import drafter, paging
from repro.serving.api import FinishReason
from repro.serving.config import SERVE_PATHS, EngineConfig, EngineStats
from repro.sharding import hints, planner

#: one DeprecationWarning per process for the legacy-kwarg ServeEngine surface
#: (tests reset this to assert the shim warns exactly once)
_LEGACY_KWARGS_WARNED = False


def _make_ctx(cfg: ModelConfig, quant: Optional[ql.QuantConfig],
              path: Optional[str]) -> QuantContext:
    if path not in SERVE_PATHS:
        raise ValueError(f"unknown serving path {path!r}; "
                         f"pick one of {sorted(k for k in SERVE_PATHS if k)}")
    return QuantContext(quant or cfg.quant, **SERVE_PATHS[path])


def _make_sampler(temperature: float, top_k: int):
    """On-device sampler: greedy at temperature 0, else temperature + top-k.

    Padded vocab ids carry -1e9 logits (models.model._lm_head), so they are never
    sampled on either branch."""

    def sample(logits: jax.Array, key: jax.Array) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / temperature
        if top_k and top_k > 0:
            kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)

    return sample


def make_prefill_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                      *, path: Optional[str] = None):
    ctx = _make_ctx(cfg, quant, path)

    def prefill_step(params, batch, caches):
        """batch["tokens"] (B, S) right-padded prompts → (last-valid-position logits
        (B, 1, V), filled caches). An optional batch["lens"] (B,) int32 gives per-slot
        prompt lengths (absent → all slots are length S)."""
        S = (batch["frames"].shape[1] if "frames" in batch else batch["tokens"].shape[1])
        if cfg.is_encoder_only:
            logits, _ = M.apply(params, batch, cfg, ctx=ctx, mode="train")
            return logits[:, -1:], caches
        lens = batch.get("lens")
        cur = jnp.asarray(S, jnp.int32) if lens is None else lens
        logits, ex = M.apply(params, batch, cfg, ctx=ctx, mode="prefill",
                             caches=caches, cur_len=cur)
        return logits, ex["caches"]

    return prefill_step


def make_decode_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                     *, path: Optional[str] = None):
    ctx = _make_ctx(cfg, quant, path)

    def decode_step(params, tokens, caches, cur_len):
        """tokens (B,1) + caches + cur_len (scalar int32 or (B,) vector of per-slot
        post-append lengths) → (logits (B,1,V), updated caches)."""
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx, mode="decode",
                             caches=caches, cur_len=cur_len)
        return logits, ex["caches"]

    return decode_step


# ======================================================================================
# Slot-scatter cache ops (admission into a live batch)
# ======================================================================================

def _map_batch_axis(caches: dict, fn_stacked, fn_flat) -> dict:
    """Apply per-leaf fns keyed by where the slot axis sits: scanned leaves
    (``blocks``/``shared``) are stacked (n_blocks, B, ...) — batch axis 1; hybrid
    ``tail`` leaves are unstacked (B, ...) — batch axis 0."""
    out = dict(caches)
    out["blocks"] = jax.tree_util.tree_map(fn_stacked, caches["blocks"])
    if "tail" in caches:
        out["tail"] = jax.tree_util.tree_map(fn_flat, caches["tail"])
    if "shared" in caches:
        out["shared"] = jax.tree_util.tree_map(fn_stacked, caches["shared"])
    return out


def _slot_scatter(live: dict, new: dict, slots: jax.Array) -> dict:
    """Write the (Bp, ...)-batched ``new`` cache rows into the live slot table at
    ``slots`` (Bp,) int32. Sentinel indices ≥ B (padding rows of the admission
    batch) are dropped — the live state of every other slot is untouched."""
    paired_stacked = jax.tree_util.tree_map(
        lambda l, n: l.at[:, slots].set(n, mode="drop"), live["blocks"],
        new["blocks"])
    out = dict(live)
    out["blocks"] = paired_stacked
    if "tail" in live:
        out["tail"] = jax.tree_util.tree_map(
            lambda l, n: l.at[slots].set(n, mode="drop"), live["tail"], new["tail"])
    if "shared" in live:
        out["shared"] = jax.tree_util.tree_map(
            lambda l, n: l.at[:, slots].set(n, mode="drop"), live["shared"],
            new["shared"])
    return out


def make_admit_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None, *,
                    path: Optional[str] = None, temperature: float = 0.0,
                    top_k: int = 0):
    """Padded prefill of newly admitted requests into a *live* slot table.

    The returned function prefills a small (Bp, S_bucket) admission batch — Bp is
    the power-of-two row bucket covering the number of admitted requests, so the
    set of prefill lowerings is the static (row bucket × length bucket) grid —
    against a *fresh zero cache* (stateful caches like the SSM recurrence can
    never leak a retired request's state), then scatters the new cache rows into
    the live slot table at the admitted slot indices. Mid-decode slots are never
    touched: a single-slot refill costs a Bp=1 prefill, not a full-batch one.
    """
    ctx = _make_ctx(cfg, quant, path)
    sample = _make_sampler(temperature, top_k)

    def admit_step(params, tokens, lens, slots, caches, key):
        """tokens (Bp, S) right-padded; lens (Bp,) int32 prompt lengths; slots
        (Bp,) int32 target slot per row (≥ B ⇒ padding row, dropped); caches =
        live slot caches. Returns (first sampled token (Bp,) int32, caches with
        the admitted slots' rows replaced)."""
        Bp = tokens.shape[0]
        # fresh zero cache with the admission batch size; dtype/layout (incl. the
        # int8 KV leaves) comes from the live cache leaves themselves
        fresh = _map_batch_axis(
            caches,
            lambda x: jnp.zeros(x.shape[:1] + (Bp,) + x.shape[2:], x.dtype),
            lambda x: jnp.zeros((Bp,) + x.shape[1:], x.dtype))
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx,
                             mode="prefill", caches=fresh, cur_len=lens)
        merged = _slot_scatter(caches, ex["caches"], slots)
        return sample(logits[:, -1], key), merged

    return admit_step


def make_paged_admit_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                          *, path: Optional[str] = None, temperature: float = 0.0,
                          top_k: int = 0, warm: bool = False):
    """Admission prefill straight into the live page pool (DESIGN.md §3.8).

    Unlike the dense slot table (fresh zero cache + ``_slot_scatter``), paged
    admission writes K/V through each admitted row's page table into pages the
    allocator handed it exclusively — other slots' pages are untouched by
    construction, so no scatter-merge step is needed. ``warm=False`` traces the
    cold path: plain right-padded prefill attention, bitwise-identical to the
    dense layout. ``warm=True`` traces the shared-prefix path: the batch rows
    are prompt *suffixes*, ``prefix`` (Bp,) counts tokens already present in the
    mapped pages, and attention reads the prefix back from the pool
    (layers.paged_prefill_attention). The engine dispatches per admission batch,
    so cold batches never pay the warm lowering (or its gather).
    """
    ctx = _make_ctx(cfg, quant, path)
    sample = _make_sampler(temperature, top_k)

    def admit_step(params, tokens, lens, prefix, row_tables, row_states, caches,
                   key):
        """tokens (Bp, S) right-padded suffixes; lens (Bp,) suffix lengths;
        prefix (Bp,) shared-prefix lengths (ignored on the cold lowering);
        row_tables (Bp, maxP) per-row page tables and row_states (Bp,) per-row
        state-page ids (sentinel-filled padding rows write nowhere; each is
        consumed only when the cache carries its routing table — §3.13).
        Returns (first sampled token (Bp,), updated caches with the live
        tables restored)."""
        c = dict(caches)
        if "page_table" in c:
            c["page_table"] = row_tables
        if "state_table" in c:
            c["state_table"] = row_states
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx,
                             mode="prefill", caches=c, cur_len=lens,
                             prefix_len=prefix if warm else None)
        out = dict(ex["caches"])
        for table in ("page_table", "state_table"):
            if table in caches:
                out[table] = caches[table]
        return sample(logits[:, -1], key), out

    return admit_step


def _page_copy(caches: dict, src, dst, n_tok):
    """Copy-on-write of a partially shared tail page (DESIGN.md §3.8): duplicate
    the first ``n_tok`` token rows of physical page ``src`` into the freshly
    allocated ``dst`` across every layer's pools (codes and int8 scale pages
    alike); rows ≥ n_tok stay zero, exactly as a cold prefill would leave them
    before writing the suffix."""
    def cp(leaf):                       # (n_blocks, P, ps, Hkv, D|1)
        row = leaf[:, src]
        mask = jnp.arange(leaf.shape[2])[None, :, None, None] < n_tok
        return leaf.at[:, dst].set(jnp.where(mask, row, jnp.zeros_like(row)))

    out = dict(caches)
    out["blocks"] = jax.tree_util.tree_map(cp, caches["blocks"])
    return out


def make_serve_decode_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                           *, path: Optional[str] = None, temperature: float = 0.0,
                           top_k: int = 0):
    """One fused decode step: model forward + on-device sampling → token ids only."""
    ctx = _make_ctx(cfg, quant, path)
    sample = _make_sampler(temperature, top_k)

    def decode_step(params, tokens, caches, cur_len, key):
        """tokens (B,) int32 pending inputs; cur_len (B,) int32 post-append lengths
        → (next token (B,) int32, updated caches)."""
        logits, ex = M.apply(params, {"tokens": tokens[:, None]}, cfg, ctx=ctx,
                             mode="decode", caches=caches, cur_len=cur_len)
        return sample(logits[:, -1], key), ex["caches"]

    return decode_step


def make_serve_verify_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                           *, path: Optional[str] = None):
    """One fused speculative verify step (DESIGN.md §3.9): score a (B, W) draft
    window — column 0 each slot's pending token, columns 1.. its drafted
    continuation — in a single forward pass and greedily argmax every window
    position on-device, so the host acceptance loop only compares int32 ids.
    Greedy-only: the engine's acceptance rule (token i accepted iff it equals
    the sample at window position i-1) is token-exact by construction only
    when sampling is deterministic."""
    ctx = _make_ctx(cfg, quant, path)

    def verify_step(params, tokens, caches, cur_len, q_len, key):
        """tokens (B, W) int32 draft windows; cur_len (B,) int32 *total*
        post-scatter lengths; q_len (B,) int32 valid window rows (1 ≤ q_len ≤
        W; shorter windows right-pad and their tail rows scatter nowhere)
        → (greedy samples (B, W) int32 — position i samples the token after
        window token i — and the updated caches)."""
        del key                                    # greedy: sampler is argmax
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx,
                             mode="verify", caches=caches, cur_len=cur_len,
                             q_len=q_len)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), ex["caches"]

    return verify_step


def make_chunked_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                      *, path: Optional[str] = None, temperature: float = 0.0,
                      top_k: int = 0):
    """One fused mixed-budget step (DESIGN.md §3.10): a packed ragged token row
    — single decode tokens, draft-verify windows and page-aligned prefill
    chunks of many slots side by side — served in one ``mode="chunked"``
    forward pass. Returns per-slot sampled tokens (from each slot's last valid
    packed row) plus the per-row greedy argmax (the speculative acceptance
    stream), so the host scheduler only moves int32 ids."""
    ctx = _make_ctx(cfg, quant, path)
    sample = _make_sampler(temperature, top_k)

    def chunked_step(params, tokens, q_start, q_len, kv_len, positions,
                     slot_ids, caches, key):
        """tokens (1, Nt) packed row; q_start/q_len/kv_len (B,) per-slot chunk
        extents (q_len == 0 ⇒ slot idle this step); positions/slot_ids (Nt,)
        per-token routing (slot_ids == B ⇒ padding row, scatters nowhere)
        → (sampled next token (B,) int32, per-row argmax (Nt,) int32, caches)."""
        chunk = {"q_start": q_start, "q_len": q_len, "kv_len": kv_len,
                 "positions": positions, "slot_ids": slot_ids}
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx,
                             mode="chunked", caches=caches, chunk=chunk)
        last = jnp.clip(q_start + jnp.maximum(q_len, 1) - 1, 0,
                        logits.shape[1] - 1)
        tok = sample(logits[0, last], key)
        rowmax = jnp.argmax(logits[0], axis=-1).astype(jnp.int32)
        return tok, rowmax, ex["caches"]

    return chunked_step


# ======================================================================================
# Tensor-parallel sharded serving (DESIGN.md §3.7)
# ======================================================================================

def _hinted(fn, plan: "planner.Plan", mesh: Mesh):
    """Wrap a step function so it traces under the plan's sharding hints: batch /
    vocab / KV-cache constraints and the row-parallel int32-accumulator pin
    (qlinear) all read these contextvars at trace time."""

    def wrapped(*args):
        # token_groups=False: grouped MoE dispatch uses *per-group* capacity, which
        # admits a different token-drop set than the single-device global dispatch
        # whenever an expert overflows — serving's EP parity contract is bitwise vs
        # single-device (§3.13), so serving steps always trace global dispatch.
        with hints.sharding_hints(
                dp_axes=plan.dp_axes, tp_axis=plan.tp_axis, mesh=mesh,
                kv_seq_axis=plan.tp_axis if plan.seq_shard_kv else None,
                ep_axis=plan.ep_axis, token_groups=False):
            return fn(*args)

    return wrapped


def shard_serving_state(params, caches, cfg: ModelConfig, plan: "planner.Plan",
                        mesh: Mesh):
    """Planner specs for a serving step's carried state: (param shardings, cache
    shardings, replicated). Params cover raw-fp *and* prepared integer trees —
    qw/qw4 split over the model axis with their sw/bcol scale leaves following the
    same dim, qalpha replicated; caches cover fp and int8-with-per-token-scales KV
    plus SSM state (planner.cache_shardings)."""
    param_sh = planner.param_shardings(params, cfg, plan, mesh)
    cache_sh = planner.cache_shardings(caches, cfg, plan, mesh)
    return param_sh, cache_sh, NamedSharding(mesh, P())


# ======================================================================================
# Host-side continuous batcher
# ======================================================================================

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[FinishReason] = None   # set at retirement
    prefix_reused: int = 0        # §3.8 radix hit length (prompt tokens)


def default_buckets(max_len: int, lo: int = 8) -> List[int]:
    """Power-of-two padded-prefill lengths up to the cache size: [8, 16, ..., T]."""
    out, b = [], lo
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


class ServeEngine:
    """Continuous batcher over a fixed-size slot table (DESIGN.md §3.6).

    Mixed-length prompts are admitted into free slots via length-bucketed padded
    prefill; finished requests retire and their slot refills immediately without
    draining the rest of the batch. Decode advances all slots in lock-step with a
    per-slot ``cur_len`` vector; sampling (greedy by default, temperature/top-k
    otherwise) happens on-device inside the jit'd step.

    ``eos_id=None`` (default) disables EOS termination — token 0 is the pad token,
    so an implicit ``eos=0`` would silently truncate on any pad-token sample; pass
    the tokenizer's real EOS id explicitly.

    ``cache_layout="paged"`` (DESIGN.md §3.8) replaces the dense per-slot rows
    with a page pool + page table: a ref-counted block allocator
    (serving/paging.py) maps each sequence onto ``page_size``-token pages, a
    radix index over prompt chunks maps previously prefilled prefixes into new
    requests **copy-free** (partial tail pages copy-on-write), only the prompt
    suffix is prefilled, and retirement decrefs pages with LRU eviction of
    unreferenced cached prefixes under pool pressure. ``n_pages`` defaults to
    the dense-equivalent capacity ``batch_size · max_len / page_size``; smaller
    pools trade on sharing. Token-exact vs the dense layout on every path × KV
    mode (tests/test_paged_serving.py). ``prefix_reuse=False`` keeps the paged
    layout but always cold-prefills (the parity baseline).

    ``speculate=k`` (DESIGN.md §3.9) turns each decode step into a k-token
    verify step: a self-drafting prompt-lookup drafter (serving/drafter.py)
    proposes up to ``k`` continuation tokens per slot from n-gram matches
    against the slot's own history, the model scores the whole window in one
    multi-token kernel launch (same paged/dense attention path decode uses),
    and greedy acceptance keeps every accepted token equal to what plain
    decode would have sampled — output is **token-exact** vs ``speculate=1``
    (tests/test_speculative.py). Requires greedy sampling, the continuous
    scheduler and attention-only caches; ``accept_rate()`` /
    ``tokens_per_step()`` report what the workload's repetitiveness bought.

    ``cache_dtype`` sets the fp KV-cache dtype, defaulting to the params dtype
    (a bf16 model serves a bf16 cache); ``kv_cache="int8"`` is unaffected.

    ``scheduler="grouped"`` keeps the admission policy of the pre-§3.6 engine
    (equal-exact-length groups, drained to completion) as the throughput baseline
    for ``benchmarks/serving_bench.py``.

    ``mesh=`` (+ optional ``plan=``, default ``planner.make_serve_plan``) serves
    TP-sharded (DESIGN.md §3.7): params/caches are placed per the plan's
    ``NamedSharding`` pytrees and both steps are jit'd with matching in/out
    shardings. Token-exact vs single-device serving on every path × KV mode
    (tests/test_sharded_serving.py).

    SSM / hybrid families serve through the same continuous slot-table scheduler
    as attention (DESIGN.md §3.13): right-padded admission prefill masks dt to
    zero at padded positions, which makes them decay-1/update-0 no-ops on the
    recurrence (ssm.mamba_apply) — the carried state is exactly the exact-length
    state, so mamba2/zamba2 get length-bucketed admission, mid-decode
    retire+refill and donated-cache decode identically to attention families.
    Under ``cache_layout="paged"`` their per-layer state checkpoints live in
    fixed-size pools (one ``state_table``-routed page per slot, allocated from
    the same ref-counted pool as attention KV pages; a hybrid slot holds both
    kinds and retires them together). Speculation and radix prefix reuse stay
    attention-only — the recurrence can neither rewind rejected draft tokens
    nor restart from a mid-prompt page boundary (serving/config.py raises
    typed errors for those combinations).

    Expert-parallel MoE serving: a mesh with an ``"expert"`` axis shards the
    stacked ``(E, ...)`` expert trees over it (planner moe_mode
    ``"expert_axis"``) — each ep shard holds whole experts with their scale
    leaves, the router stays replicated, and the int32 expert GEMMs never
    cross shards, so fused-int8 EP serving is bitwise vs single-device.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 config: Optional[EngineConfig] = None,
                 quant: Optional[ql.QuantConfig] = None,
                 mesh: Optional[Mesh] = None,
                 plan: Optional["planner.Plan"] = None,
                 sparsity_plan=None,
                 **legacy):
        if config is not None and legacy:
            raise TypeError("pass either config= or legacy engine kwargs, "
                            f"not both (got config plus {sorted(legacy)})")
        if config is None:
            # Deprecation shim (DESIGN.md §3.11): the legacy 20-kwarg surface
            # keeps working — it builds the same validated EngineConfig, so an
            # invalid combination raises identically on both surfaces — and
            # warns once per process.
            global _LEGACY_KWARGS_WARNED
            if not _LEGACY_KWARGS_WARNED:
                warnings.warn(
                    "ServeEngine(cfg, params, **kwargs) is deprecated; pass "
                    "ServeEngine(cfg, params, config=EngineConfig(...)) "
                    "(DESIGN.md §3.11)", DeprecationWarning, stacklevel=2)
                _LEGACY_KWARGS_WARNED = True
            config = EngineConfig.from_kwargs(**legacy)
        config.check_model(cfg)   # typed rejections: spec/prefix-reuse/chunked on state
        self.config = config
        batch_size, max_len = config.batch_size, config.max_len
        path, eos_id = config.path, config.eos_id
        kv_cache, cache_layout = config.kv_cache, config.cache_layout
        page_size, n_pages = config.page_size, config.n_pages
        prefix_reuse, cache_dtype = config.prefix_reuse, config.cache_dtype
        scheduler, prefill_buckets = config.scheduler, config.prefill_buckets
        chunked, token_budget = config.chunked, config.token_budget
        speculate, drafter_ngram = config.speculate, config.drafter_ngram
        temperature, top_k = config.temperature, config.top_k
        seed = config.seed
        self.paged = cache_layout == "paged"
        self.chunked = chunked
        self.token_budget = token_budget
        self.spec = speculate
        if speculate > 1:
            self.drafter = drafter.NGramDrafter(max_ngram=drafter_ngram)
        self.sparsity_plan = sparsity_plan
        if config.sparsity != "none":
            # N:M structured sparsity at engine build (DESIGN.md §3.12): prune the
            # tree the engine will serve — prepared int8 leaves are rescaled to
            # their survivors and gain packed ``mask`` leaves the fused path's
            # sparse GEMM reads; fp trees are magnitude-pruned in place so every
            # path sees the same masked weights. A ``sparsity_plan``
            # (models.quantize.make_sparsity_plan) restricts pruning to the layers
            # whose §4.1 kernel proportion says it is safe; without one, every
            # quantizable leaf is pruned. Leaves already carrying a mask pass
            # through untouched, so pre-sparsified checkpoints serve as-is.
            from repro.models import quantize as MQ
            if sparsity_plan is None:
                self.sparsity_plan = MQ.SparsityPlan(nm=MQ.parse_nm(config.sparsity))
            params = MQ.sparsify_tree(params, self.sparsity_plan)
        self.cfg, self.params = cfg, params
        self.B, self.T = batch_size, max_len
        self.eos = eos_id
        self.kv_int8 = kv_cache == "int8"
        self.scheduler = scheduler
        # Which state kinds this family's cache carries (models/state.py §3.13):
        # has_kv → token-paged attention KV (page need grows with length);
        # has_state → fixed-size SSM checkpoints (one state page per slot).
        self.has_kv, self.has_state = state_lib.family_flags(M.block_spec(cfg))
        self.buckets = sorted(b for b in (prefill_buckets or default_buckets(max_len))
                              if b <= max_len)
        if cache_dtype is None:
            # fp KV caches follow the params dtype (a bf16 model serves a bf16
            # cache) instead of silently promoting the whole pool to f32
            flt = [leaf for leaf in jax.tree_util.tree_leaves(params)
                   if hasattr(leaf, "dtype")
                   and jnp.issubdtype(leaf.dtype, jnp.floating)]
            cache_dtype = flt[0].dtype if flt else jnp.float32
        self.cache_dtype = np.dtype(cache_dtype)
        decode = make_serve_decode_step(cfg, quant, path=path,
                                        temperature=temperature, top_k=top_k)
        verify = (make_serve_verify_step(cfg, quant, path=path)
                  if speculate > 1 else None)
        chunk_step = (make_chunked_step(cfg, quant, path=path,
                                        temperature=temperature, top_k=top_k)
                      if chunked else None)
        if self.paged:
            # Paged pool + page table (DESIGN.md §3.8): the pool defaults to the
            # dense-equivalent capacity; passing less relies on prefix sharing +
            # eviction for the capacity win the benchmark measures.
            self.ps = page_size
            self.maxP = max_len // page_size
            self.n_pages = n_pages or batch_size * self.maxP
            self.pool = paging.PagePool(self.n_pages)
            # Radix prefix reuse needs position-indexed KV pages to restart a
            # prompt mid-way; a state checkpoint cannot (check_model rejects
            # prefix_reuse on stateful families — this guard is the backstop).
            self.radix = (paging.RadixIndex(page_size)
                          if prefix_reuse and not self.has_state else None)
            if self.has_kv:
                self._table = np.full((batch_size, self.maxP), self.n_pages,
                                      np.int32)
            if self.has_state:
                self._state_table = np.full((batch_size,), self.n_pages,
                                            np.int32)
            self._state_pages_held = 0
            self._table_dirty = False
            self._seq_pages: List[List[int]] = [[] for _ in range(batch_size)]
            self.caches = M.init_cache(cfg, batch_size, max_len,
                                       dtype=self.cache_dtype,
                                       kv_int8=self.kv_int8, layout="paged",
                                       page_size=page_size, n_pages=self.n_pages)
            admit_cold = make_paged_admit_step(cfg, quant, path=path,
                                               temperature=temperature,
                                               top_k=top_k, warm=False)
            admit_warm = make_paged_admit_step(cfg, quant, path=path,
                                               temperature=temperature,
                                               top_k=top_k, warm=True)
        else:
            self.caches = M.init_cache(cfg, batch_size, max_len,
                                       dtype=self.cache_dtype,
                                       kv_int8=self.kv_int8)
            admit = make_admit_step(cfg, quant, path=path, temperature=temperature,
                                    top_k=top_k)
        self.mesh = mesh
        self.plan = None
        # Every step donates its ``caches`` argument: the engine owns exactly one
        # live cache pytree (each call's output replaces ``self.caches``), so XLA
        # scatters the decode-step KV append — and the int8-KV scale append —
        # into the existing buffers instead of copying the whole multi-GiB cache
        # per token. Without donation the per-step full-cache copy dominated the
        # slot-table decode and inverted continuous-vs-grouped throughput on the
        # 4-leaf int8-KV cache (EXPERIMENTS.md §Perf).
        if mesh is None:
            self._decode_step = jax.jit(decode, donate_argnums=2)
            if verify is not None:
                self._verify_step = jax.jit(verify, donate_argnums=2)
            if chunk_step is not None:
                self._chunk_step = jax.jit(chunk_step, donate_argnums=7)
            if self.paged:
                self._admit_cold = jax.jit(admit_cold, donate_argnums=6)
                self._admit_warm = jax.jit(admit_warm, donate_argnums=6)
                self._copy_step = jax.jit(_page_copy, donate_argnums=0)
            else:
                self._admit_step = jax.jit(admit, donate_argnums=4)
        else:
            # TP-sharded serving (DESIGN.md §3.7): place the prepared integer tree
            # (weights + scale leaves), the slot-table caches (incl. int8-KV
            # per-token scales — and on the paged layout the page pools + their
            # replicated page table) and jit the steps with NamedSharding-
            # constrained in/out shardings so GSPMD partitions prefill/decode.
            # Host tokens, lens, slots, cur_len and the PRNG key stay replicated.
            # Cache in/out shardings match, so the carried state never
            # reshard-pingpongs.
            self.plan = plan or planner.make_serve_plan(cfg, mesh)
            param_sh, cache_sh, repl = shard_serving_state(
                params, self.caches, cfg, self.plan, mesh)
            self._repl_sh = repl
            self.params = jax.device_put(params, param_sh)
            self.caches = jax.device_put(self.caches, cache_sh)
            self._decode_step = jax.jit(
                _hinted(decode, self.plan, mesh),
                in_shardings=(param_sh, repl, cache_sh, repl, repl),
                out_shardings=(repl, cache_sh), donate_argnums=2)
            if verify is not None:
                # draft-window tokens/q_len stay replicated like decode tokens;
                # the window axis follows the batch through the same cache specs
                self._verify_step = jax.jit(
                    _hinted(verify, self.plan, mesh),
                    in_shardings=(param_sh, repl, cache_sh, repl, repl, repl),
                    out_shardings=(repl, cache_sh), donate_argnums=2)
            if chunk_step is not None:
                # packed row + chunk extents stay replicated like decode
                # tokens; the ragged kernel runs as one GSPMD-manual region
                self._chunk_step = jax.jit(
                    _hinted(chunk_step, self.plan, mesh),
                    in_shardings=(param_sh,) + (repl,) * 6 + (cache_sh, repl),
                    out_shardings=(repl, repl, cache_sh), donate_argnums=7)
            if self.paged:
                admit_sh = dict(in_shardings=(param_sh, repl, repl, repl, repl,
                                              repl, cache_sh, repl),
                                out_shardings=(repl, cache_sh))
                self._admit_cold = jax.jit(_hinted(admit_cold, self.plan, mesh),
                                           donate_argnums=6, **admit_sh)
                self._admit_warm = jax.jit(_hinted(admit_warm, self.plan, mesh),
                                           donate_argnums=6, **admit_sh)
                self._copy_step = jax.jit(
                    _page_copy, in_shardings=(cache_sh, repl, repl, repl),
                    out_shardings=cache_sh, donate_argnums=0)
            else:
                self._admit_step = jax.jit(
                    _hinted(admit, self.plan, mesh),
                    in_shardings=(param_sh, repl, repl, repl, cache_sh, repl),
                    out_shardings=(repl, cache_sh), donate_argnums=4)
        self.queue: List[Request] = []
        self._slots: List[Optional[Request]] = [None] * batch_size
        self._pos = np.zeros(batch_size, np.int32)       # tokens in cache per slot
        self._pending = np.zeros(batch_size, np.int32)   # next input token per slot
        # chunked prefill progress (DESIGN.md §3.10): while a slot is
        # mid-prefill, _prefill_target holds its prompt length (0 ⇒ generating)
        # and _prefill_off the tokens already in its pages (radix prefix +
        # scattered chunks)
        self._prefill_off = np.zeros(batch_size, np.int32)
        self._prefill_target = np.zeros(batch_size, np.int32)
        self._key = jax.random.PRNGKey(seed)
        self._greedy = temperature <= 0.0
        self._step = 0
        self._next_rid = 0
        #: optional per-token hook, called as ``on_token(request, token)``
        #: after every emitted token (the request is already retired when
        #: ``request.done``) — the async server streams through this
        self.on_token: Optional[Callable[[Request, int], None]] = None
        self.counters = {
            "prefill_calls": 0, "decode_steps": 0,
            "active_slot_steps": 0, "mid_decode_admissions": 0,
            # paged layout (DESIGN.md §3.8); zero on dense engines
            "prefix_hits": 0, "prefix_tokens_reused": 0,
            "prompt_tokens": 0, "prefill_tokens": 0,
            "cow_copies": 0, "pages_evicted": 0,
            "peak_pages_in_use": 0,
            # state-pool occupancy split (DESIGN.md §3.13): how many pool pages
            # currently hold attention KV tokens vs fixed-size SSM state
            # checkpoints, plus their peaks; zero on dense engines
            "kv_pages_in_use": 0, "state_pages_in_use": 0,
            "peak_kv_pages_in_use": 0, "peak_state_pages_in_use": 0,
            # speculative decoding (DESIGN.md §3.9); zero if spec==1
            "spec_steps": 0, "spec_slot_steps": 0, "spec_drafted": 0,
            "spec_accepted": 0, "spec_emitted": 0,
            # chunked serving (DESIGN.md §3.10); zero if chunked=False
            "chunk_steps": 0, "chunk_prefill_rows": 0,
            "chunk_decode_rows": 0}

    # ---------------------------------------------------------------- submission

    def submit(self, prompts: List[np.ndarray],
               max_new: Union[int, Sequence[int]] = 16) -> List[Request]:
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        reqs = []
        for p, mn in zip(prompts, max_new):
            p = np.asarray(p, np.int32)
            if not 0 < len(p) <= self.T:
                raise ValueError(f"prompt length {len(p)} not in (0, {self.T}]")
            reqs.append(Request(self._next_rid, p, mn))
            self._next_rid += 1
        self.queue.extend(reqs)
        return reqs

    # ---------------------------------------------------------------- scheduling

    def _bucket(self, plen: int) -> int:
        for b in self.buckets:
            if b >= plen:
                return b
        return self.T

    def stats(self) -> EngineStats:
        """Unified statistics snapshot (DESIGN.md §3.11): the derived rates the
        four legacy accessors returned plus a copy of the raw counters, with a
        stable ``to_dict()`` schema shared by ``benchmarks/serving_bench.py``
        and the async server's metrics endpoint."""
        return EngineStats.from_counters(self.counters, self.B)

    def occupancy(self) -> float:
        return self.stats().occupancy

    def prefix_hit_rate(self) -> float:
        """Fraction of submitted prompt tokens served from shared prefix pages
        instead of being re-prefilled (paged layout; 0.0 on dense)."""
        return self.stats().prefix_hit_rate

    def accept_rate(self) -> float:
        """Fraction of *drafted* tokens the verify step accepted (DESIGN.md
        §3.9; the mandatory pending token does not count). 0.0 when nothing
        was drafted (speculate == 1, or the drafter never proposed)."""
        return self.stats().accept_rate

    def tokens_per_step(self) -> float:
        """Mean emitted tokens per slot per speculative verify step (≥ 1.0 —
        plain decode emits exactly 1 per slot-step, so this is the per-request
        step-count compression speculation bought). 0.0 before any speculative
        step ran."""
        return self.stats().tokens_per_step

    def _next_key(self) -> jax.Array:
        if self._greedy:            # sampler ignores the key: skip the fold_in op
            return self._key
        key = jax.random.fold_in(self._key, self._step)
        self._step += 1
        return key

    def _emit(self, slot: int, tok: int, finished: List[Request]) -> None:
        """Record one sampled token for a slot; retire the request when done.

        Capacity headroom: a prompt of length ``max_len`` fills its cache row at
        admission, so it is admitted-and-retired immediately with the single
        token its prefill logits produced — the decode step never scatters past
        the cache (the ``_pos >= T`` retire fires before any decode for that
        slot; pinned by tests/test_paged_serving.py)."""
        r = self._slots[slot]
        r.out.append(tok)
        if self.eos is not None and tok == self.eos:
            reason = FinishReason.EOS
        elif len(r.out) >= r.max_new:
            reason = FinishReason.LENGTH
        elif self._pos[slot] >= self.T:            # cache full: no room to append
            reason = FinishReason.CACHE_FULL
        else:
            reason = None
        if reason is not None:
            r.done = True
            r.finish_reason = reason
            finished.append(r)
            self._slots[slot] = None
            self._pos[slot] = 0
            self._pending[slot] = 0
            self._prefill_off[slot] = 0
            self._prefill_target[slot] = 0
            if self.paged:
                # drop this sequence's page references; pages retained by the
                # radix index as cached prefixes survive (theirs is a separate
                # reference), everything else returns to the free list
                self.pool.decref(self._seq_pages[slot])
                self._seq_pages[slot] = []
                if self.has_kv:
                    self._table[slot, :] = self.n_pages
                if self.has_state:
                    # sentinel the state route too: the freed checkpoint page
                    # may be handed to the next admission, whose prefill starts
                    # from a zero init_state rather than reading it (§3.13)
                    self._state_table[slot] = self.n_pages
                    self._state_pages_held -= 1
                self._table_dirty = True
                self._note_pool()
        else:
            self._pending[slot] = tok
        if self.on_token is not None:
            self.on_token(r, tok)

    # ------------------------------------------------------------ paged planning

    def _match_prefix(self, prompt: np.ndarray):
        """Radix walk + the prefix-usability caps shared by planning and
        bucketing: a request keeps ≥ 1 suffix token (the first sampled token
        comes from the suffix prefill logits), so the usable full-page match is
        clamped to ``(plen-1)//ps`` pages — and a clamped match invalidates the
        partial tail hit (it hangs off the *unclamped* depth). Returns
        ``(shared_pages, matched_tokens, cow_src_page_or_None, j)``."""
        plen, ps = len(prompt), self.ps
        if self.radix is None:
            return [], 0, None, 0
        pages, _, partial = self.radix.match(prompt)
        n_full = min(len(pages), (plen - 1) // ps)
        if n_full < len(pages):                # truncated ⇒ tail hit is invalid
            partial = None
        j = min(partial.length, plen - 1 - n_full * ps) if partial else 0
        return (pages[:n_full], n_full * ps,
                partial.page if j > 0 else None, j)

    def _plan_paged(self, r: Request) -> Optional[dict]:
        """Page plan for one request: walk the radix index for a shared prefix,
        then reserve this sequence's worst-case page count (prompt + decode
        budget, capped at the cache length — so decode never allocates, and an
        admission either owns every page it will ever touch or stays queued).
        Evicts LRU cached prefixes under pool pressure; returns None when the
        pool cannot cover the request even after eviction.

        Reference order matters: the shared pages (and the COW source page) are
        incref'd *before* evict/alloc — a matched prefix held only by the index
        has refs == 1 and would otherwise be evicted under pressure and handed
        straight back as a writable own page of the very plan that matched it.
        """
        plen, ps = len(r.prompt), self.ps
        shared, matched, cow_src, j = self._match_prefix(r.prompt)
        self.pool.incref(shared)
        if cow_src is not None:                # pin the COW source over evict
            self.pool.incref([cow_src])
        prefix = matched + j
        # worst-case cache footprint: the prompt plus every *appended* decode
        # token — the final sampled token retires the request without ever
        # being scattered (see _emit), so the budget contributes max_new - 1.
        # Token-paged KV need grows with length; a state checkpoint (§3.13) is
        # one extra fixed-size page regardless of length.
        need = (-(-min(plen + max(r.max_new - 1, 0), self.T) // ps)
                if self.has_kv else 0)
        own_n = need - len(shared) + (1 if self.has_state else 0)
        own = self.pool.alloc(own_n)
        if own is None and self.radix is not None:
            self.counters["pages_evicted"] += self.radix.evict(self.pool, own_n)
            own = self.pool.alloc(own_n)
        if cow_src is not None:                # copy is issued before any write
            self.pool.decref([cow_src])
        if own is None:
            self.pool.decref(shared)
            return None
        cow = (cow_src, own[0], j) if cow_src is not None else None
        state_page = own[-1] if self.has_state else None
        kv_own = own[:-1] if self.has_state else own
        return {"prefix": prefix, "suffix": plen - prefix,
                "pages": shared + kv_own, "n_shared": len(shared), "cow": cow,
                "state_page": state_page}

    def _suffix_estimate(self, r: Request) -> int:
        """Prefill-window estimate for bucketing (continuous, paged): prompt
        minus the currently cached shared prefix (same capping rules as
        ``_plan_paged`` via ``_match_prefix``). Commit-time replanning may
        shrink the suffix further (new prefixes inserted this round) — still
        fits the bucket; growth (eviction raced the estimate) defers the
        request to the next admission round."""
        if not self.paged:
            return len(r.prompt)
        _, matched, _, j = self._match_prefix(r.prompt)
        return len(r.prompt) - matched - j

    def _admit_paged_batch(self, batch: List[Request], bucket: int,
                           free: List[int], finished: List[Request]) -> int:
        """Admit up to ``len(free)`` paged requests in one suffix-prefill call.
        Returns the number admitted; the rest rejoin the queue head."""
        plans, deferred = [], []
        for r in batch:
            plan = self._plan_paged(r)
            if plan is None or plan["suffix"] > bucket:
                if plan is not None:       # un-reserve: replanned next round
                    self.pool.decref(plan["pages"])
                deferred.append(r)
            else:
                plans.append((r, plan))
        if deferred:
            self.queue = deferred + self.queue
        if not plans:
            return 0

        rows = 1 << (len(plans) - 1).bit_length() if len(plans) > 1 else 1
        tokens = np.zeros((rows, bucket), np.int32)
        lens = np.ones(rows, np.int32)
        prefixes = np.zeros(rows, np.int32)
        row_tables = np.full((rows, self.maxP), self.n_pages, np.int32)
        row_states = np.full(rows, self.n_pages, np.int32)
        mid_decode = any(s is not None for s in self._slots)
        warm = False
        for j, (slot, (r, plan)) in enumerate(zip(free, plans)):
            suffix = r.prompt[plan["prefix"]:]
            tokens[j, : len(suffix)] = suffix
            lens[j] = len(suffix)
            prefixes[j] = plan["prefix"]
            row_tables[j, : len(plan["pages"])] = plan["pages"]
            if plan["cow"] is not None:
                src, dst, ncopy = plan["cow"]
                self.caches = self._copy_step(
                    self.caches, jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32), jnp.asarray(ncopy, jnp.int32))
                self.counters["cow_copies"] += 1
            self._slots[slot] = r
            # the slot's reference list covers both page kinds: retirement
            # decrefs KV pages and the state checkpoint page together (§3.13)
            self._seq_pages[slot] = plan["pages"] + (
                [plan["state_page"]] if self.has_state else [])
            if self.has_kv:
                self._table[slot, :] = self.n_pages
                self._table[slot, : len(plan["pages"])] = plan["pages"]
            if self.has_state:
                row_states[j] = plan["state_page"]
                self._state_table[slot] = plan["state_page"]
                self._state_pages_held += 1
            warm = warm or plan["prefix"] > 0
            r.prefix_reused = plan["prefix"]
            self.counters["prompt_tokens"] += len(r.prompt)
            self.counters["prefill_tokens"] += plan["suffix"]
            self.counters["prefix_tokens_reused"] += plan["prefix"]
            self.counters["prefix_hits"] += 1 if plan["prefix"] > 0 else 0
        self._table_dirty = True
        step = self._admit_warm if warm else self._admit_cold
        tok, self.caches = step(
            self.params, jnp.asarray(tokens), jnp.asarray(lens),
            jnp.asarray(prefixes), jnp.asarray(row_tables),
            jnp.asarray(row_states), self.caches, self._next_key())
        tok = np.asarray(tok)
        self.counters["prefill_calls"] += 1
        if mid_decode:
            self.counters["mid_decode_admissions"] += 1
        self._note_pool()
        for j, (slot, (r, plan)) in enumerate(zip(free, plans)):
            if self.radix is not None:
                # register the full prompt pages as a cached prefix (content is
                # on-device once the admit step above retires)
                self.radix.insert(r.prompt,
                                  plan["pages"][: len(r.prompt) // self.ps],
                                  self.pool)
            self._pos[slot] = len(r.prompt)
            self._emit(slot, int(tok[j]), finished)
        return len(plans)

    def _admit_dense_batch(self, batch: List[Request], bucket: int,
                           free: List[int], finished: List[Request]) -> int:
        # admission batch: rows padded to a power-of-two bucket so the set of
        # prefill lowerings is the static (row bucket × length bucket) grid;
        # sentinel slot index B marks padding rows (dropped by the scatter)
        rows = 1 << (len(batch) - 1).bit_length() if len(batch) > 1 else 1
        tokens = np.zeros((rows, bucket), np.int32)
        lens = np.ones(rows, np.int32)
        slot_ids = np.full(rows, self.B, np.int32)
        mid_decode = any(s is not None for s in self._slots)
        for j, (slot, r) in enumerate(zip(free, batch)):
            tokens[j, : len(r.prompt)] = r.prompt
            lens[j] = len(r.prompt)
            slot_ids[j] = slot
            self._slots[slot] = r
            self.counters["prompt_tokens"] += len(r.prompt)
            self.counters["prefill_tokens"] += len(r.prompt)
        tok, self.caches = self._admit_step(
            self.params, jnp.asarray(tokens), jnp.asarray(lens),
            jnp.asarray(slot_ids), self.caches, self._next_key())
        tok = np.asarray(tok)
        self.counters["prefill_calls"] += 1
        if mid_decode:
            self.counters["mid_decode_admissions"] += 1
        for j, (slot, r) in enumerate(zip(free, batch)):
            self._pos[slot] = len(r.prompt)
            self._emit(slot, int(tok[j]), finished)
        return len(batch)

    def _admit(self, finished: List[Request]) -> None:
        while self.queue:
            free = [i for i, s in enumerate(self._slots) if s is None]
            if not free:
                return
            if self.scheduler == "grouped":
                # Legacy batcher: whole-batch groups of one exact length, drained to
                # completion before the next group starts.
                if len(free) < self.B:
                    return
                bucket = len(self.queue[0].prompt)
                batch, rest = [], []
                for r in self.queue:
                    (batch if len(batch) < len(free)
                     and len(r.prompt) == bucket else rest).append(r)
                self.queue = rest
                self._admit_dense_batch(batch, bucket, free, finished)
                return
            # Continuous: pick the *largest admittable same-bucket group* over
            # the whole queue, not queue[0]'s bucket — one odd-length
            # head-of-line request must not split the majority bucket behind it
            # into extra (smaller) prefill calls. Ties go to the bucket whose
            # first request arrived earliest (FIFO fairness); the loop keeps
            # admitting remaining buckets while slots stay free.
            groups: dict = {}
            first: dict = {}
            for i, r in enumerate(self.queue):
                b = self._bucket(self._suffix_estimate(r))
                groups.setdefault(b, []).append(r)
                first.setdefault(b, i)
            bucket = max(groups,
                         key=lambda b: (min(len(groups[b]), len(free)), -first[b]))
            batch = groups[bucket][: len(free)]
            taken = {id(r) for r in batch}
            self.queue = [r for r in self.queue if id(r) not in taken]
            if self.paged:
                admitted = self._admit_paged_batch(batch, bucket, free, finished)
            else:
                admitted = self._admit_dense_batch(batch, bucket, free, finished)
            if admitted == 0:
                return                     # pool exhausted: wait for retirements

    # ---------------------------------------------------------------- main loop

    def _push_table(self) -> None:
        """Sync the host routing tables to the device cache pytree. Retired
        slots' rows are sentinel-cleared *before* the next decode step: a free
        slot still decodes (lock-step shapes) and its garbage token must
        scatter nowhere — a stale table row would corrupt a page the allocator
        may have already handed to another sequence or the prefix index. The
        same applies to the (B,) state table of checkpoint-paged families
        (§3.13); a hybrid engine pushes both."""
        out = dict(self.caches)
        if self.has_kv:
            table = jnp.asarray(self._table)
            if self.mesh is not None:
                table = jax.device_put(table, self._repl_sh)
            out["page_table"] = table
        if self.has_state:
            stable = jnp.asarray(self._state_table)
            if self.mesh is not None:
                stable = jax.device_put(stable, self._repl_sh)
            out["state_table"] = stable
        self.caches = out
        self._table_dirty = False

    def _note_pool(self) -> None:
        """Refresh the §3.13 pool-occupancy counters after any alloc/decref:
        the one ref-counted pool backs both page kinds, so KV occupancy is
        whatever the engine's own state checkpoints don't account for (radix-
        held cached prefixes count as KV — they are token pages)."""
        held = self._state_pages_held
        kv = self.pool.used_count - held
        c = self.counters
        c["state_pages_in_use"] = held
        c["kv_pages_in_use"] = kv
        c["peak_state_pages_in_use"] = max(c["peak_state_pages_in_use"], held)
        c["peak_kv_pages_in_use"] = max(c["peak_kv_pages_in_use"], kv)
        c["peak_pages_in_use"] = max(c["peak_pages_in_use"],
                                     self.pool.used_count)

    def _spec_step(self, active: List[int], finished: List[Request]) -> None:
        """One speculative verify step (DESIGN.md §3.9): draft ≤ spec-1 tokens
        per active slot from its own prompt+output history, score the whole
        window in one fused verify pass, then greedily accept the longest
        prefix whose draft tokens match the model's own samples. Rejection
        falls back to the verified sample, so the emitted stream is token-exact
        vs non-speculative decode; every accepted token advances ``_pos``
        exactly as a plain decode step would, and a request retiring mid-window
        (EOS / max_new / full cache) discards the rest of its window with its
        page mappings torn down before any later step could touch them."""
        W = self.spec
        toks = np.zeros((self.B, W), np.int32)
        toks[:, 0] = self._pending
        wl = np.ones(self.B, np.int32)
        for i in active:
            r = self._slots[i]
            # window budget: room left in the cache row (the pending token
            # scatters at _pos) and tokens left to emit before max_new retires
            n_d = min(W - 1, self.T - self._pos[i] - 1,
                      r.max_new - len(r.out) - 1)
            if n_d > 0:
                hist = np.concatenate([r.prompt,
                                       np.asarray(r.out, np.int32)])
                d = self.drafter.draft(hist, n_d)
                wl[i] = 1 + len(d)
                toks[i, 1:1 + len(d)] = d
        cur = jnp.asarray(self._pos + wl, jnp.int32)   # post-scatter totals
        out, self.caches = self._verify_step(
            self.params, jnp.asarray(toks), self.caches, cur,
            jnp.asarray(wl), self._next_key())
        out = np.asarray(out)                          # (B, W) greedy samples
        self.counters["decode_steps"] += 1
        self.counters["spec_steps"] += 1
        self.counters["spec_slot_steps"] += len(active)
        self.counters["active_slot_steps"] += len(active)
        for i in active:
            n = 1                                      # pending always lands
            while n < wl[i] and toks[i, n] == out[i, n - 1]:
                n += 1
            self.counters["spec_drafted"] += int(wl[i]) - 1
            self.counters["spec_accepted"] += n - 1
            r = self._slots[i]
            for j in range(n):
                # advance per emitted token: retire conditions (max_new, EOS,
                # cache-full) must fire at exactly the same token as a
                # sequential non-speculative decode would
                self._pos[i] += 1
                self._emit(i, int(out[i, j]), finished)
                self.counters["spec_emitted"] += 1
                if self._slots[i] is not r:
                    # retired mid-window: the unemitted tail (and the
                    # rejected scattered tokens) must be unreachable — the
                    # retire path has to sentinel the slot's table row and
                    # drop its page refs before any later scatter/attend
                    if self.paged:
                        assert (not self._seq_pages[i]
                                and (self._table[i] == self.n_pages).all()), \
                            "mid-window retirement left stale page mappings"
                    break

    # ------------------------------------------------------------ chunked mode

    def _admit_chunked(self, finished: List[Request]) -> None:
        """FIFO admission into free slots (DESIGN.md §3.10): page planning,
        COW and radix matching are exactly ``_admit_paged_batch``'s, but no
        prefill step runs — the admitted slot enters the *mid-prefill* state
        and its prompt is served chunk-by-chunk out of each step's leftover
        token budget. Radix insertion waits for the final chunk (pages carry
        content only once scattered)."""
        while self.queue:
            free = [i for i, s in enumerate(self._slots) if s is None]
            if not free:
                return
            r = self.queue[0]
            plan = self._plan_paged(r)
            if plan is None:
                return                     # pool pressure: wait for retirements
            self.queue.pop(0)
            slot = free[0]
            if plan["cow"] is not None:
                src, dst, ncopy = plan["cow"]
                self.caches = self._copy_step(
                    self.caches, jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32), jnp.asarray(ncopy, jnp.int32))
                self.counters["cow_copies"] += 1
            self._slots[slot] = r
            self._seq_pages[slot] = plan["pages"]
            self._table[slot, :] = self.n_pages
            self._table[slot, : len(plan["pages"])] = plan["pages"]
            self._table_dirty = True
            self._prefill_off[slot] = plan["prefix"]
            self._prefill_target[slot] = len(r.prompt)
            r.prefix_reused = plan["prefix"]
            self.counters["prompt_tokens"] += len(r.prompt)
            self.counters["prefill_tokens"] += plan["suffix"]
            self.counters["prefix_tokens_reused"] += plan["prefix"]
            self.counters["prefix_hits"] += 1 if plan["prefix"] > 0 else 0
            self._note_pool()

    def _chunked_step(self, finished: List[Request]) -> None:
        """One mixed-budget engine step (DESIGN.md §3.10): admit, pack decode
        rows (draft windows under ``speculate``) for every generating slot
        first, fill the remaining token budget with prefill chunks (page-
        aligned ends where possible — chunks may *start* mid-page after a
        partial radix hit), launch once, then emit/advance on the host."""
        self._admit_chunked(finished)
        gen = [i for i, s in enumerate(self._slots)
               if s is not None and self._prefill_target[i] == 0]
        pre = [i for i, s in enumerate(self._slots)
               if s is not None and self._prefill_target[i] > 0]
        if not gen and not pre:
            if self.queue:
                # nothing in flight yet the queue head could not be admitted —
                # no retirement will ever free enough pages
                raise RuntimeError(
                    f"page pool too small: {self.n_pages} pages of "
                    f"{self.ps} cannot hold request {self.queue[0].rid} "
                    f"(prompt {len(self.queue[0].prompt)} + budget "
                    f"{self.queue[0].max_new})")
            return
        if self._table_dirty:
            self._push_table()
        if not pre and self.spec == 1 and not self.kv_int8:
            # Pure-decode step: every resident slot is generating, so the
            # packed ragged launch would score token_budget padded rows where
            # the decode kernel scores B. Dispatch the lean decode launch —
            # for an fp KV cache its q_len == 1 numerics are exactly the
            # ragged kernel's decode rows (tests/test_chunked_prefill.py
            # parity), so emitted tokens do not depend on which branch served
            # the step. int8 KV stays on the ragged launch: the two kernels'
            # dequant/accumulation orders differ within tolerance, and on a
            # chunk-quantized pool that is enough to flip an argmax tie.
            # Speculative chunked serving also keeps the ragged launch: draft
            # windows need the per-row causal mask every step.
            cur = jnp.asarray(self._pos + 1, jnp.int32)
            tok, self.caches = self._decode_step(
                self.params, jnp.asarray(self._pending), self.caches, cur,
                self._next_key())
            tok = np.asarray(tok)
            self._pos[gen] += 1
            self.counters["decode_steps"] += 1
            self.counters["active_slot_steps"] += len(gen)
            for i in gen:
                self._emit(i, int(tok[i]), finished)
            return
        Nt = self.token_budget
        toks = np.zeros(Nt, np.int32)
        positions = np.zeros(Nt, np.int32)
        slot_ids = np.full(Nt, self.B, np.int32)
        q_start = np.zeros(self.B, np.int32)
        q_len = np.zeros(self.B, np.int32)
        kv_len = np.zeros(self.B, np.int32)
        wl = np.ones(self.B, np.int32)
        off = 0
        # ---- decode rows first (token_budget >= B*spec: they always fit)
        for i in gen:
            r = self._slots[i]
            window = [int(self._pending[i])]
            if self.spec > 1:
                n_d = min(self.spec - 1, self.T - self._pos[i] - 1,
                          r.max_new - len(r.out) - 1)
                if n_d > 0:
                    hist = np.concatenate([r.prompt,
                                           np.asarray(r.out, np.int32)])
                    window += list(self.drafter.draft(hist, n_d))
            W = len(window)
            toks[off: off + W] = window
            positions[off: off + W] = self._pos[i] + np.arange(W)
            slot_ids[off: off + W] = i
            q_start[i], q_len[i], kv_len[i] = off, W, self._pos[i] + W
            wl[i] = W
            off += W
        # ---- leftover budget → prefill chunks (FIFO over mid-prefill slots)
        for i in pre:
            room = Nt - off
            if room <= 0:
                break
            start = int(self._prefill_off[i])
            plen = int(self._prefill_target[i])
            end = min(plen, start + room)
            if end < plen:
                # prefer a page-aligned chunk end; fall back to the raw budget
                # cut when a whole page doesn't fit (progress must never stall)
                aligned = (end // self.ps) * self.ps
                if aligned > start:
                    end = aligned
            toks[off: off + end - start] = self._slots[i].prompt[start:end]
            positions[off: off + end - start] = np.arange(start, end)
            slot_ids[off: off + end - start] = i
            q_start[i], q_len[i], kv_len[i] = off, end - start, end
            off += end - start
        tok, rowmax, self.caches = self._chunk_step(
            self.params, jnp.asarray(toks[None]), jnp.asarray(q_start),
            jnp.asarray(q_len), jnp.asarray(kv_len), jnp.asarray(positions),
            jnp.asarray(slot_ids), self.caches, self._next_key())
        tok, rowmax = np.asarray(tok), np.asarray(rowmax)
        self.counters["chunk_steps"] += 1
        self.counters["chunk_decode_rows"] += int(sum(wl[i] for i in gen))
        if gen:
            self.counters["decode_steps"] += 1
            self.counters["active_slot_steps"] += len(gen)
        served_pre = [i for i in pre if q_len[i] > 0]
        if served_pre:
            self.counters["prefill_calls"] += 1
            self.counters["chunk_prefill_rows"] += int(
                sum(q_len[i] for i in served_pre))
            if gen:
                self.counters["mid_decode_admissions"] += 1
        # ---- generating slots: emit (speculative acceptance under spec > 1)
        if self.spec > 1 and gen:
            self.counters["spec_steps"] += 1
            self.counters["spec_slot_steps"] += len(gen)
        for i in gen:
            if self.spec > 1:
                r = self._slots[i]
                out_w = rowmax[q_start[i]: q_start[i] + wl[i]]
                n = 1                                  # pending always lands
                while n < wl[i] and toks[q_start[i] + n] == out_w[n - 1]:
                    n += 1
                self.counters["spec_drafted"] += int(wl[i]) - 1
                self.counters["spec_accepted"] += n - 1
                for j in range(n):
                    self._pos[i] += 1
                    self._emit(i, int(out_w[j]), finished)
                    self.counters["spec_emitted"] += 1
                    if self._slots[i] is not r:
                        assert (not self._seq_pages[i]
                                and (self._table[i] == self.n_pages).all()), \
                            "mid-window retirement left stale page mappings"
                        break
            else:
                self._pos[i] += 1
                self._emit(i, int(tok[i]), finished)
        # ---- mid-prefill slots: advance; final chunk emits the first token
        for i in served_pre:
            end = int(kv_len[i])
            self._prefill_off[i] = end
            if end == self._prefill_target[i]:
                r = self._slots[i]
                self._prefill_target[i] = 0
                self._pos[i] = len(r.prompt)
                if self.radix is not None:
                    # the full prompt is on device now: register its pages as
                    # a cached prefix (same point the admit step does it)
                    self.radix.insert(r.prompt,
                                      self._seq_pages[i][: len(r.prompt)
                                                         // self.ps],
                                      self.pool)
                self._emit(i, int(tok[i]), finished)

    def step(self, finished: List[Request]) -> bool:
        """One engine iteration: admissions plus at most one model launch.
        Appends retired requests to ``finished``; returns False once the
        engine is idle (empty queue, no slots in flight). Exposed so callers
        — the latency benchmark drives this directly — can time individual
        steps and inject mid-run traffic between them."""
        if not (self.queue or any(s is not None for s in self._slots)):
            return False
        if self.chunked:
            self._chunked_step(finished)
            return True
        self._admit(finished)
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            if self.queue and self.paged:
                # nothing in flight yet the queue head could not be
                # admitted — no retirement will ever free enough pages
                raise RuntimeError(
                    f"page pool too small: {self.n_pages} pages of "
                    f"{self.ps} cannot hold request {self.queue[0].rid} "
                    f"(prompt {len(self.queue[0].prompt)} + budget "
                    f"{self.queue[0].max_new})")
            assert not self.queue, "scheduler stalled with queued requests"
            return True   # everything admitted retired at its first token
        if self.paged and self._table_dirty:
            self._push_table()
        if self.spec > 1:
            self._spec_step(active, finished)
            return True
        cur = jnp.asarray(self._pos + 1, jnp.int32)   # post-append lengths
        tok, self.caches = self._decode_step(
            self.params, jnp.asarray(self._pending), self.caches, cur,
            self._next_key())
        tok = np.asarray(tok)
        self._pos[active] += 1
        self.counters["decode_steps"] += 1
        self.counters["active_slot_steps"] += len(active)
        for i in active:
            self._emit(i, int(tok[i]), finished)
        return True

    def run(self) -> List[Request]:
        finished: List[Request] = []
        while self.step(finished):
            pass
        return sorted(finished, key=lambda r: r.rid)
