"""Serving engine: prefill / decode step builders + a host-side continuous batcher.

Step functions are pure and jit/pjit-ready: the dry-run lowers exactly these. The
engine serves either raw-fp params (with fake-quant CrossQuant activations — the
paper-faithful W8A8 evaluation path) or a prepared integer tree from
``models.quantize.quantize_tree`` (the int8/int4 deployment path: ~2×/4× weight bytes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext


def make_prefill_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None):
    ctx = QuantContext(quant or cfg.quant)

    def prefill_step(params, batch, caches):
        """batch tokens (B, S) → (last-position logits (B,1,V), filled caches)."""
        S = (batch["frames"].shape[1] if "frames" in batch else batch["tokens"].shape[1])
        if cfg.is_encoder_only:
            logits, _ = M.apply(params, batch, cfg, ctx=ctx, mode="train")
            return logits[:, -1:], caches
        logits, ex = M.apply(params, batch, cfg, ctx=ctx, mode="prefill",
                             caches=caches, cur_len=jnp.asarray(S, jnp.int32))
        return logits, ex["caches"]

    return prefill_step


def make_decode_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None):
    ctx = QuantContext(quant or cfg.quant)

    def decode_step(params, tokens, caches, cur_len):
        """tokens (B,1) + caches + cur_len (scalar int32, post-append length)
        → (logits (B,1,V), updated caches)."""
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx, mode="decode",
                             caches=caches, cur_len=cur_len)
        return logits, ex["caches"]

    return decode_step


# ======================================================================================
# Host-side continuous batcher (end-to-end serving example / integration tests)
# ======================================================================================

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Batched greedy serving over a fixed-size slot table.

    Requests with equal prompt lengths are prefetched together (the batcher groups by
    length); decode advances all active slots in lock-step, retiring finished requests
    and refilling slots — the standard continuous-batching loop, single-host edition.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int, max_len: int,
                 quant: Optional[ql.QuantConfig] = None, eos_id: int = 0):
        self.cfg, self.params = cfg, params
        self.B, self.T = batch_size, max_len
        self.eos = eos_id
        self.prefill = jax.jit(make_prefill_step(cfg, quant))
        self.decode = jax.jit(make_decode_step(cfg, quant))
        self.queue: List[Request] = []

    def submit(self, prompts: List[np.ndarray], max_new: int = 16) -> List[Request]:
        reqs = [Request(i, np.asarray(p, np.int32), max_new)
                for i, p in enumerate(prompts)]
        self.queue.extend(reqs)
        return reqs

    def run(self) -> List[Request]:
        done: List[Request] = []
        while self.queue:
            group_len = len(self.queue[0].prompt)
            group = [r for r in self.queue if len(r.prompt) == group_len][: self.B]
            self.queue = [r for r in self.queue if r not in group]
            done.extend(self._serve_group(group, group_len))
        return done

    def _serve_group(self, group: List[Request], plen: int) -> List[Request]:
        B = self.B
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(group):
            toks[i] = r.prompt
        caches = M.init_cache(self.cfg, B, self.T, dtype=jnp.float32)
        logits, caches = self.prefill(self.params, {"tokens": jnp.asarray(toks)}, caches)
        cur = plen
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in group)
        for step in range(max_new):
            for i, r in enumerate(group):
                if not r.done and step < r.max_new:
                    t = int(next_tok[i])
                    r.out.append(t)
                    if t == self.eos:
                        r.done = True
            cur += 1
            if cur >= self.T or all(r.done for r in group):
                break
            logits, caches = self.decode(self.params, next_tok[:, None], caches,
                                         jnp.asarray(cur, jnp.int32))
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for r in group:
            r.done = True
        return group
