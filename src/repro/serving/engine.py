"""Serving engine: prefill / decode step builders + a slot-table continuous batcher.

Step functions are pure and jit/pjit-ready: the dry-run lowers exactly these. The
engine serves raw-fp params (fp or fake-quant CrossQuant activations — the
paper-faithful W8A8 evaluation path) or a prepared integer tree from
``models.quantize.quantize_tree``, executed through one of three integer backends
(``path`` — DESIGN.md §3.3):

* ``"fake"``       — fp weights, fake-quant activations (accuracy-evaluation path).
* ``"dequant-fp"`` — prepared tree, codes dequantized to f32 before an fp GEMM
                     (weight-storage savings only; the serving baseline).
* ``"fused-int8"`` — prepared tree through the Pallas ``act_quantize → qgemm``
                     kernels: true int8×int8→int32 contractions per layer
                     (Mosaic on TPU, ``interpret=True`` off-TPU so CI runs it).

``kv_cache="int8"`` additionally stores decode K/V as int8 codes + per-token scales
(models.layers.kv_quantize), cutting decode-step cache HBM traffic.

Continuous batching (DESIGN.md §3.6): ``ServeEngine`` keeps a fixed slot table of
``batch_size`` sequences with **per-slot lengths** — ``cur_len`` is a ``(B,)`` int32
vector all the way down to the attention masks and cache scatter positions. New
requests are admitted into free slots mid-decode via length-bucketed padded prefill
(a small static set of prefill shapes bounds recompilation); finished requests retire
and free their slot immediately. The decode step is a single jit'd function that
folds greedy/temperature/top-k sampling in on-device, so the host loop only moves
int32 token ids.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.sharding import hints, planner

#: serving path → QuantContext wiring (DESIGN.md §3.3). ``None`` keeps the legacy
#: behaviour: whatever the params tree + quant config imply, on the jnp ref backend.
SERVE_PATHS = {
    None: {},
    "fp": {},
    "fake": {},
    "dequant-fp": {"int_exec": "dequant"},
    "fused-int8": {"int_exec": "pallas", "use_pallas": True},
}


def _make_ctx(cfg: ModelConfig, quant: Optional[ql.QuantConfig],
              path: Optional[str]) -> QuantContext:
    if path not in SERVE_PATHS:
        raise ValueError(f"unknown serving path {path!r}; "
                         f"pick one of {sorted(k for k in SERVE_PATHS if k)}")
    return QuantContext(quant or cfg.quant, **SERVE_PATHS[path])


def _make_sampler(temperature: float, top_k: int):
    """On-device sampler: greedy at temperature 0, else temperature + top-k.

    Padded vocab ids carry -1e9 logits (models.model._lm_head), so they are never
    sampled on either branch."""

    def sample(logits: jax.Array, key: jax.Array) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / temperature
        if top_k and top_k > 0:
            kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)

    return sample


def make_prefill_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                      *, path: Optional[str] = None):
    ctx = _make_ctx(cfg, quant, path)

    def prefill_step(params, batch, caches):
        """batch["tokens"] (B, S) right-padded prompts → (last-valid-position logits
        (B, 1, V), filled caches). An optional batch["lens"] (B,) int32 gives per-slot
        prompt lengths (absent → all slots are length S)."""
        S = (batch["frames"].shape[1] if "frames" in batch else batch["tokens"].shape[1])
        if cfg.is_encoder_only:
            logits, _ = M.apply(params, batch, cfg, ctx=ctx, mode="train")
            return logits[:, -1:], caches
        lens = batch.get("lens")
        cur = jnp.asarray(S, jnp.int32) if lens is None else lens
        logits, ex = M.apply(params, batch, cfg, ctx=ctx, mode="prefill",
                             caches=caches, cur_len=cur)
        return logits, ex["caches"]

    return prefill_step


def make_decode_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                     *, path: Optional[str] = None):
    ctx = _make_ctx(cfg, quant, path)

    def decode_step(params, tokens, caches, cur_len):
        """tokens (B,1) + caches + cur_len (scalar int32 or (B,) vector of per-slot
        post-append lengths) → (logits (B,1,V), updated caches)."""
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx, mode="decode",
                             caches=caches, cur_len=cur_len)
        return logits, ex["caches"]

    return decode_step


# ======================================================================================
# Slot-scatter cache ops (admission into a live batch)
# ======================================================================================

def _map_batch_axis(caches: dict, fn_stacked, fn_flat) -> dict:
    """Apply per-leaf fns keyed by where the slot axis sits: scanned leaves
    (``blocks``/``shared``) are stacked (n_blocks, B, ...) — batch axis 1; hybrid
    ``tail`` leaves are unstacked (B, ...) — batch axis 0."""
    out = dict(caches)
    out["blocks"] = jax.tree_util.tree_map(fn_stacked, caches["blocks"])
    if "tail" in caches:
        out["tail"] = jax.tree_util.tree_map(fn_flat, caches["tail"])
    if "shared" in caches:
        out["shared"] = jax.tree_util.tree_map(fn_stacked, caches["shared"])
    return out


def _slot_scatter(live: dict, new: dict, slots: jax.Array) -> dict:
    """Write the (Bp, ...)-batched ``new`` cache rows into the live slot table at
    ``slots`` (Bp,) int32. Sentinel indices ≥ B (padding rows of the admission
    batch) are dropped — the live state of every other slot is untouched."""
    paired_stacked = jax.tree_util.tree_map(
        lambda l, n: l.at[:, slots].set(n, mode="drop"), live["blocks"],
        new["blocks"])
    out = dict(live)
    out["blocks"] = paired_stacked
    if "tail" in live:
        out["tail"] = jax.tree_util.tree_map(
            lambda l, n: l.at[slots].set(n, mode="drop"), live["tail"], new["tail"])
    if "shared" in live:
        out["shared"] = jax.tree_util.tree_map(
            lambda l, n: l.at[:, slots].set(n, mode="drop"), live["shared"],
            new["shared"])
    return out


def make_admit_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None, *,
                    path: Optional[str] = None, temperature: float = 0.0,
                    top_k: int = 0):
    """Padded prefill of newly admitted requests into a *live* slot table.

    The returned function prefills a small (Bp, S_bucket) admission batch — Bp is
    the power-of-two row bucket covering the number of admitted requests, so the
    set of prefill lowerings is the static (row bucket × length bucket) grid —
    against a *fresh zero cache* (stateful caches like the SSM recurrence can
    never leak a retired request's state), then scatters the new cache rows into
    the live slot table at the admitted slot indices. Mid-decode slots are never
    touched: a single-slot refill costs a Bp=1 prefill, not a full-batch one.
    """
    ctx = _make_ctx(cfg, quant, path)
    sample = _make_sampler(temperature, top_k)

    def admit_step(params, tokens, lens, slots, caches, key):
        """tokens (Bp, S) right-padded; lens (Bp,) int32 prompt lengths; slots
        (Bp,) int32 target slot per row (≥ B ⇒ padding row, dropped); caches =
        live slot caches. Returns (first sampled token (Bp,) int32, caches with
        the admitted slots' rows replaced)."""
        Bp = tokens.shape[0]
        # fresh zero cache with the admission batch size; dtype/layout (incl. the
        # int8 KV leaves) comes from the live cache leaves themselves
        fresh = _map_batch_axis(
            caches,
            lambda x: jnp.zeros(x.shape[:1] + (Bp,) + x.shape[2:], x.dtype),
            lambda x: jnp.zeros((Bp,) + x.shape[1:], x.dtype))
        logits, ex = M.apply(params, {"tokens": tokens}, cfg, ctx=ctx,
                             mode="prefill", caches=fresh, cur_len=lens)
        merged = _slot_scatter(caches, ex["caches"], slots)
        return sample(logits[:, -1], key), merged

    return admit_step


def make_serve_decode_step(cfg: ModelConfig, quant: Optional[ql.QuantConfig] = None,
                           *, path: Optional[str] = None, temperature: float = 0.0,
                           top_k: int = 0):
    """One fused decode step: model forward + on-device sampling → token ids only."""
    ctx = _make_ctx(cfg, quant, path)
    sample = _make_sampler(temperature, top_k)

    def decode_step(params, tokens, caches, cur_len, key):
        """tokens (B,) int32 pending inputs; cur_len (B,) int32 post-append lengths
        → (next token (B,) int32, updated caches)."""
        logits, ex = M.apply(params, {"tokens": tokens[:, None]}, cfg, ctx=ctx,
                             mode="decode", caches=caches, cur_len=cur_len)
        return sample(logits[:, -1], key), ex["caches"]

    return decode_step


# ======================================================================================
# Tensor-parallel sharded serving (DESIGN.md §3.7)
# ======================================================================================

def _hinted(fn, plan: "planner.Plan", mesh: Mesh):
    """Wrap a step function so it traces under the plan's sharding hints: batch /
    vocab / KV-cache constraints and the row-parallel int32-accumulator pin
    (qlinear) all read these contextvars at trace time."""

    def wrapped(*args):
        with hints.sharding_hints(
                dp_axes=plan.dp_axes, tp_axis=plan.tp_axis, mesh=mesh,
                kv_seq_axis=plan.tp_axis if plan.seq_shard_kv else None):
            return fn(*args)

    return wrapped


def shard_serving_state(params, caches, cfg: ModelConfig, plan: "planner.Plan",
                        mesh: Mesh):
    """Planner specs for a serving step's carried state: (param shardings, cache
    shardings, replicated). Params cover raw-fp *and* prepared integer trees —
    qw/qw4 split over the model axis with their sw/bcol scale leaves following the
    same dim, qalpha replicated; caches cover fp and int8-with-per-token-scales KV
    plus SSM state (planner.cache_shardings)."""
    param_sh = planner.param_shardings(params, cfg, plan, mesh)
    cache_sh = planner.cache_shardings(caches, cfg, plan, mesh)
    return param_sh, cache_sh, NamedSharding(mesh, P())


# ======================================================================================
# Host-side continuous batcher
# ======================================================================================

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def default_buckets(max_len: int, lo: int = 8) -> List[int]:
    """Power-of-two padded-prefill lengths up to the cache size: [8, 16, ..., T]."""
    out, b = [], lo
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


class ServeEngine:
    """Continuous batcher over a fixed-size slot table (DESIGN.md §3.6).

    Mixed-length prompts are admitted into free slots via length-bucketed padded
    prefill; finished requests retire and their slot refills immediately without
    draining the rest of the batch. Decode advances all slots in lock-step with a
    per-slot ``cur_len`` vector; sampling (greedy by default, temperature/top-k
    otherwise) happens on-device inside the jit'd step.

    ``eos_id=None`` (default) disables EOS termination — token 0 is the pad token,
    so an implicit ``eos=0`` would silently truncate on any pad-token sample; pass
    the tokenizer's real EOS id explicitly.

    ``scheduler="grouped"`` keeps the admission policy of the pre-§3.6 engine
    (equal-exact-length groups, drained to completion) as the throughput baseline
    for ``benchmarks/serving_bench.py``.

    ``mesh=`` (+ optional ``plan=``, default ``planner.make_serve_plan``) serves
    TP-sharded (DESIGN.md §3.7): params/caches are placed per the plan's
    ``NamedSharding`` pytrees and both steps are jit'd with matching in/out
    shardings. Token-exact vs single-device serving on every path × KV mode
    (tests/test_sharded_serving.py).

    SSM / hybrid families use exact-length buckets: their recurrent state is built
    by a scan over the whole prefill window, so right-padding would fold garbage
    tokens into the state (attention caches mask padded positions instead).
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int, max_len: int,
                 quant: Optional[ql.QuantConfig] = None,
                 eos_id: Optional[int] = None,
                 path: Optional[str] = None, kv_cache: str = "fp",
                 scheduler: str = "continuous",
                 prefill_buckets: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None,
                 plan: Optional["planner.Plan"] = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0):
        assert kv_cache in ("fp", "int8"), kv_cache
        assert scheduler in ("continuous", "grouped"), scheduler
        self.cfg, self.params = cfg, params
        self.B, self.T = batch_size, max_len
        self.eos = eos_id
        self.kv_int8 = kv_cache == "int8"
        self.scheduler = scheduler
        self.pad_prefill = cfg.family not in ("ssm", "hybrid")
        self.buckets = sorted(b for b in (prefill_buckets or default_buckets(max_len))
                              if b <= max_len)
        admit = make_admit_step(cfg, quant, path=path, temperature=temperature,
                                top_k=top_k)
        decode = make_serve_decode_step(cfg, quant, path=path,
                                        temperature=temperature, top_k=top_k)
        self.caches = M.init_cache(cfg, batch_size, max_len, dtype=jnp.float32,
                                   kv_int8=self.kv_int8)
        self.mesh = mesh
        self.plan = None
        if mesh is None:
            self._admit_step = jax.jit(admit)
            self._decode_step = jax.jit(decode)
        else:
            # TP-sharded serving (DESIGN.md §3.7): place the prepared integer tree
            # (weights + scale leaves), the slot-table caches (incl. int8-KV
            # per-token scales) and jit the steps with NamedSharding-constrained
            # in/out shardings so GSPMD partitions prefill/decode. Host tokens,
            # lens, slots, cur_len and the PRNG key stay replicated. Cache in/out
            # shardings match, so the carried slot table never reshard-pingpongs.
            self.plan = plan or planner.make_serve_plan(cfg, mesh)
            param_sh, cache_sh, repl = shard_serving_state(
                params, self.caches, cfg, self.plan, mesh)
            self.params = jax.device_put(params, param_sh)
            self.caches = jax.device_put(self.caches, cache_sh)
            self._admit_step = jax.jit(
                _hinted(admit, self.plan, mesh),
                in_shardings=(param_sh, repl, repl, repl, cache_sh, repl),
                out_shardings=(repl, cache_sh))
            self._decode_step = jax.jit(
                _hinted(decode, self.plan, mesh),
                in_shardings=(param_sh, repl, cache_sh, repl, repl),
                out_shardings=(repl, cache_sh))
        self.queue: List[Request] = []
        self._slots: List[Optional[Request]] = [None] * batch_size
        self._pos = np.zeros(batch_size, np.int32)       # tokens in cache per slot
        self._pending = np.zeros(batch_size, np.int32)   # next input token per slot
        self._key = jax.random.PRNGKey(seed)
        self._greedy = temperature <= 0.0
        self._step = 0
        self._next_rid = 0
        self.stats = {"prefill_calls": 0, "decode_steps": 0,
                      "active_slot_steps": 0, "mid_decode_admissions": 0}

    # ---------------------------------------------------------------- submission

    def submit(self, prompts: List[np.ndarray],
               max_new: Union[int, Sequence[int]] = 16) -> List[Request]:
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        reqs = []
        for p, mn in zip(prompts, max_new):
            p = np.asarray(p, np.int32)
            if not 0 < len(p) <= self.T:
                raise ValueError(f"prompt length {len(p)} not in (0, {self.T}]")
            reqs.append(Request(self._next_rid, p, mn))
            self._next_rid += 1
        self.queue.extend(reqs)
        return reqs

    # ---------------------------------------------------------------- scheduling

    def _bucket(self, plen: int) -> int:
        if not self.pad_prefill:
            return plen
        for b in self.buckets:
            if b >= plen:
                return b
        return self.T

    def occupancy(self) -> float:
        steps = self.stats["decode_steps"]
        return self.stats["active_slot_steps"] / (steps * self.B) if steps else 0.0

    def _next_key(self) -> jax.Array:
        if self._greedy:            # sampler ignores the key: skip the fold_in op
            return self._key
        key = jax.random.fold_in(self._key, self._step)
        self._step += 1
        return key

    def _emit(self, slot: int, tok: int, finished: List[Request]) -> None:
        """Record one sampled token for a slot; retire the request when done."""
        r = self._slots[slot]
        r.out.append(tok)
        retire = (len(r.out) >= r.max_new
                  or (self.eos is not None and tok == self.eos)
                  or self._pos[slot] >= self.T)    # cache full: no room to append
        if retire:
            r.done = True
            finished.append(r)
            self._slots[slot] = None
            self._pos[slot] = 0
            self._pending[slot] = 0
        else:
            self._pending[slot] = tok

    def _admit(self, finished: List[Request]) -> None:
        while self.queue:
            free = [i for i, s in enumerate(self._slots) if s is None]
            if not free:
                return
            if self.scheduler == "grouped":
                # Legacy batcher: whole-batch groups of one exact length, drained to
                # completion before the next group starts.
                if len(free) < self.B:
                    return
                bucket = len(self.queue[0].prompt)
                fits = lambda r: len(r.prompt) == bucket
            else:
                bucket = self._bucket(len(self.queue[0].prompt))
                fits = lambda r: self._bucket(len(r.prompt)) == bucket
            batch, rest = [], []
            for r in self.queue:
                (batch if len(batch) < len(free) and fits(r) else rest).append(r)
            self.queue = rest

            # admission batch: rows padded to a power-of-two bucket so the set of
            # prefill lowerings is the static (row bucket × length bucket) grid;
            # sentinel slot index B marks padding rows (dropped by the scatter)
            rows = 1 << (len(batch) - 1).bit_length() if len(batch) > 1 else 1
            tokens = np.zeros((rows, bucket), np.int32)
            lens = np.ones(rows, np.int32)
            slot_ids = np.full(rows, self.B, np.int32)
            mid_decode = any(s is not None for s in self._slots)
            for j, (slot, r) in enumerate(zip(free, batch)):
                tokens[j, : len(r.prompt)] = r.prompt
                lens[j] = len(r.prompt)
                slot_ids[j] = slot
                self._slots[slot] = r
            tok, self.caches = self._admit_step(
                self.params, jnp.asarray(tokens), jnp.asarray(lens),
                jnp.asarray(slot_ids), self.caches, self._next_key())
            tok = np.asarray(tok)
            self.stats["prefill_calls"] += 1
            if mid_decode:
                self.stats["mid_decode_admissions"] += 1
            for j, (slot, r) in enumerate(zip(free, batch)):
                self._pos[slot] = len(r.prompt)
                self._emit(slot, int(tok[j]), finished)
            if self.scheduler == "grouped":
                return

    # ---------------------------------------------------------------- main loop

    def run(self) -> List[Request]:
        finished: List[Request] = []
        while self.queue or any(s is not None for s in self._slots):
            self._admit(finished)
            active = [i for i, s in enumerate(self._slots) if s is not None]
            if not active:
                continue   # everything admitted retired at its first token
            cur = jnp.asarray(self._pos + 1, jnp.int32)   # post-append lengths
            tok, self.caches = self._decode_step(
                self.params, jnp.asarray(self._pending), self.caches, cur,
                self._next_key())
            tok = np.asarray(tok)
            self._pos[active] += 1
            self.stats["decode_steps"] += 1
            self.stats["active_slot_steps"] += len(active)
            for i in active:
                self._emit(i, int(tok[i]), finished)
        return sorted(finished, key=lambda r: r.rid)
