"""Asyncio serving front end over data-parallel ``ServeEngine`` replicas
(DESIGN.md §3.11).

``AsyncServer`` owns ``replicas`` independent :class:`ServeEngine` instances,
each driven on its own thread (pinned to its own device when the process has
enough), and exposes one async streaming call::

    async with AsyncServer(cfg, params, config=EngineConfig(...)) as srv:
        async for ev in srv.submit(Request(prompt=[...], max_new=16)):
            ...  # StreamEvent: per-token frames, then one terminal frame

Three policies hold the SLO story together:

* **Bounded admission with backpressure** — at most ``max_queue`` requests are
  in flight server-wide, and on paged layouts a request must also fit some
  alive replica's page pool (worst-case reservation vs free + reclaimable
  pages); a submit past either bound waits up to ``admission_timeout`` seconds
  for capacity, then fails with a typed :class:`AdmissionError` whose
  ``reason`` says which bound held (``queue_full`` / ``pool_pressure``).
  Rejecting at the door beats admitting into a full page pool, where the
  overflow request would LRU-thrash the radix cache every admission round.
* **Prefix-affinity routing** — the router hashes the leading page-aligned
  prompt chunks and places each request on the replica whose radix index
  already holds the longest matching prefix (falling back to least-loaded), so
  dp replicas do not shred the §3.8 prefix cache across the fleet the way
  random placement does (measured by ``serving_bench_server``).
* **Replica health** — a replica whose engine thread throws is *drained* (its
  in-flight requests are requeued onto survivors as prompt+emitted
  continuations — greedy decoding makes the continuation token-exact, the same
  prefill/decode boundary invariance the warm/cold parity tests pin) and then
  restarted, with the restart budget accounted by the same
  :class:`~repro.runtime.supervisor.RestartTracker` the training supervisor
  uses. A replica that exhausts its budget is marked dead and routes no more.

Per-request metrics (TTFT, TPOT, queue wait, prefix hit, requeues — and with
``kernel_stats=True`` the paper's §4.1 quantization-kernel proportion measured
on exactly the tokens this request served) ride on the terminal StreamEvent;
fleet-level aggregates come from :meth:`AsyncServer.metrics`.
"""
from __future__ import annotations

import asyncio
import collections
import contextlib
import dataclasses
import logging
import threading
import time
from typing import AsyncIterator, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import kernel_analysis as KA
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.runtime.supervisor import (FailureInjector, ReplicaHealth,
                                      RestartTracker)
from repro.serving.api import (AdmissionError, FinishReason, Request,
                               RequestMetrics, StreamEvent)
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine
from repro.serving.engine import Request as EngineRequest

log = logging.getLogger("repro.server")


@dataclasses.dataclass
class _Record:
    """Server-side state of one in-flight request. Owned by the replica thread
    once dispatched; the event loop touches it again only after that thread
    has died (failure requeue)."""

    req: Request
    rid: str
    queue: "asyncio.Queue[StreamEvent]"
    submit_t: float
    admit_t: Optional[float] = None
    first_t: Optional[float] = None
    last_t: Optional[float] = None
    emitted: List[int] = dataclasses.field(default_factory=list)
    replica: int = -1
    requeues: int = 0
    prefix_reused: int = 0


class _KernelProportionObserver:
    """calibration.Observer protocol shim: running mean of the §4.1 CrossQuant
    kernel proportion over every quantized linear's activation rows."""

    def __init__(self, bits: int, alpha: float):
        self.bits, self.alpha = bits, alpha
        self.fracs: List[float] = []

    def observe(self, name, x):
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        self.fracs.append(float(KA.crossquant_kernel_fraction(
            x2, self.bits, self.alpha)))


class PrefixRouter:
    """Prefix-affinity placement across replicas (DESIGN.md §3.11).

    Keeps one LRU-capped set of page-aligned prompt-prefix hashes per replica
    — the host-visible mirror of what each replica's radix index plausibly
    still caches. ``route`` walks a prompt's prefix hashes longest-first and
    places it on the alive replica with the deepest match; no match (or
    ``policy`` = ``"least-loaded"``) falls back to the least-loaded replica,
    ``policy="random"`` is the seeded baseline the benchmark compares against.
    """

    def __init__(self, n_replicas: int, page_size: int, *,
                 policy: str = "affinity", seed: int = 0,
                 max_entries: int = 4096):
        if policy not in ("affinity", "least-loaded", "random"):
            raise ValueError(f"unknown router policy {policy!r}")
        self.policy = policy
        self.ps = page_size
        self.max_entries = max_entries
        # insertion-ordered dict as an LRU set per replica
        self._index: List[Dict[int, None]] = [dict() for _ in range(n_replicas)]
        self._rng = np.random.default_rng(seed)
        self.affinity_hits = 0

    def _hashes(self, prompt: np.ndarray) -> List[int]:
        return [hash(prompt[: (k + 1) * self.ps].tobytes())
                for k in range(len(prompt) // self.ps)]

    def route(self, prompt: np.ndarray, alive: Sequence[int],
              load: Dict[int, int]) -> int:
        if self.policy == "random":
            return int(self._rng.choice(np.asarray(alive)))
        if self.policy == "affinity":
            hashes = self._hashes(prompt)
            best, best_depth = None, 0
            for r in alive:
                idx = self._index[r]
                depth = 0
                for k, h in enumerate(hashes):
                    if h in idx:
                        depth = k + 1
                    else:
                        break
                if depth > best_depth or (depth == best_depth and best is not None
                                          and depth > 0
                                          and load[r] < load[best]):
                    best, best_depth = r, depth
            if best is not None and best_depth > 0:
                self.affinity_hits += 1
                return best
        return min(alive, key=lambda r: (load[r], r))

    def note(self, prompt: np.ndarray, replica: int) -> None:
        """Record that ``replica`` now caches this prompt's page-aligned
        prefixes (the engine inserts the full prompt into its radix index at
        admission, so every page-aligned prefix becomes reusable there)."""
        idx = self._index[replica]
        for h in self._hashes(prompt):
            idx.pop(h, None)
            idx[h] = None
        while len(idx) > self.max_entries:
            idx.pop(next(iter(idx)))

    def forget(self, replica: int) -> None:
        """Drop a replica's affinity state (its engine — and radix cache —
        was just torn down by a restart)."""
        self._index[replica].clear()


class _Replica:
    """One engine replica: a worker thread that builds its ``ServeEngine``
    (under ``jax.default_device`` when pinned), drains the inbox into the
    engine, steps it, and streams tokens back to the event loop. All engine
    state lives on this thread; the server communicates only through the
    locked inbox + wake event (in) and ``loop.call_soon_threadsafe`` (out)."""

    def __init__(self, server: "AsyncServer", idx: int, device=None,
                 injector: Optional[FailureInjector] = None):
        self.server = server
        self.idx = idx
        self.device = device
        self.injector = injector
        self.inbox: collections.deque = collections.deque()
        self.lock = threading.Lock()
        self.wake = threading.Event()
        self.pause_flag = threading.Event()
        self.stop_flag = threading.Event()
        self.ready = threading.Event()
        self.tracked: Dict[int, _Record] = {}      # engine rid -> record
        self.health = ReplicaHealth()
        self.tracker = RestartTracker(max_restarts=server.max_restarts)
        self.total_steps = 0                       # survives restarts
        self.engine: Optional[ServeEngine] = None
        self.thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ loop-side

    def start(self) -> None:
        self.ready.clear()
        self.thread = threading.Thread(target=self._main, daemon=True,
                                       name=f"replica-{self.idx}")
        self.thread.start()

    def post(self, rec: _Record) -> None:
        with self.lock:
            self.inbox.append(rec)
        self.wake.set()

    @property
    def load(self) -> int:
        with self.lock:
            return len(self.inbox) + len(self.tracked)

    @property
    def alive(self) -> bool:
        return self.health.state == "live"

    # ---------------------------------------------------------- thread-side

    def _main(self) -> None:
        ctx = (jax.default_device(self.device) if self.device is not None
               else contextlib.nullcontext())
        try:
            with ctx:
                engine = ServeEngine(self.server.cfg, self.server.params,
                                     config=self.server.config,
                                     quant=self.server.quant)
                engine.on_token = self._on_token
                self.engine = engine
                self.health.state = "live"
                self.ready.set()
                self._loop(engine)
        except Exception as e:      # WorkerFailure or anything else: drain
            self.ready.set()
            self._fail(e)

    def _loop(self, engine: ServeEngine) -> None:
        finished: List[EngineRequest] = []
        while not self.stop_flag.is_set():
            if self.pause_flag.is_set():
                time.sleep(0.002)
                continue
            self._drain(engine)
            busy = bool(engine.queue) or any(s is not None
                                             for s in engine._slots)
            if not busy:
                self.wake.wait(timeout=0.02)
                self.wake.clear()
                continue
            if self.injector is not None:
                self.injector.check(self.total_steps)  # raises WorkerFailure
            self.total_steps += 1
            self.health.steps += 1
            finished.clear()
            engine.step(finished)

    def _drain(self, engine: ServeEngine) -> None:
        while True:
            with self.lock:
                if not self.inbox:
                    return
                rec = self.inbox.popleft()
            now = time.monotonic()
            if rec.admit_t is None:
                rec.admit_t = now
            prompt = np.concatenate(
                [np.asarray(rec.req.prompt, np.int32),
                 np.asarray(rec.emitted, np.int32)]) \
                if rec.emitted else np.asarray(rec.req.prompt, np.int32)
            max_new = rec.req.max_new - len(rec.emitted)
            try:
                ereq = engine.submit([prompt], max_new=max_new)[0]
            except ValueError as e:     # e.g. prompt longer than the cache
                # count before posting: a consumer that saw the terminal frame
                # must find the counters already settled
                self.server._note_done(rec, completed=False)
                self._post(rec, StreamEvent(kind="error", rid=rec.rid,
                                            error=str(e)))
                continue
            self.tracked[ereq.rid] = rec

    def _on_token(self, r: EngineRequest, tok: int) -> None:
        rec = self.tracked.get(r.rid)
        if rec is None:
            return
        now = time.monotonic()
        if rec.first_t is None:
            rec.first_t = now
        rec.last_t = now
        rec.emitted.append(int(tok))
        rec.prefix_reused = max(rec.prefix_reused, r.prefix_reused)
        self._post(rec, StreamEvent(kind="token", rid=rec.rid, token=int(tok)))
        if r.done:
            del self.tracked[r.rid]
            self._finish(rec, r.finish_reason)

    def _finish(self, rec: _Record, reason: FinishReason) -> None:
        n = len(rec.emitted)
        kp = None
        if self.server.kernel_stats:
            kp = self.server._kernel_proportion(
                np.concatenate([np.asarray(rec.req.prompt, np.int32),
                                np.asarray(rec.emitted, np.int32)]))
        m = RequestMetrics(
            queue_wait_s=(rec.admit_t or rec.submit_t) - rec.submit_t,
            ttft_s=(rec.first_t - rec.submit_t) if rec.first_t else 0.0,
            tpot_s=((rec.last_t - rec.first_t) / (n - 1)
                    if n > 1 and rec.first_t else 0.0),
            n_tokens=n, prefix_reused=rec.prefix_reused,
            replica=self.idx, requeues=rec.requeues, kernel_proportion=kp)
        # count before posting the terminal frame: a consumer that saw it must
        # find the counters already settled
        self.server._note_done(rec, completed=True, metrics=m)
        self._post(rec, StreamEvent(kind="finished", rid=rec.rid,
                                    finish_reason=reason, metrics=m))

    def _post(self, rec: _Record, ev: StreamEvent) -> None:
        self.server._loop.call_soon_threadsafe(rec.queue.put_nowait, ev)

    def _fail(self, err: BaseException) -> None:
        """Terminal path of a dying replica thread: snapshot every request this
        replica still owed tokens to, then hand the mess to the event loop."""
        self.health.state = "restarting"
        self.health.last_error = f"{type(err).__name__}: {err}"
        with self.lock:
            queued = list(self.inbox)
            self.inbox.clear()
        interrupted = list(self.tracked.values()) + queued
        self.tracked.clear()
        self.engine = None
        log.warning("replica %d failed (%s); draining %d in-flight request(s)",
                    self.idx, err, len(interrupted))
        self.server._loop.call_soon_threadsafe(
            self.server._handle_replica_failure, self, interrupted, err)


class AsyncServer:
    """Async front end over ``replicas`` ServeEngine replicas (DESIGN.md §3.11).

    ``config`` is the shared :class:`EngineConfig` every replica serves;
    ``router`` picks the placement policy (``"affinity"`` / ``"least-loaded"``
    / ``"random"``); ``max_queue`` bounds server-wide in-flight requests
    (default ``2 × replicas × batch_size``) with ``admission_timeout`` seconds
    of grace before an :class:`AdmissionError`; ``injectors`` maps replica
    index → :class:`FailureInjector` for fault-injection tests;
    ``devices="auto"`` pins replica *i* to ``jax.devices()[i]`` when the
    process has at least ``replicas`` devices (single-device hosts share).
    ``kernel_stats=True`` replays each finished request eagerly to report the
    paper's §4.1 kernel proportion in its metrics. Use as an async context
    manager, or call :meth:`start` / :meth:`aclose` explicitly.
    """

    def __init__(self, cfg: ModelConfig, params, *, config: EngineConfig,
                 replicas: int = 2, quant: Optional[ql.QuantConfig] = None,
                 router: str = "affinity", max_queue: Optional[int] = None,
                 admission_timeout: float = 1.0, max_restarts: int = 2,
                 injectors: Optional[Dict[int, FailureInjector]] = None,
                 devices: str = "auto", kernel_stats: bool = False,
                 router_seed: int = 0):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.cfg, self.params = cfg, params
        self.config = config
        self.quant = quant
        self.max_restarts = max_restarts
        self.kernel_stats = kernel_stats
        self.max_queue = max_queue or 2 * replicas * config.batch_size
        self.admission_timeout = admission_timeout
        self.router = PrefixRouter(replicas, config.page_size, policy=router,
                                   seed=router_seed)
        devs = jax.devices() if devices == "auto" else list(devices or [])
        pin = len(devs) >= replicas
        inj = injectors or {}
        self.replicas = [_Replica(self, i, device=devs[i] if pin else None,
                                  injector=inj.get(i))
                         for i in range(replicas)]
        self.counters = {"submitted": 0, "completed": 0, "rejected": 0,
                         "errors": 0, "requeued": 0, "restarts": 0,
                         "routed": 0}
        self._ttfts: List[float] = []
        self._tpots: List[float] = []
        self._stats_lock = threading.Lock()   # counters vs replica threads
        self._inflight = 0
        self._next_rid = 0
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._cond: Optional[asyncio.Condition] = None
        self._started = False

    # -------------------------------------------------------------- lifecycle

    async def start(self) -> "AsyncServer":
        if self._started:
            return self
        self._loop = asyncio.get_running_loop()
        self._cond = asyncio.Condition()
        for r in self.replicas:
            r.start()
        for r in self.replicas:
            await self._loop.run_in_executor(None, r.ready.wait)
        self._started = True
        return self

    async def aclose(self) -> None:
        for r in self.replicas:
            r.stop_flag.set()
            r.wake.set()
        for r in self.replicas:
            if r.thread is not None:
                await self._loop.run_in_executor(None, r.thread.join)
        self._started = False

    async def __aenter__(self) -> "AsyncServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def pause(self) -> None:
        """Freeze every replica's engine loop (deterministic backpressure /
        routing tests); in-flight state is kept, nothing is dropped."""
        for r in self.replicas:
            r.pause_flag.set()

    def resume(self) -> None:
        for r in self.replicas:
            r.pause_flag.clear()
            r.wake.set()

    # -------------------------------------------------------------- admission

    def _worst_case_pages(self, request: Request) -> int:
        """The page reservation ``engine._plan_paged`` will commit for this
        request: every prompt token plus all-but-one generated token, capped at
        ``max_len``, rounded up to whole pages."""
        cfg = self.config
        toks = min(len(request.prompt) + max(request.max_new - 1, 0), cfg.max_len)
        return -(-toks // cfg.page_size)

    def _pool_blocked(self, request: Request) -> bool:
        """Paged layouts: True when no alive replica could cover the request's
        worst-case page reservation right now — counting free pages plus the
        radix-retained pages the engine's LRU eviction could reclaim (pages
        whose only reference is the cache itself; anything a live sequence
        holds is not reclaimable by waiting)."""
        if self.config.cache_layout != "paged":
            return False
        need = self._worst_case_pages(request)
        seen = False
        for r in self.replicas:
            eng = r.engine
            if not r.alive or eng is None or getattr(eng, "pool", None) is None:
                continue
            seen = True
            avail = eng.pool.free_count
            if eng.radix is not None:
                avail += sum(1 for p in eng.radix.held_pages()
                             if eng.pool.refs[p] == 1)
            if avail >= need:
                return False
        return seen

    async def submit(self, request: Request) -> AsyncIterator[StreamEvent]:
        """Stream one request: yields per-token ``StreamEvent`` frames and
        terminates after the ``finished`` (or ``error``) frame. Raises
        :class:`AdmissionError` when admission backpressure — ``max_queue``
        in-flight requests, or (paged layouts) no replica page pool able to
        cover the request's worst-case reservation — holds past
        ``admission_timeout`` seconds; ``AdmissionError.reason`` says which."""
        assert self._started, "call start() / use 'async with' first"
        rid = request.rid or f"req-{self._next_rid}"
        self._next_rid += 1
        t0 = time.monotonic()
        deadline = t0 + self.admission_timeout
        async with self._cond:
            while True:
                queue_ok = self._inflight < self.max_queue
                if queue_ok and not self._pool_blocked(request):
                    break
                reason = "queue_full" if not queue_ok else "pool_pressure"
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    with self._stats_lock:
                        self.counters["rejected"] += 1
                    what = (f"admission queue full ({self.max_queue} in flight)"
                            if reason == "queue_full" else
                            f"page-pool pressure ({self._worst_case_pages(request)}"
                            f" pages needed, no alive replica can cover it)")
                    raise AdmissionError(
                        f"{what} past {self.admission_timeout:.3g}s deadline",
                        queue_wait_s=time.monotonic() - t0, reason=reason)
                try:
                    # In-flight count changes notify this condition; page-pool
                    # occupancy changes on the replica threads, which do not —
                    # so wait on a short tick and re-poll the pools.
                    await asyncio.wait_for(self._cond.wait(),
                                           timeout=min(remaining, 0.05))
                except asyncio.TimeoutError:
                    pass
            self._inflight += 1
        with self._stats_lock:
            self.counters["submitted"] += 1
        rec = _Record(req=request, rid=rid, queue=asyncio.Queue(),
                      submit_t=t0)
        try:
            self._dispatch(rec)
            while True:
                ev = await rec.queue.get()
                yield ev
                if ev.terminal:
                    break
        finally:
            async with self._cond:
                self._inflight -= 1
                self._cond.notify_all()

    def _dispatch(self, rec: _Record,
                  exclude: Optional[int] = None) -> None:
        """Place a record on a replica (router policy or ``replica_hint``),
        or emit a terminal error when no replica is alive."""
        alive = [r.idx for r in self.replicas
                 if r.alive and r.idx != exclude]
        if not alive:
            with self._stats_lock:
                self.counters["errors"] += 1
            rec.queue.put_nowait(StreamEvent(
                kind="error", rid=rec.rid, error="no live replica"))
            return
        hint = rec.req.replica_hint
        if hint is not None and hint in alive:
            target = hint
        else:
            prompt = np.asarray(rec.req.prompt, np.int32)
            load = {r.idx: r.load for r in self.replicas}
            target = self.router.route(prompt, alive, load)
            self.router.note(prompt, target)
        with self._stats_lock:
            self.counters["routed"] += 1
        rec.replica = target
        self.replicas[target].post(rec)

    # ---------------------------------------------------------------- failure

    def _handle_replica_failure(self, replica: _Replica,
                                interrupted: List[_Record],
                                err: BaseException) -> None:
        """Event-loop side of a replica death: requeue every interrupted
        request onto a survivor as a prompt+emitted continuation (token-exact
        under greedy decoding — already-streamed tokens stand, the survivor
        re-prefills and continues), then restart the replica unless its
        budget is exhausted."""
        with self._stats_lock:
            self.counters["restarts"] += 1
        self.router.forget(replica.idx)
        for rec in interrupted:
            rec.requeues += 1
            with self._stats_lock:
                self.counters["requeued"] += 1
            if rec.req.max_new - len(rec.emitted) <= 0:
                # the failing step emitted the last token but died before the
                # finished frame went out: close the stream as LENGTH
                rec.queue.put_nowait(StreamEvent(
                    kind="finished", rid=rec.rid,
                    finish_reason=FinishReason.LENGTH,
                    metrics=RequestMetrics(n_tokens=len(rec.emitted),
                                           replica=replica.idx,
                                           requeues=rec.requeues)))
                continue
            self._dispatch(rec, exclude=replica.idx)
        try:
            replica.tracker.record(err, what=f"replica {replica.idx}")
        except RuntimeError:
            replica.health.state = "dead"
            log.error("replica %d is dead (restart budget exhausted)",
                      replica.idx)
            return
        replica.health.restarts += 1
        replica.start()     # fresh thread + fresh engine; state goes live
                            # once the engine is rebuilt (ready event)

    # ---------------------------------------------------------------- metrics

    def _note_done(self, rec: _Record, *, completed: bool,
                   metrics: Optional[RequestMetrics] = None) -> None:
        # called from replica threads: dict-entry += is not atomic across
        # threads, so all counter mutation goes through one lock
        with self._stats_lock:
            self.counters["completed" if completed else "errors"] += 1
            if metrics is not None:
                self._ttfts.append(metrics.ttft_s)
                if metrics.n_tokens > 1:
                    self._tpots.append(metrics.tpot_s)

    def _kernel_proportion(self, tokens: np.ndarray) -> float:
        """Paper §4.1 per-request quantization-kernel proportion: replay the
        request's served tokens eagerly with an activation observer and return
        the mean CrossQuant kernel fraction across quantized linears."""
        quant = self.quant or self.cfg.quant
        bits = getattr(quant, "a_bits", 8) or 8
        alpha = getattr(quant, "alpha", 0.15)
        obs = _KernelProportionObserver(bits, alpha)
        M.apply(self.params, {"tokens": jnp.asarray(tokens[None])}, self.cfg,
                ctx=QuantContext(quant, observer=obs), mode="train",
                unroll=True)
        return float(np.mean(obs.fracs)) if obs.fracs else 0.0

    def metrics(self) -> dict:
        """Fleet metrics snapshot: server counters, request-latency aggregates
        and per-replica health + engine stats (the stable ``EngineStats``
        ``to_dict()`` schema serving_bench shares)."""
        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0
        ttfts, tpots = list(self._ttfts), list(self._tpots)
        reps = []
        for r in self.replicas:
            d = r.health.to_dict()
            d["load"] = r.load
            eng = r.engine
            d["engine"] = eng.stats().to_dict() if eng is not None else None
            reps.append(d)
        return {
            "server": {**self.counters,
                       "affinity_hits": self.router.affinity_hits,
                       "inflight": self._inflight,
                       "max_queue": self.max_queue},
            "latency": {"ttft_p50_s": pct(ttfts, 50),
                        "ttft_p95_s": pct(ttfts, 95),
                        "tpot_p50_s": pct(tpots, 50),
                        "tpot_p95_s": pct(tpots, 95)},
            "replicas": reps,
        }
