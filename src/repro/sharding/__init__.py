from repro.sharding.planner import (  # noqa: F401
    Plan, make_plan, param_shardings, batch_shardings, cache_shardings, replicated,
)
