"""Activation-sharding hints (contextvars) consumed inside model code.

GSPMD propagates most activation shardings from parameter/input shardings, but a few
internal tensors need explicit constraints to avoid pathological layouts — notably the
MoE dispatch buffer (must be expert-sharded, not replicated) and the post-embedding
activations (a gather output can lose its batch sharding, after which the partitioner
replicates whole activation stacks). Launchers set these hints around tracing; unit
tests and eager code leave them unset (every constraint degrades to a no-op).

Axis *sizes* are carried in the hints (from the concrete mesh) because
``jax.sharding.get_abstract_mesh()`` is empty under a plain ``with mesh:`` scope —
divisibility checks cannot read the mesh from inside a trace.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_EP_AXIS: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "ep_axis", default=None)
_DP_AXES: contextvars.ContextVar[Optional[Tuple[str, ...]]] = contextvars.ContextVar(
    "dp_axes", default=None)
_TP_AXIS: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "tp_axis", default=None)
_AXIS_SIZES: contextvars.ContextVar[Dict[str, int]] = contextvars.ContextVar(
    "axis_sizes", default={})
_MESH: contextvars.ContextVar = contextvars.ContextVar("hint_mesh", default=None)
_KV_SEQ_AXIS: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "kv_seq_axis", default=None)
_TOKEN_GROUPS: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "token_groups", default=True)


@contextlib.contextmanager
def sharding_hints(ep_axis: Optional[str] = None,
                   dp_axes: Optional[Tuple[str, ...]] = None,
                   tp_axis: Optional[str] = None,
                   mesh=None,
                   kv_seq_axis: Optional[str] = None,
                   token_groups: bool = True):
    sizes = dict(mesh.shape) if mesh is not None else {}
    t1 = _EP_AXIS.set(ep_axis)
    t2 = _DP_AXES.set(dp_axes)
    t3 = _TP_AXIS.set(tp_axis)
    t4 = _AXIS_SIZES.set(sizes)
    t5 = _MESH.set(mesh)
    t6 = _KV_SEQ_AXIS.set(kv_seq_axis)
    t7 = _TOKEN_GROUPS.set(token_groups)
    try:
        yield
    finally:
        _EP_AXIS.reset(t1)
        _DP_AXES.reset(t2)
        _TP_AXIS.reset(t3)
        _AXIS_SIZES.reset(t4)
        _MESH.reset(t5)
        _KV_SEQ_AXIS.reset(t6)
        _TOKEN_GROUPS.reset(t7)


def _constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that does not require an ambient mesh context:
    when the hints carry a concrete mesh (serving engine, launchers), the spec is
    bound to it as a NamedSharding; otherwise the plain-spec form is used (the
    dry-run already traces under ``with mesh:``)."""
    mesh = _MESH.get()
    if mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def _axis_size(axes) -> int:
    sizes = _AXIS_SIZES.get()
    if not sizes:
        return 1 << 62   # unknown mesh: fail every divisibility check -> no-op
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        if a not in sizes:
            return 1 << 62
        n *= sizes[a]
    return n


def constrain_experts(x: jax.Array) -> jax.Array:
    """x: (E, C, d) stacked expert buffers — pin E to the EP axis (when divisible)
    and the capacity axis to the data axes (token parallelism inside the expert
    computation). Without the C constraint the dispatch buffer replicates across the
    data axis: 7.5 GB/device on granite-moe train_4k (EXPERIMENTS.md §Perf)."""
    ep = _EP_AXIS.get()
    dp = _DP_AXES.get()
    spec = [None] * x.ndim
    if ep is not None and x.shape[0] % _axis_size(ep) == 0:
        spec[0] = ep
    if dp is not None and x.ndim >= 2 and x.shape[1] % _axis_size(dp) == 0:
        spec[1] = dp
    if all(s is None for s in spec):
        return x
    return _constrain(x, P(*spec))


def constrain_batch(x: jax.Array) -> jax.Array:
    """(B, ...) activations — pin the leading batch axis to the data axes.

    GSPMD mostly propagates batch sharding from the input tokens, but gathers
    (embedding lookups) and microbatch reshapes can lose it, after which the
    partitioner replicates entire activation stacks (observed: 265 GB/device temps on
    mamba2 train_4k before this constraint — EXPERIMENTS.md §Perf iteration 0)."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.ndim == 0 or x.shape[0] % _axis_size(axes) != 0:
        return x
    return _constrain(x, P(axes, *([None] * (x.ndim - 1))))


def constrain_tokens(x: jax.Array) -> jax.Array:
    """x: (N, d) flat token activations — pin to the data axes if hinted."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.shape[0] % _axis_size(axes) != 0:
        return x
    return _constrain(x, P(axes, *([None] * (x.ndim - 1))))


def token_group_count(n_tokens: int) -> int:
    """Number of dp-aligned token groups for grouped MoE dispatch (GShard-style
    per-group capacity). Equals the data-axis size when it divides the token count,
    else 1 (single global dispatch — tests, eager mode).

    Grouping changes the *capacity arithmetic*: per-group capacity admits a
    different set of (token, k) assignments than one global dispatch whenever an
    expert overflows, so grouped and global dispatch are not token-exact. Callers
    that need mesh-invariant numerics — the serving engine, whose EP parity
    contract is bitwise vs single-device (§3.13) — trace under
    ``sharding_hints(..., token_groups=False)``, which forces global dispatch."""
    if not _TOKEN_GROUPS.get():
        return 1
    axes = _DP_AXES.get()
    if axes is None:
        return 1
    g = _axis_size(axes)
    if g >= (1 << 62) or n_tokens % g != 0:
        return 1
    return g


def constrain_token_groups(x: jax.Array) -> jax.Array:
    """(G, N/G, ...) grouped tokens — pin the group axis to the data axes so every
    per-group dispatch gather/scatter has a sharded batch dimension (SPMD partitions
    batched gathers on their parallel dims; unbatched dispatch gathers replicate the
    whole (N·K, d) expansion — 48 GiB/device on granite prefill_32k,
    EXPERIMENTS.md §Perf)."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.shape[0] % _axis_size(axes) != 0:
        return x
    return _constrain(x, P(axes, *([None] * (x.ndim - 1))))


def constrain_grouped_experts(x: jax.Array) -> jax.Array:
    """(G, E, C, d) grouped expert buffers — G → data axes, E → EP axis."""
    ep = _EP_AXIS.get()
    dp = _DP_AXES.get()
    spec = [None] * x.ndim
    if dp is not None and x.shape[0] % _axis_size(dp) == 0:
        spec[0] = dp
    if ep is not None and x.ndim >= 2 and x.shape[1] % _axis_size(ep) == 0:
        spec[1] = ep
    if all(s is None for s in spec):
        return x
    return _constrain(x, P(*spec))


def constrain_microbatches(x: jax.Array) -> jax.Array:
    """(n_micro, B_micro, ...) stacked microbatches — dp on axis 1, never axis 0
    (the scan axis must stay unsharded or every scan step pays a reshard)."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.ndim < 2 or x.shape[1] % _axis_size(axes) != 0:
        return x
    return _constrain(x, P(None, axes, *([None] * (x.ndim - 2))))


def constrain_gemm_acc(acc: jax.Array, expert_leading: bool = False) -> jax.Array:
    """int32 GEMM accumulator of a quantized linear (DESIGN.md §3.7) — pin to the
    natural output layout (batch → dp, d_out → model, everything else replicated)
    *while still int32*.

    For a row-parallel weight (contraction dim sharded over the model axis) this
    forces the cross-shard partial-sum reduction to happen on the integer
    accumulator BEFORE the f32 dequant multiply. Without the pin the partitioner
    is free to sink the all-reduce past the elementwise dequant, summing partially
    dequantized f32 shards — numerically close, but no longer the bitwise-exact
    integer contraction the single-device path computes, and exactly the
    per-channel/per-token scale-handling trap ZeroQuant-V2 documents for
    quantized-TP serving.

    ``expert_leading=True`` marks stacked-expert accumulators ((E, C, d_out) or
    (E, C, G, d_out)): dim 0 is the expert axis (pinned to the EP axis when
    hinted and divisible — the expert_tp case leaves it replicated) and dim 1 the
    capacity axis (→ dp), mirroring constrain_experts."""
    tp = _TP_AXIS.get()
    dp = _DP_AXES.get()
    if tp is None and dp is None:
        return acc
    spec = [None] * acc.ndim
    if expert_leading:
        ep = _EP_AXIS.get()
        if ep is not None and acc.shape[0] % _axis_size(ep) == 0:
            spec[0] = ep
        if dp is not None and acc.ndim >= 3 and acc.shape[1] % _axis_size(dp) == 0:
            spec[1] = dp
    elif dp is not None and acc.ndim >= 2 and acc.shape[0] % _axis_size(dp) == 0:
        spec[0] = dp
    used = {a for s in spec if s is not None
            for a in ((s,) if isinstance(s, str) else s)}
    if tp is not None and tp not in used and acc.shape[-1] % _axis_size(tp) == 0:
        spec[-1] = tp
    return _constrain(acc, P(*spec))


def constrain_kv_cache(x: jax.Array) -> jax.Array:
    """(B, T, Hkv, D|1) attention-cache leaf (codes or int8-KV per-token scales) —
    pin B to the data axes and, when the plan sequence-shards decode caches, T to
    the model axis. Applied to freshly written cache leaves so the per-step scatter
    output keeps the slot table's placement instead of GSPMD resharding the whole
    cache every decode step."""
    dp = _DP_AXES.get()
    kv_seq = _KV_SEQ_AXIS.get()
    if (dp is None and kv_seq is None) or x.ndim < 2:
        return x
    spec = [None] * x.ndim
    if dp is not None and x.shape[0] % _axis_size(dp) == 0:
        spec[0] = dp
    if kv_seq is not None and x.shape[1] % _axis_size(kv_seq) == 0:
        spec[1] = kv_seq
    if all(s is None for s in spec):
        return x
    return _constrain(x, P(*spec))


def constrain_kv_pages(x: jax.Array) -> jax.Array:
    """(P, ps, Hkv, D|1) paged-KV pool leaf (codes, fp pages or int8 per-token
    scale pages — DESIGN.md §3.8) — pin the physical page axis to the data axes
    and the kv-head axis to the model axis when they divide, mirroring
    planner.cache_shardings so the per-step page scatter keeps the pool's
    placement instead of GSPMD resharding the whole pool every decode step.
    The page table itself stays replicated (tiny, host-owned)."""
    dp = _DP_AXES.get()
    tp = _TP_AXIS.get()
    if (dp is None and tp is None) or x.ndim < 4:
        return x
    spec = [None] * x.ndim
    if dp is not None and x.shape[0] % _axis_size(dp) == 0:
        spec[0] = dp
    if tp is not None and x.shape[2] % _axis_size(tp) == 0:
        spec[2] = tp
    if all(s is None for s in spec):
        return x
    return _constrain(x, P(*spec))


def constrain_state_pages(x: jax.Array) -> jax.Array:
    """Paged SSM state pools (DESIGN.md §3.13): ``state_pages`` (P, H, Pd, N) or
    ``conv_pages`` (P, K-1, C) — pin the physical page axis to the data axes and,
    for the 4-d recurrent-state pool, the head axis to the model axis, mirroring
    planner.cache_shardings. Deliberately NOT routed through constrain_kv_pages:
    that helper pins dim 2 of any 4-d leaf (the kv-head axis of a KV pool), which
    on a state pool would land on the head-*dim* axis instead of the head axis."""
    dp = _DP_AXES.get()
    tp = _TP_AXIS.get()
    if (dp is None and tp is None) or x.ndim < 3:
        return x
    spec = [None] * x.ndim
    if dp is not None and x.shape[0] % _axis_size(dp) == 0:
        spec[0] = dp
    if tp is not None and x.ndim >= 4 and x.shape[1] % _axis_size(tp) == 0:
        spec[1] = tp
    if all(s is None for s in spec):
        return x
    return _constrain(x, P(*spec))


def constrain_vocab(logits: jax.Array) -> jax.Array:
    """(B, S, V_padded) logits — batch to dp, padded vocab to the model axis (the
    whole point of vocab_padded: logits shard over model instead of replicating)."""
    tp = _TP_AXIS.get()
    dp = _DP_AXES.get()
    if tp is None and dp is None:
        return logits
    spec = [None] * logits.ndim
    if dp is not None and logits.shape[0] % _axis_size(dp) == 0:
        spec[0] = dp
    if tp is not None and logits.shape[-1] % _axis_size(tp) == 0:
        spec[-1] = tp
    if all(s is None for s in spec):
        return logits
    return _constrain(logits, P(*spec))


def current_mesh():
    """The hinted concrete mesh, or None. Kernel wrappers thread this into their
    jitted bodies as a *static* argument: jit's trace cache does not key on
    contextvars, so reading the hint inside a traced body would silently reuse
    whichever lowering (manual-region or plain) happened to be traced first."""
    return _MESH.get()


def manual_kernel(fn, args: tuple, mesh=None):
    """Run a Pallas kernel wrapper body as a GSPMD-*manual* region (DESIGN.md
    §3.7): ``shard_map`` over ``mesh`` with fully replicated in/out specs, so
    every device computes the exact single-device result on gathered operands.

    Why not sharding constraints: off-TPU the kernels run in interpret mode — the
    "kernel" is ordinary HLO emulating the grid (fori over blocks + dynamic
    slices), and this XLA version miscompiles parts of that emulation once
    operand shardings propagate into it (observed: concatenating a model-sharded
    ``bcol`` with its block padding multiplies the values by the data-axis size —
    a partitioner bug, reproduced standalone). A manual region takes the
    partitioner out of the loop entirely. Weights stay *stored* sharded — the
    per-device HBM win — and are gathered at this boundary; partitioning the
    kernel grid itself over the mesh (Mosaic) is future work. No-op when ``mesh``
    is None.

    ``args`` may carry ``None`` leaves for optional operands — e.g. the paged
    decode kernel's int8-KV per-token scale pools (DESIGN.md §3.8), absent on
    fp pools: the per-leaf ``tree_map`` leaves them un-spec'd, so one boundary
    serves both the fp and int8-KV operand tuples (any operand relayout, like
    the scale pools' (P, ps, Hkv, 1)→(P, Hkv, ps) transpose, belongs *inside*
    ``fn`` where the partitioner cannot touch it)."""
    if mesh is None:
        return fn(*args)
    from jax.experimental.shard_map import shard_map

    replicated = jax.tree_util.tree_map(lambda _: P(), args)
    return shard_map(fn, mesh=mesh, in_specs=replicated, out_specs=P(),
                     check_rep=False)(*args)
