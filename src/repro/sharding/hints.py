"""Activation-sharding hints (contextvars) consumed inside model code.

GSPMD propagates most activation shardings from parameter/input shardings, but a few
internal tensors need explicit constraints to avoid pathological layouts — notably the
MoE dispatch buffer (must be expert-sharded, not replicated) and the post-embedding
activations (a gather output can lose its batch sharding, after which the partitioner
replicates whole activation stacks). Launchers set these hints around tracing; unit
tests and eager code leave them unset (every constraint degrades to a no-op).

Axis *sizes* are carried in the hints (from the concrete mesh) because
``jax.sharding.get_abstract_mesh()`` is empty under a plain ``with mesh:`` scope —
divisibility checks cannot read the mesh from inside a trace.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_EP_AXIS: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "ep_axis", default=None)
_DP_AXES: contextvars.ContextVar[Optional[Tuple[str, ...]]] = contextvars.ContextVar(
    "dp_axes", default=None)
_TP_AXIS: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "tp_axis", default=None)
_AXIS_SIZES: contextvars.ContextVar[Dict[str, int]] = contextvars.ContextVar(
    "axis_sizes", default={})


@contextlib.contextmanager
def sharding_hints(ep_axis: Optional[str] = None,
                   dp_axes: Optional[Tuple[str, ...]] = None,
                   tp_axis: Optional[str] = None,
                   mesh=None):
    sizes = dict(mesh.shape) if mesh is not None else {}
    t1 = _EP_AXIS.set(ep_axis)
    t2 = _DP_AXES.set(dp_axes)
    t3 = _TP_AXIS.set(tp_axis)
    t4 = _AXIS_SIZES.set(sizes)
    try:
        yield
    finally:
        _EP_AXIS.reset(t1)
        _DP_AXES.reset(t2)
        _TP_AXIS.reset(t3)
        _AXIS_SIZES.reset(t4)


def _axis_size(axes) -> int:
    sizes = _AXIS_SIZES.get()
    if not sizes:
        return 1 << 62   # unknown mesh: fail every divisibility check -> no-op
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        if a not in sizes:
            return 1 << 62
        n *= sizes[a]
    return n


def constrain_experts(x: jax.Array) -> jax.Array:
    """x: (E, C, d) stacked expert buffers — pin E to the EP axis (when divisible)
    and the capacity axis to the data axes (token parallelism inside the expert
    computation). Without the C constraint the dispatch buffer replicates across the
    data axis: 7.5 GB/device on granite-moe train_4k (EXPERIMENTS.md §Perf)."""
    ep = _EP_AXIS.get()
    dp = _DP_AXES.get()
    spec = [None] * x.ndim
    if ep is not None and x.shape[0] % _axis_size(ep) == 0:
        spec[0] = ep
    if dp is not None and x.ndim >= 2 and x.shape[1] % _axis_size(dp) == 0:
        spec[1] = dp
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_batch(x: jax.Array) -> jax.Array:
    """(B, ...) activations — pin the leading batch axis to the data axes.

    GSPMD mostly propagates batch sharding from the input tokens, but gathers
    (embedding lookups) and microbatch reshapes can lose it, after which the
    partitioner replicates entire activation stacks (observed: 265 GB/device temps on
    mamba2 train_4k before this constraint — EXPERIMENTS.md §Perf iteration 0)."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.ndim == 0 or x.shape[0] % _axis_size(axes) != 0:
        return x
    return jax.lax.with_sharding_constraint(x, P(axes, *([None] * (x.ndim - 1))))


def constrain_tokens(x: jax.Array) -> jax.Array:
    """x: (N, d) flat token activations — pin to the data axes if hinted."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.shape[0] % _axis_size(axes) != 0:
        return x
    return jax.lax.with_sharding_constraint(x, P(axes, *([None] * (x.ndim - 1))))


def token_group_count(n_tokens: int) -> int:
    """Number of dp-aligned token groups for grouped MoE dispatch (GShard-style
    per-group capacity). Equals the data-axis size when it divides the token count,
    else 1 (single global dispatch — tests, eager mode)."""
    axes = _DP_AXES.get()
    if axes is None:
        return 1
    g = _axis_size(axes)
    if g >= (1 << 62) or n_tokens % g != 0:
        return 1
    return g


def constrain_token_groups(x: jax.Array) -> jax.Array:
    """(G, N/G, ...) grouped tokens — pin the group axis to the data axes so every
    per-group dispatch gather/scatter has a sharded batch dimension (SPMD partitions
    batched gathers on their parallel dims; unbatched dispatch gathers replicate the
    whole (N·K, d) expansion — 48 GiB/device on granite prefill_32k,
    EXPERIMENTS.md §Perf)."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.shape[0] % _axis_size(axes) != 0:
        return x
    return jax.lax.with_sharding_constraint(x, P(axes, *([None] * (x.ndim - 1))))


def constrain_grouped_experts(x: jax.Array) -> jax.Array:
    """(G, E, C, d) grouped expert buffers — G → data axes, E → EP axis."""
    ep = _EP_AXIS.get()
    dp = _DP_AXES.get()
    spec = [None] * x.ndim
    if dp is not None and x.shape[0] % _axis_size(dp) == 0:
        spec[0] = dp
    if ep is not None and x.ndim >= 2 and x.shape[1] % _axis_size(ep) == 0:
        spec[1] = ep
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_microbatches(x: jax.Array) -> jax.Array:
    """(n_micro, B_micro, ...) stacked microbatches — dp on axis 1, never axis 0
    (the scan axis must stay unsharded or every scan step pays a reshard)."""
    axes = _DP_AXES.get()
    if axes is None:
        return x
    if x.ndim < 2 or x.shape[1] % _axis_size(axes) != 0:
        return x
    return jax.lax.with_sharding_constraint(x, P(None, axes, *([None] * (x.ndim - 2))))
