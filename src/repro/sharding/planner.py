"""Divisibility-aware sharding planner (DESIGN.md §3.4).

Head counts and widths in the assigned pool rarely divide the model axis (56/40/36/24
heads vs tp=16), so hand-written per-model shardings would either error or silently
replicate. The planner chooses, per (arch × workload), the strongest tier whose
divisibility constraints hold, and emits concrete ``NamedSharding`` pytrees for params,
optimizer state, batches and KV/SSM caches. Every rule degrades gracefully: a dimension
that does not divide its target axis is replicated, never an error.

Tiers (attention handling):
  tp_full    q, kv heads and ffn sharded over "model"
  tp_kv_rep  kv replicated (GQA repeat stays shard-local), q + ffn sharded
  tp_ffn     attention replicated, ffn/vocab sharded
MoE: EP over "model" when E divides, else expert-internal TP (d_ff_expert divides).
Decode KV caches are sequence-sharded over "model" (flash-decoding via GSPMD partial
softmax) — the only layout that fits TB-scale 32k caches when kv-heads don't divide.
Training additionally FSDP-shards weight input dims over the data axes (ZeRO-3:
all-gather per scanned layer).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class Plan:
    tier: str                  # tp_full | tp_kv_rep | tp_ffn
    moe_mode: str              # none | ep | expert_tp | expert_axis
    dp_axes: Tuple[str, ...]   # batch axes, e.g. ("pod", "data")
    tp_axis: str               # "model"
    dp: int
    tp: int
    fsdp: bool                 # shard weight free dims over dp axes (training)
    seq_shard_kv: bool         # decode caches: T over model
    # Dedicated expert-parallel mesh axis (DESIGN.md §3.13): when the mesh carries
    # an "expert" axis that divides n_experts, stacked (E, ...) expert trees shard
    # on E over it (moe_mode == "expert_axis") — orthogonal to the model axis, so
    # tp×ep meshes compose. None on 2-axis meshes (legacy "ep" then shards experts
    # over the model axis as before).
    ep_axis: Optional[str] = None
    ep: int = 1

    def describe(self) -> dict:
        return dataclasses.asdict(self)


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def make_serve_plan(cfg: ModelConfig, mesh: Mesh,
                    force_tier: Optional[str] = None) -> Plan:
    """Serving-mode plan for ``ServeEngine`` (DESIGN.md §3.7): a decode-kind plan
    whose specs also cover *prepared integer* trees — int8/packed-int4 weights and
    their scale leaves (``sw``, ``bcol``, ``qalpha``) and packed sparsity ``mask``
    leaves follow the same model-axis split as the weight they dequantize — and
    slot-table KV caches including the int8-KV per-token scale leaves."""
    shape = ShapeConfig(name="serve", seq_len=0, global_batch=0, kind="decode")
    return make_plan(cfg, shape, mesh, force_tier=force_tier)


def make_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              force_tier: Optional[str] = None) -> Plan:
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = mesh.shape["model"]
    dp = _axis_size(mesh, dp_axes)

    if cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0:
        tier = "tp_full"
    elif cfg.n_heads % tp == 0:
        tier = "tp_kv_rep"
    else:
        tier = "tp_ffn"
    if force_tier:
        tier = force_tier
    if tier == "dp_only":
        # Small models waste a 16-wide TP axis (32-wide GEMM shards, per-layer
        # collectives dwarfing compute — mamba2-130m baseline, EXPERIMENTS.md
        # §Perf). dp_only folds the model axis into data parallelism: batch shards
        # over (data, model), weights FSDP over the full mesh, no TP collectives.
        dp_axes = dp_axes + ("model",)
        dp = _axis_size(mesh, dp_axes)

    ep_axis = None
    ep = 1
    moe_mode = "none"
    if cfg.n_experts and "expert" in mesh.shape and tier != "dp_only" \
            and cfg.n_experts % mesh.shape["expert"] == 0:
        # Dedicated expert axis: experts shard over it, expert-internal dims stay
        # whole (each expert GEMM runs entirely on one ep shard — its int32
        # contraction is shard-local, hence bitwise vs single-device).
        ep_axis, ep, moe_mode = "expert", mesh.shape["expert"], "expert_axis"
    elif cfg.n_experts and tier != "dp_only":
        if cfg.n_experts % tp == 0:
            moe_mode = "ep"
        elif (cfg.d_ff_expert or cfg.d_ff) % tp == 0:
            moe_mode = "expert_tp"

    return Plan(
        tier=tier, moe_mode=moe_mode, dp_axes=dp_axes, tp_axis="model",
        dp=dp, tp=tp, fsdp=(shape.kind == "train"), ep_axis=ep_axis, ep=ep,
        # KV caches are the dominant serving bytes at 32k context; sequence-shard them
        # over the model axis for decode (flash-decoding partial softmax) AND prefill
        # (the cache write pays one reshard; holding 32 × 32k × Hkv caches replicated
        # over model does not fit HBM — EXPERIMENTS.md §Perf).
        seq_shard_kv=(shape.kind in ("decode", "prefill")),
    )


# ======================================================================================
# Parameter shardings
# ======================================================================================

def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _maybe(axis: str | Tuple[str, ...], dim: int, mesh: Mesh):
    """Return the axis if the dim divides it, else None (replicate)."""
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _param_spec(pathstr: str, shape: Tuple[int, ...], cfg: ModelConfig,
                plan: Plan, mesh: Mesh) -> P:
    names = pathstr.split("/")
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    tp, dpa = plan.tp_axis, plan.dp_axes
    nd = len(shape)

    def build(out_axis: Optional[int], model_ok: bool, fsdp_axis: Optional[int] = None):
        """spec with `model` on dim `out_axis` (negative index) and optional FSDP dim.

        Hybrid ZeRO-3: when the weight carries no model-axis shard (tier degraded or
        dim not divisible), FSDP uses (data..., model) so parameters/optimizer shard
        over the *full* mesh — the difference between 35 GiB/dev and 4 GiB/dev on
        deepseek-33b train (EXPERIMENTS.md §Perf)."""
        spec: list = [None] * nd
        placed_model = False
        if model_ok and out_axis is not None and _maybe(tp, shape[out_axis], mesh):
            spec[out_axis] = tp
            placed_model = True
        if plan.fsdp and fsdp_axis is not None and spec[fsdp_axis] is None:
            full = dpa if tp in dpa else tuple(dpa) + (tp,)
            candidates = (dpa,) if placed_model else (full, dpa)
            for axes in candidates:
                if _maybe(axes, shape[fsdp_axis], mesh):
                    spec[fsdp_axis] = axes
                    break
        return P(*spec)

    # ---- scalars / vectors: norms, biases, A_log, D, dt_bias, conv, router ----------
    # (quantization-metadata leaves — sw/bcol/qalpha — are handled with their weight
    # below: scale vectors must split along the same model axis as the weight dim
    # they dequantize, or every sharded serving step pays a per-layer reshard.)
    if parent in ("router",) or leaf in ("scale", "bias", "conv_w", "conv_b", "A_log",
                                         "D", "dt_bias", "norm_scale"):
        return P(*([None] * nd))

    # ---- dp_only: pure FSDP over the folded (data+model) mesh, no TP placement -------
    if plan.tier == "dp_only":
        if leaf in ("w", "qw", "qw4") and nd >= 2:
            if _maybe(dpa, shape[-2], mesh):
                return build(out_axis=None, model_ok=False, fsdp_axis=-2)
            return build(out_axis=None, model_ok=False, fsdp_axis=-1)
        return P(*([None] * nd))

    # ---- embedding / lm head ---------------------------------------------------------
    if parent == "embed":
        spec: list = [None] * nd
        placed = False
        if _maybe(tp, shape[-2], mesh):
            spec[-2] = tp                                    # vocab over model
            placed = True
        if plan.fsdp:
            full = dpa if tp in dpa else tuple(dpa) + (tp,)
            for axes in ((dpa,) if placed else (full, dpa)):
                if _maybe(axes, shape[-1], mesh):
                    spec[-1] = axes
                    break
        return P(*spec)
    if parent == "lm_head":
        return build(out_axis=-1, model_ok=True, fsdp_axis=-2)

    # Shared experts are plain dense MLPs: shard d_ff over model like any MLP.
    # (Treating them as stacked-expert tensors would shard the layer-stack axis,
    # which XLA then all-gathers wholesale outside the scan — 7.5 GiB/device on
    # llama4 decode, EXPERIMENTS.md §Perf.)
    moe = "moe" in names and parent in ("up", "gate", "down") and "shared" not in names
    if moe:
        if plan.ep_axis is not None:
            # Dedicated expert axis (§3.13): EVERY stacked expert leaf — weights
            # AND their quantization metadata (sw/bcol/qalpha) and packed sparsity
            # masks — shards its E dim over the expert axis, so each ep shard holds
            # whole experts with their scales co-located (no per-step reshard, and
            # the int32 expert GEMM never crosses shards → bitwise). Expert-internal
            # dims stay whole in this mode; the router was replicated above.
            e_dim = 1 if names[0] == "blocks" else 0
            spec = [None] * nd
            if nd > e_dim and _maybe(plan.ep_axis, shape[e_dim], mesh):
                spec[e_dim] = plan.ep_axis
            return P(*spec)
        if nd < 3 or leaf not in ("w", "qw", "qw4"):
            # prepared-tree scale vectors ((L, E, d_out) sw etc.): replicate — tiny
            return P(*([None] * nd))
        if plan.moe_mode == "ep":
            spec = [None] * nd
            spec[-3] = tp                                    # experts over model
            if plan.fsdp and _maybe(dpa, shape[-2], mesh):
                spec[-2] = dpa
            return P(*spec)
        if plan.moe_mode == "expert_tp":
            ax = -1 if parent in ("up", "gate") else -2      # shard d_ff_expert
            return build(out_axis=ax, model_ok=True, fsdp_axis=(-2 if ax == -1 else -1))
        return build(out_axis=None, model_ok=False, fsdp_axis=-2)

    attn_ok = plan.tier in ("tp_full", "tp_kv_rep")
    table = {
        "wq":  (-1, attn_ok, -2),
        "wk":  (-1, plan.tier == "tp_full", -2),
        "wv":  (-1, plan.tier == "tp_full", -2),
        "wo":  (-2, attn_ok, -1),
        "up":   (-1, True, -2),
        "gate": (-1, True, -2),
        "down": (-2, True, -1),
        "in_proj":  (-1, True, -2),
        "out_proj": (-2, True, -1),
        "proj": (-1, True, -2),                              # frontend stub
    }
    if parent in table and leaf in ("w", "qw", "qw4"):
        ax, ok, fa = table[parent]
        return build(out_axis=ax, model_ok=ok, fsdp_axis=fa)
    if parent in table and leaf == "sw":
        # Dequant scale vector(s) follow the weight's model-axis split. Column-
        # parallel (d_out last on the weight): shard sw's d_out. Row-parallel int4
        # (d_in sharded): sw is (..., G, d_out) with G = d_in/group — shard the
        # group axis, which stays aligned with the weight's d_in shard exactly when
        # tp divides G (whole groups per shard). Anything else replicates. The
        # group axis only exists when the per-layer rank is 2: a scanned int8 sw is
        # (n_blocks, d_out) — its leading dim is the layer-stack axis, which must
        # never shard (XLA all-gathers the whole stack outside the scan otherwise).
        ax, ok, _ = table[parent]
        rank = nd - (1 if names[0] == "blocks" else 0)
        if ok and ax == -1 and _maybe(tp, shape[-1], mesh):
            return P(*([None] * (nd - 1) + [tp]))
        if ok and ax == -2 and rank == 2 and _maybe(tp, shape[-2], mesh):
            return P(*([None] * (nd - 2) + [tp, None]))
        return P(*([None] * nd))
    if parent in table and leaf == "bcol":
        # Per-input-channel b = c^(1-α) divides the activation before the GEMM:
        # shard along d_in exactly when the weight is row-parallel (its d_in is the
        # model-sharded contraction dim), so the act-quantize divide runs on the
        # shard each device already holds.
        ax, ok, _ = table[parent]
        if ok and ax == -2 and _maybe(tp, shape[-1], mesh):
            return P(*([None] * (nd - 1) + [tp]))
        return P(*([None] * nd))
    if parent in table and leaf == "mask":
        # Bit-packed N:M keep-mask (packed along d_in — §3.12): rides its weight's
        # model-axis split. Column-parallel: shard d_out (last axis, unpacked).
        # Row-parallel: the shard would land on the *packed* axis — allowed only at
        # byte granularity (same contract as packed int4 qw4), so tp must divide
        # d_in//8; otherwise replicate — the mask is metadata the kernel wrapper
        # gathers anyway, so replication costs capacity, never correctness.
        ax, ok, _ = table[parent]
        if ok and ax == -1 and _maybe(tp, shape[-1], mesh):
            return P(*([None] * (nd - 1) + [tp]))
        if ok and ax == -2 and _maybe(tp, shape[-2], mesh):
            return P(*([None] * (nd - 2) + [tp, None]))
        return P(*([None] * nd))
    # qalpha (effective-alpha scalar, leading stack dims only) and anything else
    # unrecognized: replicate
    return P(*([None] * nd))


def param_shardings(param_tree, cfg: ModelConfig, plan: Plan, mesh: Mesh):
    """param_tree: pytree of arrays or ShapeDtypeStructs → pytree of NamedSharding."""
    def one(path, leaf):
        spec = _param_spec(_path_str(path), leaf.shape, cfg, plan, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, param_tree)


# ======================================================================================
# Batch / cache shardings
# ======================================================================================

def batch_shardings(batch_tree, plan: Plan, mesh: Mesh):
    def one(path, leaf):
        spec: list = [None] * len(leaf.shape)
        if leaf.shape and _maybe(plan.dp_axes, leaf.shape[0], mesh):
            spec[0] = plan.dp_axes
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_shardings(cache_tree, cfg: ModelConfig, plan: Plan, mesh: Mesh):
    """KV caches (B,T,Hkv,D) [+ leading n_blocks when stacked]: B→dp, T→model (decode).
    int8 KV per-token scale leaves (``k_scale``/``v_scale``, (B,T,Hkv,1)) carry the
    same (B→dp, T→model) split as the codes they dequantize — a slot's scale row
    must live with its code row or every decode-step scatter pays a reshard.
    Paged pools (``*_pages``, (P,ps,Hkv,D|1) — DESIGN.md §3.8): physical page
    axis→dp, kv heads→model when divisible (there is no contiguous T axis to
    sequence-shard; capacity scales with the dp-split page axis instead), with
    the int8 scale pools following their code pools; the ``page_table`` and any
    unrecognized leaf replicate. These placements govern *storage*: at the
    decode step the paged kernel consumes code and scale pools alike as
    operands of one ``hints.manual_kernel`` region (gathered at that boundary),
    so scale pools sharding differently from their codes would only add a
    reshard — following the code pools keeps scatter and gather symmetric.
    SSM caches: B→dp, heads→model when divisible."""
    def one(path, leaf):
        pathstr = _path_str(path)
        names = pathstr.split("/")
        stacked = "tail" not in names
        nd = len(leaf.shape)
        off = 1 if stacked else 0
        spec: list = [None] * nd
        last = names[-1]
        if last in ("k_pages", "v_pages", "k_scale_pages", "v_scale_pages"):
            if _maybe(plan.dp_axes, leaf.shape[off + 0], mesh):
                spec[off + 0] = plan.dp_axes
            if _maybe(plan.tp_axis, leaf.shape[off + 2], mesh):
                spec[off + 2] = plan.tp_axis
        elif last in ("k", "v", "k_scale", "v_scale"):
            if _maybe(plan.dp_axes, leaf.shape[off + 0], mesh):
                spec[off + 0] = plan.dp_axes
            if plan.seq_shard_kv and _maybe(plan.tp_axis, leaf.shape[off + 1], mesh):
                spec[off + 1] = plan.tp_axis
        elif last == "state_pages":                  # (P, H, Pd, N) — §3.13
            if _maybe(plan.dp_axes, leaf.shape[off + 0], mesh):
                spec[off + 0] = plan.dp_axes
            if _maybe(plan.tp_axis, leaf.shape[off + 1], mesh):
                spec[off + 1] = plan.tp_axis
        elif last == "conv_pages":                   # (P, K-1, C)
            if _maybe(plan.dp_axes, leaf.shape[off + 0], mesh):
                spec[off + 0] = plan.dp_axes
        elif last == "state":                        # (B, H, P, N)
            if _maybe(plan.dp_axes, leaf.shape[off + 0], mesh):
                spec[off + 0] = plan.dp_axes
            if _maybe(plan.tp_axis, leaf.shape[off + 1], mesh):
                spec[off + 1] = plan.tp_axis
        elif last == "conv":                         # (B, K-1, C)
            if _maybe(plan.dp_axes, leaf.shape[off + 0], mesh):
                spec[off + 0] = plan.dp_axes
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))), tree)
