"""Modality frontend STUBS (per the assignment: ``[audio]``/``[vlm]`` entries specify
the transformer backbone only; ``input_specs()`` provides precomputed frame/patch
embeddings). The stubs are linear projections from precomputed features into d_model."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qlinear as ql
from repro.configs.base import ModelConfig


def init_frontend(key, cfg: ModelConfig) -> dict:
    if cfg.frontend == "none":
        return {}
    return {"proj": ql.init(key, cfg.frontend_dim, cfg.d_model)}


def vision_stub_apply(params: dict, tokens_embed: jax.Array, patch_embeds: jax.Array,
                      cfg: ModelConfig) -> jax.Array:
    """Prepend projected patch embeddings: sequence = [patches | text]."""
    patches = (patch_embeds @ params["proj"]["w"].astype(patch_embeds.dtype))
    return jnp.concatenate(
        [patches.astype(tokens_embed.dtype), tokens_embed[:, cfg.n_patches:]], axis=1)


def audio_stub_apply(params: dict, frames: jax.Array) -> jax.Array:
    """Project precomputed acoustic frame features to the backbone width."""
    return frames @ params["proj"]["w"].astype(frames.dtype)
