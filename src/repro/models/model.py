"""Unified language model covering all 10 assigned architectures.

The layer stack is expressed as a *block spec*: a static list of sublayer kinds that is
repeated ``n_blocks`` times and executed with ``lax.scan`` over stacked parameters
(small HLO, cheap remat). Heterogeneous stacks map onto this:

  dense global        -> [attn] × L
  gemma2 alternating  -> [attn_local, attn_global] × L/2
  moe                 -> [attn+moe] × L
  mamba2              -> [ssm] × L
  zamba2 hybrid       -> ([ssm] × attn_every + shared-attn) × L//k  (+ ssm tail),
                         shared attention/MLP params are closed over (weight sharing)

Modes: ``train`` (full logits), ``prefill`` (writes caches, last-position logits),
``decode`` (one token against caches). An ``unroll`` python-loop path supports eager
calibration (observers cannot run under scan).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qlinear as ql
from repro.models import frontends, moe as moe_lib, ssm as ssm_lib, state as state_lib
from repro.sharding import hints
from repro.models.layers import (
    QuantContext, attention_apply, init_attention, init_mlp, init_norm, mlp_apply,
    norm_apply,
)


# ======================================================================================
# Block spec
# ======================================================================================

@dataclasses.dataclass(frozen=True)
class BlockSpec:
    sublayers: Tuple[str, ...]       # attn | attn_local | attn_moe | ssm
    n_blocks: int
    tail: Tuple[str, ...] = ()       # unscanned remainder layers (hybrid)
    shared_attn: bool = False        # zamba2: shared block applied after each super-block


def block_spec(cfg: ModelConfig) -> BlockSpec:
    L = cfg.n_layers
    if cfg.family in ("dense", "vlm", "audio"):
        if cfg.layer_pattern == "local_global":
            assert L % 2 == 0
            return BlockSpec(("attn_local", "attn"), L // 2)
        return BlockSpec(("attn",), L)
    if cfg.family == "moe":
        return BlockSpec(("attn_moe",), L)
    if cfg.family == "ssm":
        return BlockSpec(("ssm",), L)
    if cfg.family == "hybrid":
        k = cfg.attn_every
        return BlockSpec(("ssm",) * k, L // k, tail=("ssm",) * (L % k), shared_attn=True)
    raise ValueError(cfg.family)


# ======================================================================================
# Init
# ======================================================================================

def _init_sublayer(key, kind: str, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"norm": init_norm(cfg), "ssm": ssm_lib.init_mamba(ks[0], cfg)}
    p = {"norm1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
         "norm2": init_norm(cfg)}
    if kind == "attn_moe":
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.float32
    spec = block_spec(cfg)
    ks = jax.random.split(key, 8)

    def stack(base_key, kind):
        keys = jax.random.split(base_key, spec.n_blocks)
        return jax.vmap(lambda k: _init_sublayer(k, kind, cfg))(keys)

    params: Dict[str, Any] = {
        "embed": {"w": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model)) * 0.02)},
        "blocks": [stack(jax.random.fold_in(ks[1], i), kind)
                   for i, kind in enumerate(spec.sublayers)],
        "final_norm": init_norm(cfg),
    }
    if spec.tail:
        params["tail"] = [_init_sublayer(jax.random.fold_in(ks[2], i), kind, cfg)
                          for i, kind in enumerate(spec.tail)]
    if spec.shared_attn:
        params["shared_attn"] = {
            "norm1": init_norm(cfg),
            "attn": init_attention(ks[3], cfg),
            "norm2": init_norm(cfg),
            "mlp": init_mlp(ks[4], cfg),
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = ql.init(ks[5], cfg.d_model, cfg.vocab_padded)
    if cfg.frontend != "none":
        params["frontend"] = frontends.init_frontend(ks[6], cfg)
    params = jax.tree_util.tree_map(lambda x: x.astype(dtype)
                                    if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    return params


# ======================================================================================
# Sublayer application
# ======================================================================================

def _apply_sublayer(kind: str, p: dict, x, cfg: ModelConfig, ctx: QuantContext, *,
                    cache=None, cur_len=None, decode=False, page_table=None,
                    prefix_len=None, q_len=None, chunk=None, state_table=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h, new_cache = ssm_lib.mamba_apply(p["ssm"], norm_apply(p["norm"], x, cfg), cfg,
                                           ctx.sub("ssm"), cache=cache, decode=decode,
                                           cur_len=cur_len, state_table=state_table)
        return x + h, new_cache, aux
    local = kind == "attn_local"
    h, new_cache = attention_apply(p["attn"], norm_apply(p["norm1"], x, cfg), cfg,
                                   ctx.sub("attn"), local=local, cache=cache,
                                   cur_len=cur_len, page_table=page_table,
                                   prefix_len=prefix_len, q_len=q_len, chunk=chunk)
    x = x + h
    if kind == "attn_moe":
        h, aux = moe_lib.moe_apply(p["moe"], norm_apply(p["norm2"], x, cfg), cfg,
                                   ctx.sub("moe"))
    else:
        h = mlp_apply(p["mlp"], norm_apply(p["norm2"], x, cfg), cfg, ctx.sub("mlp"))
    return x + h, new_cache, aux


def _shared_block(p: dict, x, cfg: ModelConfig, ctx: QuantContext, *,
                  cache=None, cur_len=None, page_table=None, prefix_len=None):
    h, new_cache = attention_apply(p["attn"], norm_apply(p["norm1"], x, cfg), cfg,
                                   ctx.sub("shared_attn"), cache=cache, cur_len=cur_len,
                                   page_table=page_table, prefix_len=prefix_len)
    x = x + h
    x = x + mlp_apply(p["mlp"], norm_apply(p["norm2"], x, cfg), cfg, ctx.sub("shared_mlp"))
    return x, new_cache


# ======================================================================================
# Cache construction
# ======================================================================================

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=jnp.bfloat16,
               *, kv_int8: bool = False, layout: str = "dense",
               page_size: int = 16, n_pages: Optional[int] = None) -> dict:
    """Pytree of per-layer caches, stacked (n_blocks, ...) to be scanned.

    ``layout="dense"`` (default): the batch axis is a *slot table* (DESIGN.md
    §3.6): each of the ``batch_size`` rows holds one in-flight sequence at its
    own length (``cur_len`` vector), so a continuous batcher can retire and
    refill individual slots without touching the others
    (serving/engine.py::_slot_scatter does the per-slot cache writes).

    ``layout="paged"`` (DESIGN.md §3.8/§3.13): instead of a dense
    ``(B, max_len)`` row per slot, every layer holds a physical pool built by
    its :mod:`repro.models.state` StateSpec and slots address it through
    top-level routing tables — ``page_table`` (batch_size, max_len//page_size)
    int32 for token-paged attention KV, ``state_table`` (batch_size,) int32 for
    fixed-size SSM state checkpoints (recurrent-state slab + pre-conv window,
    one page per slot regardless of length). Entry value ``n_pages`` is the
    *invalid* sentinel in both tables (reads clamp, the scatter drops).
    ``n_pages`` defaults to the dense-equivalent capacity
    ``batch_size * max_len / page_size``; serving engines pass less and rely
    on prefix sharing. Both table kinds draw ids from the same ref-counted
    pool (serving/paging.py), so a hybrid slot's KV pages and state page
    retire together.

    ``kv_int8=True`` stores attention K/V as int8 codes plus per-token f32 scales
    (layers.kv_quantize) — ~2×/4× less decode HBM traffic vs bf16/f32 caches
    (DESIGN.md §3.3). SSM recurrence state always stays f32.
    """
    spec = block_spec(cfg)
    has_kv, has_state = state_lib.family_flags(spec)
    stack = lambda tree: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (spec.n_blocks,) + x.shape), tree)
    if layout == "paged":
        if max_len % page_size:
            raise ValueError(f"page_size {page_size} must divide "
                             f"max_len {max_len}")
        n_pages = n_pages or batch_size * (max_len // page_size)

        def one_paged(kind):
            return state_lib.spec_for(kind).paged_leaves(
                cfg, n_pages, page_size, dtype, kv_int8)

        cache: Dict[str, Any] = {
            "blocks": [stack(one_paged(kind)) for kind in spec.sublayers]}
        if spec.tail:
            cache["tail"] = [one_paged(k) for k in spec.tail]
        if spec.shared_attn:
            cache["shared"] = stack(one_paged("attn"))
        if has_kv:
            cache["page_table"] = jnp.full(
                (batch_size, max_len // page_size), n_pages, jnp.int32)
        if has_state:
            cache["state_table"] = jnp.full((batch_size,), n_pages, jnp.int32)
        return cache
    if layout != "dense":
        raise ValueError(f"unknown cache layout {layout!r}")

    def one(kind):
        return state_lib.spec_for(kind).dense_leaves(
            cfg, batch_size, max_len, dtype, kv_int8)

    cache = {"blocks": [stack(one(kind)) for kind in spec.sublayers]}
    if spec.tail:
        cache["tail"] = [one(k) for k in spec.tail]
    if spec.shared_attn:
        cache["shared"] = stack(one("attn"))
    return cache


# ======================================================================================
# Forward
# ======================================================================================

def _embed(params, batch, cfg: ModelConfig):
    if cfg.frontend == "audio_stub":
        x = frontends.audio_stub_apply(params["frontend"], batch["frames"])
    else:
        x = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            # prefill carries patch embeddings; decode steps are text-token-only
            x = frontends.vision_stub_apply(params["frontend"], x,
                                            batch["patch_embeds"], cfg)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = hints.constrain_batch(x)
    return x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)


def _lm_head(params, x, cfg: ModelConfig, ctx: QuantContext):
    """Returns logits over cfg.vocab_padded; padded ids carry -1e9 (never sampled,
    ~zero softmax mass) so callers can treat the padded width as the vocabulary."""
    x = norm_apply(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["w"].T.astype(x.dtype)
    else:
        logits = ctx.linear(params["lm_head"], x, "lm_head")
    logits = logits.astype(jnp.float32)
    # vocab_padded divides every production TP degree by construction: pin the
    # logits' padded-vocab dim to the model axis (and batch to dp) so the softcap /
    # pad-mask / sampling ops below run sharded instead of replicating a (B, S, V)
    # stack per device. No-op without sharding hints.
    logits = hints.constrain_vocab(logits)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab) * -1e9
        logits = logits + pad_mask
    return logits


def apply(
    params: dict, batch: dict, cfg: ModelConfig, *,
    ctx: Optional[QuantContext] = None, mode: str = "train",
    caches: Optional[dict] = None, cur_len: Optional[jax.Array] = None,
    prefix_len: Optional[jax.Array] = None, q_len: Optional[jax.Array] = None,
    chunk: Optional[dict] = None,
    unroll: bool = False, remat: bool = False,
) -> Tuple[jax.Array, dict]:
    """Returns (logits, {"aux_loss": scalar, "caches": updated-or-None}).

    mode: train (no caches) | prefill (build caches) | decode (read+update caches)
    | verify (speculative draft window, DESIGN.md §3.9).

    ``mode="verify"``: tokens (B, W) are a speculative draft window — column 0
    the pending token, the rest drafted continuations. All W tokens scatter
    into the caches at positions ``cur_len - q_len + i`` (``q_len`` (B,) valid
    window rows; invalid rows drop) and every window position's logits return
    (B, W, V) so the engine can greedily accept the longest matching prefix.
    ``cur_len`` is the per-slot *total* post-scatter length. Attention-only
    families — the SSM recurrence cannot rewind rejected tokens.

    ``cur_len`` may be a scalar (all slots aligned) or a per-slot (B,) int32 vector
    (DESIGN.md §3.6). Prefill: tokens are right-padded, positions start at 0, and
    ``cur_len`` holds per-slot prompt lengths — the returned logits are taken at
    each slot's own last valid position. Decode: ``cur_len`` is the per-slot
    post-append length; the token scatters into cache position ``cur_len - 1``.

    Paged caches (``init_cache(layout="paged")``, DESIGN.md §3.8) carry their
    ``page_table`` inside the cache pytree; it is threaded to every attention
    layer unchanged (the serving engine owns its contents). ``prefix_len`` (B,)
    marks prefill batches whose slots already hold a shared prefix of that many
    tokens in their pages: the batch tokens are the *suffix*, positions start at
    ``prefix_len[b]``, and ``cur_len`` counts suffix tokens only.

    ``mode="chunked"`` (DESIGN.md §3.10): tokens (1, Nt) are a *packed ragged
    token row* mixing many slots' work — single decode tokens, page-aligned
    prefill chunks, cold admissions — served in one launch against a paged
    cache. ``chunk`` carries per-slot extents (``q_start``/``q_len``/``kv_len``
    (B,)) and per-token routing (``positions``/``slot_ids`` (Nt,)); logits
    return for every packed row (1, Nt, V) and the engine gathers each slot's
    last valid row. Attention-only families, paged caches only.
    """
    ctx = ctx or QuantContext(cfg.quant)
    spec = block_spec(cfg)
    decode = mode == "decode"
    verify = mode == "verify"
    chunked = mode == "chunked"
    if verify and q_len is None:
        raise ValueError("mode='verify' needs q_len (per-slot valid window rows)")
    if verify and cfg.family in ("ssm", "hybrid"):
        raise ValueError(f"speculative verify needs attention-only caches; "
                         f"family {cfg.family!r} carries SSM state")
    if q_len is not None and not verify:
        raise ValueError("q_len is only meaningful under mode='verify'")
    if chunked and chunk is None:
        raise ValueError("mode='chunked' needs a chunk dict (per-slot extents "
                         "+ per-token routing, DESIGN.md §3.10)")
    if chunk is not None and not chunked:
        raise ValueError("chunk is only meaningful under mode='chunked'")
    if chunked and cfg.family in ("ssm", "hybrid"):
        raise ValueError(f"chunked serving needs attention-only caches; "
                         f"family {cfg.family!r} carries SSM state")
    x = _embed(params, batch, cfg)
    aux_total = jnp.zeros((), jnp.float32)

    use_cache = mode in ("prefill", "decode", "verify", "chunked")
    if use_cache and caches is None:
        raise ValueError("prefill/decode/verify need caches (init_cache)")
    page_table = caches.get("page_table") if use_cache else None
    state_table = caches.get("state_table") if use_cache else None
    if prefix_len is not None and page_table is None:
        raise ValueError("prefix_len needs a paged cache (its page_table routes "
                         "the shared prefix)")

    def block_fn(x, block_params, block_caches, shared_cache, cur_len, bctx=None):
        bctx = bctx or ctx
        x = hints.constrain_batch(x)
        aux_sum = jnp.zeros((), jnp.float32)
        new_caches: List[Any] = []
        for i, kind in enumerate(spec.sublayers):
            c = block_caches[i] if use_cache else None
            x, nc, aux = _apply_sublayer(kind, block_params[i], x, cfg,
                                         bctx.sub(f"S{i}"),
                                         cache=c, cur_len=cur_len, decode=decode,
                                         page_table=page_table,
                                         prefix_len=prefix_len, q_len=q_len,
                                         chunk=chunk, state_table=state_table)
            aux_sum += aux
            new_caches.append(nc if nc is not None else c)
        new_shared = shared_cache
        if spec.shared_attn:
            x, new_shared = _shared_block(params["shared_attn"], x, cfg, ctx,
                                          cache=shared_cache, cur_len=cur_len,
                                          page_table=page_table,
                                          prefix_len=prefix_len)
        return x, new_caches, new_shared, aux_sum

    if unroll:
        take = lambda tree, i: jax.tree_util.tree_map(lambda a: a[i], tree)
        for b in range(spec.n_blocks):
            bp = [take(params["blocks"][i], b) for i in range(len(spec.sublayers))]
            bc = ([take(caches["blocks"][i], b) for i in range(len(spec.sublayers))]
                  if use_cache else [None] * len(spec.sublayers))
            sc = take(caches["shared"], b) if (use_cache and spec.shared_attn) else None
            # Per-layer ctx prefix: calibration observers record per-layer column
            # stats under names calibration.stack_tables maps back to param paths.
            x, _, _, aux = block_fn(x, bp, bc, sc, cur_len, bctx=ctx.sub(f"L{b}"))
            aux_total += aux
    else:
        def scan_body(carry, xs):
            x, aux_acc = carry
            bp = xs["p"]
            bc = xs.get("c", [None] * len(spec.sublayers))
            sc = xs.get("s")
            x, ncs, nsc, aux = block_fn(x, bp, bc, sc, cur_len)
            ys = {}
            if use_cache:
                ys["c"] = ncs
                if spec.shared_attn:
                    ys["s"] = nsc
            return (x, aux_acc + aux), ys

        body = jax.checkpoint(scan_body, policy=None) if remat else scan_body
        xs: Dict[str, Any] = {"p": params["blocks"]}
        if use_cache:
            xs["c"] = caches["blocks"]
            if spec.shared_attn:
                xs["s"] = caches["shared"]
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
        if use_cache:
            caches = dict(caches)
            caches["blocks"] = ys["c"]
            if spec.shared_attn:
                caches["shared"] = ys["s"]

    # hybrid tail (unscanned remainder layers)
    if spec.tail:
        new_tail = []
        for i, kind in enumerate(spec.tail):
            c = caches["tail"][i] if use_cache else None
            x, nc, aux = _apply_sublayer(kind, params["tail"][i], x, cfg,
                                         ctx.sub(f"T{i}"),
                                         cache=c, cur_len=cur_len, decode=decode,
                                         state_table=state_table)
            aux_total += aux
            new_tail.append(nc if nc is not None else c)
        if use_cache:
            caches["tail"] = new_tail

    if mode == "prefill":
        if cur_len is None:
            x = x[:, -1:]
        else:
            # per-slot last valid position (right-padded prompts, §3.6)
            last = jnp.reshape(jnp.asarray(cur_len, jnp.int32), (-1,)) - 1
            last = jnp.clip(last, 0, x.shape[1] - 1)
            idx = jnp.broadcast_to(last[:, None, None], (x.shape[0], 1, x.shape[2]))
            x = jnp.take_along_axis(x, idx, axis=1)
        logits = _lm_head(params, x, cfg, ctx)
    else:
        logits = _lm_head(params, x, cfg, ctx)
    return logits, {"aux_loss": aux_total, "caches": caches if use_cache else None}


# ======================================================================================
# Loss
# ======================================================================================

def loss_fn(params, batch, cfg: ModelConfig, *, ctx=None, remat: bool = True):
    """Causal-LM (or encoder classification) cross entropy + MoE aux loss."""
    logits, extras = apply(params, batch, cfg, ctx=ctx, mode="train", remat=remat)
    if cfg.is_encoder_only:
        labels = batch["labels"]
        mask = jnp.ones(labels.shape, jnp.float32)
    else:
        tokens = batch["tokens"]
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        mask = jnp.ones(labels.shape, jnp.float32).at[:, -1].set(0.0)
        if cfg.frontend == "vision_stub":
            mask = mask.at[:, : cfg.n_patches].set(0.0)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce + 0.01 * extras["aux_loss"]
    return loss, {"ce": ce, "aux": extras["aux_loss"],
                  "ppl": jnp.exp(jnp.minimum(ce, 20.0))}
