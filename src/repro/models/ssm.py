"""Mamba2 (state-space duality / SSD) block — arXiv:2405.21060.

Implements the chunked SSD algorithm as a single `lax.scan` over sequence chunks
(carry = inter-chunk SSM state), which keeps peak memory at O(chunk²) instead of
O(S²) or O(S·N·H): the formulation long-context prefill needs, and the direct jnp
oracle for the Pallas `ssd` kernel.

Per chunk (length l, heads h, head dim p, state n; decay dA = dt·A ≤ 0):
  L[i,j]      = exp(Σ_{k=j+1..i} dA_k)              intra-chunk decay (lower-tri)
  y_diag      = (C·Bᵀ ⊙ L) · (dt·x)                 intra-chunk "attention"
  y_off       = C · S_prev, decayed by exp(cum dA)  contribution of carried state
  S_new       = S_prev·exp(Σ dA) + Σ_s B_s ⊗ (dt·x)_s · exp(Σ_{k>s} dA_k)

Decode is the O(1) recurrence  S ← S·exp(dt·A) + dt·x⊗B,  y = C·S + D·x.

All projections are quantized linears (CrossQuant applies to the in/out projections;
the recurrence itself stays fp — DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qlinear as ql
from repro.configs.base import ModelConfig
from repro.models.layers import QuantContext
from repro.sharding import hints


def _conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba(key, cfg: ModelConfig) -> dict:
    d, di, H = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    G, N, K = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * G * N + H        # z, x, B, C, dt
    dt = jnp.exp(jax.random.uniform(ks[2], (H,)) * (jnp.log(0.1) - jnp.log(0.001))
                 + jnp.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": ql.init(ks[0], d, proj_out),
        "conv_w": (jax.random.normal(ks[1], (K, _conv_channels(cfg))) * 0.1).astype(jnp.float32),
        "conv_b": jnp.zeros((_conv_channels(cfg),), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": ql.init(ks[3], di, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d via shift-sum (K is tiny). x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for k in range(K):
        shift = K - 1 - k
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[k]
    return out + b


def _conv_step(x_t: jax.Array, buf: jax.Array, w: jax.Array, b: jax.Array):
    """One-token causal conv with rolling buffer. x_t: (B,C); buf: (B,K-1,C)."""
    window = jnp.concatenate([buf, x_t[:, None]], axis=1)          # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:]


def _split_proj(proj: jax.Array, cfg: ModelConfig):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * G * N], axis=-1)
    return z, xbc, dt                                              # dt: (..., H)


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (B, l, H) -> (B, H, l, l) with T[i,j] = Σ_{k=j+1..i} dA_k (−inf above diag)."""
    cum = jnp.cumsum(dA, axis=1)                                   # (B, l, H)
    T = cum.transpose(0, 2, 1)[:, :, :, None] - cum.transpose(0, 2, 1)[:, :, None, :]
    l = dA.shape[1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, T, -jnp.inf)


def ssd_scan(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    chunk: int, init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N) (G=1 squeezed).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    S0 = S
    pad = (-S) % chunk
    if pad:
        # Pad the sequence to a chunk multiple. dt is padded with zeros so padded
        # positions neither decay nor update the carried state (dA = dt·A = 0 →
        # decay 1, update dt·x = 0): the final state stays exact for prefill.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3).astype(jnp.float32)

    state0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def step(state, inp):
        xb, dtb, Bb, Cb = inp                                      # per-chunk slices
        dA = dtb * A                                               # (B,l,H), ≤ 0
        cum = jnp.cumsum(dA, axis=1)                               # (B,l,H)
        xdt = xb * dtb[..., None]                                  # (B,l,H,P)

        L = jnp.exp(_segsum(dA))                                   # (B,H,l,l)
        scores = jnp.einsum("bln,bsn->bls", Cb, Bb)                # (B,l,l)
        y_diag = jnp.einsum("bls,bhls,bshp->blhp", scores, L, xdt)

        decay_out = jnp.exp(cum)                                   # (B,l,H)
        y_off = jnp.einsum("bln,bhpn,blh->blhp", Cb, state, decay_out)

        chunk_decay = jnp.exp(cum[:, -1])                          # (B,H)
        decay_states = jnp.exp(cum[:, -1:] - cum)                  # (B,l,H)
        state_new = state * chunk_decay[:, :, None, None] + jnp.einsum(
            "bln,blhp,blh->bhpn", Bb, xdt, decay_states)
        return state_new, y_diag + y_off

    final_state, ys = jax.lax.scan(step, state0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)[:, :S0]
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
    Bm: jax.Array, Cm: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """O(1) recurrence. state: (B,H,P,N); x: (B,H,P); dt: (B,H); Bm/Cm: (B,N)."""
    dA = jnp.exp(dt * A)                                           # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x * dt[..., None], Bm)
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    return state, y


def mamba_apply(
    params: dict, x: jax.Array, cfg: ModelConfig, ctx: QuantContext, *,
    cache: Optional[dict] = None, decode: bool = False,
    cur_len: Optional[jax.Array] = None, state_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba2 block. x: (B,S,d). cache = {"state": (B,H,P,N), "conv": (B,K-1,C)}
    for the dense layout, or {"state_pages": (nP,H,P,N), "conv_pages": (nP,K-1,C)}
    pools routed through ``state_table`` (B,) int32 for the paged layout (the
    sentinel id ``nP`` gathers a clamped page and drops the scatter — retired
    slots neither read nor write state).

    ``cur_len`` (B,) marks each row's valid prompt length on a right-padded
    prefill: dt is masked to 0 at padded positions, so (per the ssd_scan pad
    note) they neither decay nor update the carried state — the final state is
    exactly the exact-length state, which is what lets the continuous batcher
    admit SSM rows through the same length-bucketed padded prefill as attention.
    """
    Bsz, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    A = -jnp.exp(params["A_log"])

    paged = cache is not None and "state_pages" in cache
    pools = None
    if paged:
        if state_table is None:
            raise ValueError("paged SSM cache needs a state_table")
        pools = cache
        nP = pools["state_pages"].shape[0]
        tbl = jnp.reshape(state_table, (-1,)).astype(jnp.int32)
        safe = jnp.clip(tbl, 0, nP - 1)
        cache = {"state": pools["state_pages"][safe],
                 "conv": pools["conv_pages"][safe]}

    proj = ctx.linear(params["in_proj"], x, "in_proj")
    z, xbc, dt_raw = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    if decode:
        assert S == 1 and cache is not None
        xbc_t, conv_buf = _conv_step(xbc[:, 0].astype(jnp.float32),
                                     cache["conv"], params["conv_w"], params["conv_b"])
        xbc_t = jax.nn.silu(xbc_t)
        xi, Bm, Cm = jnp.split(xbc_t, [cfg.d_inner, cfg.d_inner + N], axis=-1)
        state, y = ssd_decode_step(
            cache["state"], xi.reshape(Bsz, H, P), dt[:, 0], A, Bm, Cm)
        y = y + params["D"][:, None] * xi.reshape(Bsz, H, P)
        y = y.reshape(Bsz, 1, cfg.d_inner)
        new_cache = {"state": state, "conv": conv_buf}
    else:
        cur = None
        if cur_len is not None:
            cur = jnp.broadcast_to(
                jnp.reshape(cur_len, (-1,)).astype(jnp.int32), (Bsz,))
            # Padded positions must not touch the carried state: dt = 0 there
            # makes them decay-1 / update-0 no-ops (see ssd_scan's pad note),
            # and the causal conv never reads rightward, so every valid
            # position's output and the final state match exact-length prefill.
            dt = jnp.where(jnp.arange(S)[None, :, None] < cur[:, None, None],
                           dt, 0.0)
        xbc_raw = xbc.astype(jnp.float32)          # cache keeps PRE-conv inputs
        xbc = jax.nn.silu(_causal_conv(xbc_raw, params["conv_w"], params["conv_b"]))
        xi, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + N], axis=-1)
        xh = xi.reshape(Bsz, S, H, P)
        # Paged prefill is always a fresh admission (prefix reuse is rejected for
        # SSM state): start from zero state — the gathered page may still hold a
        # retired sequence's checkpoint.
        init_state = (None if paged
                      else (cache["state"] if cache is not None else None))
        y, final_state = ssd_scan(xh, dt, A, Bm, Cm, min(cfg.ssm_chunk, S),
                                  init_state=init_state)
        y = y + params["D"][None, None, :, None] * xh
        y = y.reshape(Bsz, S, cfg.d_inner)
        new_cache = None
        if cache is not None:
            K = cfg.ssm_conv
            if cur is None:
                conv_buf = xbc_raw[:, -(K - 1):] if S >= K - 1 else jnp.pad(
                    xbc_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
            else:
                # Last K-1 *valid* pre-conv inputs per row (left-padded with
                # zeros for prompts shorter than the window, matching the
                # dense branch's jnp.pad semantics).
                idx = cur[:, None] - (K - 1) + jnp.arange(K - 1)[None, :]
                gathered = jnp.take_along_axis(
                    xbc_raw, jnp.clip(idx, 0, S - 1)[:, :, None], axis=1)
                conv_buf = jnp.where((idx >= 0)[:, :, None], gathered, 0.0)
            new_cache = {"state": final_state, "conv": conv_buf}

    if paged and new_cache is not None:
        # Scatter each row's refreshed state back into its pool page; rows whose
        # table entry is the sentinel nP index out of range and are dropped.
        new_cache = {
            "state_pages": hints.constrain_state_pages(
                pools["state_pages"].at[tbl].set(
                    new_cache["state"], mode="drop")),
            "conv_pages": hints.constrain_state_pages(
                pools["conv_pages"].at[tbl].set(
                    new_cache["conv"], mode="drop")),
        }

    # gated RMSNorm (mamba2) then output projection
    g = y * jax.nn.silu(z.astype(y.dtype))
    g = g * jax.lax.rsqrt(jnp.mean(jnp.square(g), axis=-1, keepdims=True) + 1e-6)
    g = (g * params["norm_scale"]).astype(x.dtype)
    out = ctx.linear(params["out_proj"], g, "out_proj")
    return out, new_cache
