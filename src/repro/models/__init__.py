"""Unified model zoo for the 10 assigned architectures."""
from repro.models.model import apply, init_params, init_cache, loss_fn, block_spec  # noqa: F401
from repro.models.layers import QuantContext  # noqa: F401
