"""Mixture-of-Experts layer: deterministic top-k routing with capacity, sort-based
dispatch (O(N·k) memory — no (N, E, C) dense dispatch tensors), stacked-expert GEMMs
that shard over the model axis (EP) or within experts (expert-internal TP) per the
sharding planner, and a load-balancing auxiliary loss.

Activation quantization inside experts: CrossQuant column statistics are computed over
the tokens routed to each expert (the (E, C, d) stacked layout keeps eq. 5's row/col
geometry per expert) — DESIGN.md §4.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import qlinear as ql
from repro.configs.base import ModelConfig
from repro.models.layers import QuantContext
from repro.sharding import hints


def init_moe(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dff = cfg.d_ff_expert or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, E)) * d ** -0.5).astype(jnp.float32)},
        "up": ql.init(ks[1], d, dff, n_stack=E),
        "down": ql.init(ks[2], dff, d, n_stack=E),
    }
    if cfg.act.endswith("_glu"):
        p["gate"] = ql.init(ks[3], d, dff, n_stack=E)
    if cfg.n_shared_experts:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)   # round up to 8 for TPU-friendly shapes


def _expert_ffn(p: dict, x: jax.Array, cfg: ModelConfig, ctx: QuantContext) -> jax.Array:
    """x: (E, C, d) stacked per expert. Linear names match the param-tree paths so
    calibration tables attach (calibration.stack_tables)."""
    up = ctx.linear(p["up"], x, "up")
    if cfg.act == "silu_glu":
        h = jax.nn.silu(ctx.linear(p["gate"], x, "gate")) * up
    elif cfg.act == "gelu_glu":
        h = jax.nn.gelu(ctx.linear(p["gate"], x, "gate")) * up
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    return ctx.linear(p["down"], h, "down")


def _route_group(xf: jax.Array, router_w: jax.Array, cfg: ModelConfig):
    """Routing + sort-based slot assignment for one token group.

    xf: (Ng, d). Returns (gate_w (Ng,K), e_idx (Ng*K,), pos (Ng*K,), keep, aux).
    """
    Ng, _ = xf.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(Ng, cfg)

    logits = xf.astype(jnp.float32) @ router_w                       # router stays fp32
    probs = jax.nn.softmax(logits, axis=-1)                          # (Ng, E)
    gate_w, gate_idx = jax.lax.top_k(probs, K)                       # (Ng, K)
    if K > 1:
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing aux loss.
    me = probs.mean(axis=0)
    ce = jnp.zeros(E).at[gate_idx.reshape(-1)].add(1.0) / (Ng * K)
    aux = E * jnp.sum(me * ce)

    # Sort-based position of each (token, k) within its expert; overflow beyond the
    # per-group capacity routes to expert id E, dropped by the scatter's mode="drop".
    flat_e = gate_idx.reshape(-1)                                    # (Ng*K,)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(Ng * K) - starts[flat_e[order]]
    pos = jnp.zeros(Ng * K, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C
    e_idx = jnp.where(keep, flat_e, E).astype(jnp.int32)
    pos_c = jnp.where(keep, pos, 0)
    return gate_w, e_idx, pos_c, keep, aux


def _dispatch_group(xf: jax.Array, gate_w, e_idx, pos_c, keep, cfg: ModelConfig):
    """Scatter one group's tokens into its (E, C, d) expert buffer."""
    Ng, d = xf.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(Ng, cfg)
    token_id = jnp.repeat(jnp.arange(Ng), K)
    expanded = xf[token_id]                                          # (Ng*K, d)
    buf = jnp.zeros((E, C, d), xf.dtype).at[e_idx, pos_c].set(expanded, mode="drop")
    return buf


def _combine_group(expert_out, gate_w, e_idx, pos_c, keep, cfg: ModelConfig, dtype):
    """Gather one group's expert outputs back to token order and mix by gate."""
    E, C, d = expert_out.shape
    K = cfg.top_k
    Ng = e_idx.shape[0] // K
    token_id = jnp.repeat(jnp.arange(Ng), K)
    out_rows = expert_out[jnp.minimum(e_idx, E - 1), pos_c]          # (Ng*K, d)
    gathered = jnp.where(keep[:, None], out_rows, 0.0)
    contrib = gathered * gate_w.reshape(-1)[:, None].astype(dtype)
    return jnp.zeros((Ng, d), dtype).at[token_id].add(contrib)


def moe_apply(
    params: dict, x: jax.Array, cfg: ModelConfig, ctx: QuantContext,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_loss scalar).

    Dispatch is *grouped*: tokens are split into G data-parallel groups, each with
    its own capacity (GShard/Switch "local capacity"). Every gather/scatter then has
    a leading sharded batch dim, which SPMD partitions cleanly — an ungrouped global
    dispatch replicates the (N·K, d) expansion on every device (48 GiB/device on
    granite prefill_32k, EXPERIMENTS.md §Perf). G == data-axis size under the
    launcher's hints; 1 (global dispatch) in tests/eager mode, during calibration
    (observers cannot run under vmap), and in serving steps
    (``sharding_hints(token_groups=False)`` — per-group capacity admits a different
    token-drop set than global dispatch, and the EP serving parity contract is
    bitwise vs single-device, DESIGN.md §3.13).
    """
    B, S, d = x.shape
    N = B * S
    E = cfg.n_experts
    G = 1 if ctx.observer is not None else hints.token_group_count(N)
    xf = x.reshape(N, d)

    if G == 1:
        gate_w, e_idx, pos_c, keep, aux = _route_group(xf, params["router"]["w"], cfg)
        expert_in = hints.constrain_experts(
            _dispatch_group(xf, gate_w, e_idx, pos_c, keep, cfg))
        expert_out = hints.constrain_experts(_expert_ffn(params, expert_in, cfg, ctx))
        y = _combine_group(expert_out, gate_w, e_idx, pos_c, keep, cfg, x.dtype)
    else:
        xg = hints.constrain_token_groups(xf.reshape(G, N // G, d))
        gate_w, e_idx, pos_c, keep, aux_g = jax.vmap(
            lambda xi: _route_group(xi, params["router"]["w"], cfg))(xg)
        aux = aux_g.mean()
        expert_in = jax.vmap(
            lambda xi, gw, ei, pc, kp: _dispatch_group(xi, gw, ei, pc, kp, cfg)
        )(xg, gate_w, e_idx, pos_c, keep)                            # (G, E, C, d)
        expert_in = hints.constrain_grouped_experts(expert_in)
        # Experts see all groups' slots: fold G into capacity for the stacked GEMM.
        C = expert_in.shape[2]
        flat_in = expert_in.transpose(1, 0, 2, 3).reshape(E, G * C, d)
        flat_in = hints.constrain_experts(flat_in)
        flat_out = hints.constrain_experts(_expert_ffn(params, flat_in, cfg, ctx))
        expert_out = hints.constrain_grouped_experts(
            flat_out.reshape(E, G, C, d).transpose(1, 0, 2, 3))
        y = jax.vmap(
            lambda eo, gw, ei, pc, kp: _combine_group(eo, gw, ei, pc, kp, cfg, x.dtype)
        )(expert_out, gate_w, e_idx, pos_c, keep)
        y = hints.constrain_token_groups(y).reshape(N, d)

    if cfg.n_shared_experts:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(params["shared"], xf[None], cfg, ctx)[0]
    return y.reshape(B, S, d), aux
