"""Whole-model weight quantization: walk a params pytree and convert every quantizable
linear to its prepared integer form (int8 static-c CrossQuant or packed int4 groups).

This is the offline PTQ step of a serving deployment: run once, checkpoint the
quantized tree, serve from it. Embeddings, lm_head, router, norms, convs and the SSM
recurrence parameters stay fp (paper scope: activations *entering linear layers*)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlinear as ql

QUANTIZABLE_PARENTS = ("wq", "wk", "wv", "wo", "up", "gate", "down",
                       "in_proj", "out_proj")


def _pathstr(path) -> str:
    out = []
    for p in path:
        out.append(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)))
    return "/".join(out)


def quantize_tree(params, cfg: ql.QuantConfig,
                  tables: Optional[Dict[str, np.ndarray]] = None):
    """Returns a new params pytree with prepared quantized linears.

    tables: calibration column-absmax per linear name (core.calibration.Observer);
    missing names fall back to c=1 (pure per-token row scaling)."""
    tables = tables or {}

    def convert(node, prefix):
        if isinstance(node, dict):
            if "w" in node and prefix and prefix.split("/")[-1] in QUANTIZABLE_PARENTS:
                w = node["w"]
                if w.ndim >= 2:
                    cmax = node.get("cmax")
                    if cmax is None and prefix in tables:
                        cmax = jnp.asarray(tables[prefix])
                    if cfg.w_bits <= 4:
                        return ql.prepare_int4({"w": w}, cfg, cmax)
                    return ql.prepare_int8({"w": w}, cfg, cmax)
            return {k: convert(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        return node

    return convert(params, "")


def dequantize_tree(qparams, cfg: ql.QuantConfig):
    """Invert :func:`quantize_tree`'s *weight* quantization: every prepared linear
    becomes ``{"w": dequant(q)/b, "cmax": ...}`` — an fp tree whose weights carry
    exactly the integer path's weight rounding.

    Serving this tree with ``mode="fake", act_quant="crossquant", static_c=True,
    w_prequantized=True`` is the fake-quant twin of the fused int path: the
    activation fake-quant applies the same ``t_i^α · c_j^(1-α)`` grid the kernels
    use, so logits agree up to f32 association (the §3.3 parity tests pin this).
    Leaves prepared without calibration (``qalpha == 1``) re-attach ``cmax = 1``;
    their fake twin is per-token activation quantization.
    """
    def convert(node):
        if isinstance(node, dict):
            if "qw" in node or "qw4" in node:
                b = node["bcol"]
                if "qw" in node:
                    wb = node["qw"].astype(jnp.float32) * node["sw"][..., None, :]
                else:
                    wb = ql.dequant_int4_weight(node["qw4"], node["sw"], cfg.w_group)
                w = wb / b[..., :, None]
                alpha = node["qalpha"][..., None]
                denom = jnp.where(alpha < 1.0, 1.0 - alpha, 1.0)
                cmax = jnp.where(alpha < 1.0, b ** (1.0 / denom), jnp.ones_like(b))
                return {"w": w, "cmax": cmax}
            return {k: convert(v) for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v) for v in node]
        return node

    return convert(qparams)


def fake_quantize_weights(params, cfg: ql.QuantConfig):
    """Offline PTQ for the *fake-quant* evaluation path: replace every quantizable
    linear's ``w`` with its fake-quantized value. Serving with
    ``cfg.w_prequantized=True`` is then bitwise identical to in-graph weight fake
    quantization, but the decode/prefill graphs carry no weight-quant compute (which
    XLA otherwise hoists into stacked f32 copies of the whole weight tree —
    EXPERIMENTS.md §Perf)."""
    from repro.core.qlinear import _fake_weight

    def convert(node, prefix):
        if isinstance(node, dict):
            if "w" in node and prefix and prefix.split("/")[-1] in QUANTIZABLE_PARENTS:
                if node["w"].ndim >= 2:
                    return {**node, "w": _fake_weight(node["w"], cfg)}
            return {k: convert(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        return node

    return convert(params, "")


def quantized_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def pad_head_params(params, cfg_from, cfg_to):
    """Transplant params into a head-padded layout (configs.with_padded_heads).

    Padding is PER KV GROUP (GQA maps head h to kv group h // G, so appending heads
    at the tail would reassign existing heads to different kv groups): each group
    gains zero q-columns (padded heads emit q=0) and zero wo-rows (padded heads
    contribute nothing) — the padded model computes exactly the same function, but
    its attention projections divide the TP degree.
    """
    import jax.numpy as jnp
    dh = cfg_to.head_dim
    hkv = cfg_from.n_kv_heads
    g0 = cfg_from.n_heads // hkv
    g1 = cfg_to.n_heads // hkv
    assert dh == cfg_from.head_dim and cfg_to.n_kv_heads == hkv
    if g0 == g1:
        return params

    def pad_wq(w):            # (..., d, H0*dh) -> (..., d, H1*dh)
        lead = w.shape[:-1]
        wg = w.reshape(*lead, hkv, g0, dh)
        pad = [(0, 0)] * wg.ndim
        pad[-2] = (0, g1 - g0)
        return jnp.pad(wg, pad).reshape(*lead, hkv * g1 * dh)

    def pad_wo(w):            # (..., H0*dh, d) -> (..., H1*dh, d)
        lead, d_out = w.shape[:-2], w.shape[-1]
        wg = w.reshape(*lead, hkv, g0, dh, d_out)
        pad = [(0, 0)] * wg.ndim
        pad[-3] = (0, g1 - g0)
        return jnp.pad(wg, pad).reshape(*lead, hkv * g1 * dh, d_out)

    def convert(node, parent=""):
        if isinstance(node, dict):
            if parent == "wq" and "w" in node:
                return {**node, "w": pad_wq(node["w"])}
            if parent == "wo" and "w" in node:
                return {**node, "w": pad_wo(node["w"])}
            return {k: convert(v, k) for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v, parent) for v in node]
        return node

    return convert(params)
