"""Whole-model weight quantization: walk a params pytree and convert every quantizable
linear to its prepared integer form (int8 static-c CrossQuant or packed int4 groups).

This is the offline PTQ step of a serving deployment: run once, checkpoint the
quantized tree, serve from it. Embeddings, lm_head, router, norms, convs and the SSM
recurrence parameters stay fp (paper scope: activations *entering linear layers*)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core import qlinear as ql
from repro.core import quantizers as Q

QUANTIZABLE_PARENTS = ("wq", "wk", "wv", "wo", "up", "gate", "down",
                       "in_proj", "out_proj")


def _pathstr(path) -> str:
    out = []
    for p in path:
        out.append(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)))
    return "/".join(out)


def quantize_tree(params, cfg: ql.QuantConfig,
                  tables: Optional[Dict[str, np.ndarray]] = None):
    """Returns a new params pytree with prepared quantized linears.

    tables: calibration column-absmax per linear name (core.calibration.Observer);
    missing names fall back to c=1 (pure per-token row scaling)."""
    tables = tables or {}

    def convert(node, prefix):
        if isinstance(node, dict):
            if "w" in node and prefix and prefix.split("/")[-1] in QUANTIZABLE_PARENTS:
                w = node["w"]
                if w.ndim >= 2:
                    cmax = node.get("cmax")
                    if cmax is None and prefix in tables:
                        cmax = jnp.asarray(tables[prefix])
                    if cfg.w_bits <= 4:
                        return ql.prepare_int4({"w": w}, cfg, cmax)
                    return ql.prepare_int8({"w": w}, cfg, cmax)
            return {k: convert(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        return node

    return convert(params, "")


def dequantize_tree(qparams, cfg: ql.QuantConfig):
    """Invert :func:`quantize_tree`'s *weight* quantization: every prepared linear
    becomes ``{"w": dequant(q)/b, "cmax": ...}`` — an fp tree whose weights carry
    exactly the integer path's weight rounding.

    Serving this tree with ``mode="fake", act_quant="crossquant", static_c=True,
    w_prequantized=True`` is the fake-quant twin of the fused int path: the
    activation fake-quant applies the same ``t_i^α · c_j^(1-α)`` grid the kernels
    use, so logits agree up to f32 association (the §3.3 parity tests pin this).
    Leaves prepared without calibration (``qalpha == 1``) re-attach ``cmax = 1``;
    their fake twin is per-token activation quantization.
    """
    def convert(node):
        if isinstance(node, dict):
            if "qw" in node or "qw4" in node:
                b = node["bcol"]
                if "qw" in node:
                    wb = node["qw"].astype(jnp.float32) * node["sw"][..., None, :]
                else:
                    wb = ql.dequant_int4_weight(node["qw4"], node["sw"], cfg.w_group)
                w = wb / b[..., :, None]
                alpha = node["qalpha"][..., None]
                denom = jnp.where(alpha < 1.0, 1.0 - alpha, 1.0)
                cmax = jnp.where(alpha < 1.0, b ** (1.0 / denom), jnp.ones_like(b))
                return {"w": w, "cmax": cmax}
            return {k: convert(v) for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v) for v in node]
        return node

    return convert(qparams)


def fake_quantize_weights(params, cfg: ql.QuantConfig):
    """Offline PTQ for the *fake-quant* evaluation path: replace every quantizable
    linear's ``w`` with its fake-quantized value. Serving with
    ``cfg.w_prequantized=True`` is then bitwise identical to in-graph weight fake
    quantization, but the decode/prefill graphs carry no weight-quant compute (which
    XLA otherwise hoists into stacked f32 copies of the whole weight tree —
    EXPERIMENTS.md §Perf)."""
    from repro.core.qlinear import _fake_weight

    def convert(node, prefix):
        if isinstance(node, dict):
            if "w" in node and prefix and prefix.split("/")[-1] in QUANTIZABLE_PARENTS:
                if node["w"].ndim >= 2:
                    return {**node, "w": _fake_weight(node["w"], cfg)}
            return {k: convert(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        return node

    return convert(params, "")


# --------------------------------------------------------------------------------------
# N:M structured sparsity (DESIGN.md §3.12)
# --------------------------------------------------------------------------------------

def parse_nm(spec: str) -> Tuple[int, int]:
    """``"2:4"`` -> ``(2, 4)`` (keep n of every m consecutive input channels)."""
    try:
        n, m = (int(p) for p in spec.split(":"))
    except ValueError:
        raise ValueError(f"sparsity spec {spec!r} is not 'N:M'") from None
    if not 0 < n < m:
        raise ValueError(f"sparsity spec {spec!r} needs 0 < N < M")
    return n, m


@dataclasses.dataclass
class SparsityPlan:
    """Which linears to prune, at what N:M, and the §4.1 evidence for the choice.

    ``layers=None`` prunes every eligible leaf (the serving default when no
    calibration traffic is available); :func:`make_sparsity_plan` instead measures
    each linear's CrossQuant quantization-kernel proportion and lists only the
    layers where it stays under ``threshold`` — small kernel ⇒ the activation grid
    already preserves the layer's information, so the extra weight compression is
    where it is safest (paper §4.1; ZeroQuant-V2's per-layer sensitivity)."""

    nm: Tuple[int, int] = (2, 4)
    layers: Optional[Tuple[str, ...]] = None   # leaf paths, e.g. "blocks/0/attn/wq"
    fractions: Dict[str, float] = dataclasses.field(default_factory=dict)
    threshold: float = 0.0

    def wants(self, prefix: str) -> bool:
        return self.layers is None or prefix in self.layers


def nm_keep_mask(score: jax.Array, n: int, m: int) -> jax.Array:
    """Boolean keep-mask holding the top-``n`` scores of every ``m`` consecutive
    input channels (axis -2), independently per output channel. Ties break toward
    the lower channel index (argsort is stable), so exactly ``n`` survive per
    group. A trailing remainder when ``d_in % m != 0`` stays dense."""
    *lead, K, N = score.shape
    kg = (K // m) * m
    head = score[..., :kg, :].reshape(*lead, kg // m, m, N)
    order = jnp.argsort(-head, axis=-2)            # descending within the group
    rank = jnp.argsort(order, axis=-2)             # each element's rank
    keep = (rank < n).reshape(*lead, kg, N)
    if kg < K:
        tail = jnp.ones((*lead, K - kg, N), bool)
        keep = jnp.concatenate([keep, tail], axis=-2)
    return keep


def _activation_weight(cm, alpha, d_in: int):
    """Residual activation factor that turns |wb| into the full |w|·c score.

    The prepared weight already carries ``c^(1-α)`` (the folded ``b`` column), so
    multiplying by ``c^α`` recovers magnitude × activation-absmax — the
    Wanda-style score — without unfolding. Uncalibrated leaves (α=1, b=1) get the
    whole ``c`` here."""
    cm = jnp.maximum(jnp.asarray(cm, jnp.float32), Q.EPS)
    cm = jnp.broadcast_to(cm, cm.shape[:-1] + (d_in,))
    return cm ** jnp.asarray(alpha, jnp.float32)[..., None]


def sparsify_tree(qparams, plan: SparsityPlan,
                  tables: Optional[Dict[str, np.ndarray]] = None):
    """Prune the linears named by ``plan`` to N:M structured sparsity.

    Works on either tree form:

    * **prepared int8** (post :func:`quantize_tree`): scores ``|qw·sw|`` — the
      b-folded weight, i.e. magnitude already weighted by ``c^(1-α)`` — times the
      residual ``c^α`` when calibration tables are available, zeroes the losers,
      then *refits* ``sw`` to the survivors before requantizing. Refitting is the
      point of pruning before per-channel scaling: the pruned weights no longer
      claim dynamic range, so every int8 code lands on a surviving value.
    * **fp** (pre-quantization, fake/fp serving): scores ``|w|·cmax`` (or plain
      magnitude without calibration) and zeroes the pruned fp weights in place.

    Either way each pruned leaf gains a bit-packed ``mask`` leaf
    (:func:`repro.core.packing.pack_mask`). Packed-int4 leaves and leaves already
    carrying a mask pass through untouched.
    """
    tables = tables or {}
    n, m = plan.nm

    def table_cmax(node, prefix):
        cm = node.get("cmax")
        if cm is None and prefix in tables:
            cm = jnp.asarray(tables[prefix])
        return cm

    def prune_prepared(node, prefix):
        qw, sw = node["qw"], node["sw"]
        wb = qw.astype(jnp.float32) * sw[..., None, :]
        score = jnp.abs(wb)
        cm = table_cmax(node, prefix)
        if cm is not None:
            score = score * _activation_weight(cm, node["qalpha"], qw.shape[-2])[..., :, None]
        mask = nm_keep_mask(score, n, m)
        wbp = jnp.where(mask, wb, 0.0)
        sw2 = jnp.maximum(jnp.max(jnp.abs(wbp), axis=-2), Q.EPS) / Q.qmax(8)
        qw2 = jnp.clip(jnp.round(wbp / sw2[..., None, :]),
                       -Q.qmax(8), Q.qmax(8)).astype(jnp.int8)
        return {**node, "qw": qw2, "sw": sw2.astype(jnp.float32),
                "mask": packing.pack_mask(mask)}

    def prune_fp(node, prefix):
        w = node["w"]
        score = jnp.abs(w).astype(jnp.float32)
        cm = table_cmax(node, prefix)
        if cm is not None:
            cm = jnp.maximum(jnp.asarray(cm, jnp.float32), Q.EPS)
            score = score * cm[..., :, None]
        mask = nm_keep_mask(score, n, m)
        return {**node, "w": jnp.where(mask, w, 0.0).astype(w.dtype),
                "mask": packing.pack_mask(mask)}

    def convert(node, prefix):
        if isinstance(node, dict):
            leaf = prefix.split("/")[-1] if prefix else ""
            if leaf in QUANTIZABLE_PARENTS and "mask" not in node and plan.wants(prefix):
                if "qw" in node:
                    return prune_prepared(node, prefix)
                if "w" in node and node["w"].ndim >= 2:
                    return prune_fp(node, prefix)
            return {k: convert(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        return node

    return convert(qparams, "")


def make_sparsity_plan(cfg, params, batches: Iterable, *, nm: Tuple[int, int] = (2, 4),
                       threshold: float = 0.05, bits: int = 8, alpha: float = 0.15,
                       ) -> SparsityPlan:
    """Measure each linear's §4.1 quantization-kernel proportion on calibration
    traffic and plan N:M pruning for the layers where it stays under ``threshold``.

    The proportion is ``|K(Q)| / |Q|`` under the CrossQuant grid (eager observer
    pass, like :mod:`repro.core.calibration`); a *stacked* leaf (one param array
    per sublayer across the scanned blocks) is gated on its **worst** layer, so a
    single outlier-heavy layer keeps the whole leaf dense."""
    from repro.core import kernel_analysis as KA
    from repro.core.calibration import stack_tables
    from repro.models import model as M
    from repro.models.layers import QuantContext

    per_name: Dict[str, list] = {}

    class _Shim:
        def observe(self, name, x):
            x2 = jnp.asarray(x).reshape(-1, x.shape[-1]).astype(jnp.float32)
            frac = float(KA.crossquant_kernel_fraction(x2, bits=bits, alpha=alpha))
            per_name.setdefault(name, []).append(frac)

    ctx = QuantContext(ql.W8A8_CROSSQUANT, observer=_Shim())
    for batch in batches:
        M.apply(params, batch, cfg, ctx=ctx, mode="train", unroll=True)

    stacked = stack_tables({k: np.float32(np.mean(v)) for k, v in per_name.items()})
    fractions = {path: float(np.max(v)) for path, v in stacked.items()}
    layers = tuple(sorted(p for p, f in fractions.items()
                          if f <= threshold and p.split("/")[-1] in QUANTIZABLE_PARENTS))
    return SparsityPlan(nm=nm, layers=layers, fractions=fractions,
                        threshold=threshold)


def sparsity_summary(qparams) -> Dict[str, float]:
    """``{leaf path: kept fraction}`` for every masked leaf (popcount / elements)."""
    out: Dict[str, float] = {}

    def walk(node, prefix):
        if isinstance(node, dict):
            if "mask" in node:
                ref = node["qw"] if "qw" in node else node["w"]
                kept = int(np.unpackbits(np.asarray(node["mask"])).sum())
                out[prefix] = kept / ref.size
                return
            for k, v in node.items():
                walk(v, f"{prefix}/{k}" if prefix else k)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(v, f"{prefix}/{i}")

    walk(qparams, "")
    return out


def quantized_bytes(params, *, deploy_sparse: bool = False) -> int:
    """Total bytes of **every** leaf — integer codes, scale/aux vectors (``sw``,
    ``bcol``, ``qalpha``, the int8-KV ``k_scale``/``v_scale``) and packed ``mask``
    leaves alike. Nothing is exempt: serving capacity math (README, serving_bench
    ``capacity_x``) divides HBM by this number, so auxiliary leaves must be paid
    for where they live.

    ``deploy_sparse=True`` costs each masked int8 leaf at its N:M *deployment*
    size — surviving codes (mask popcount) plus the packed mask — instead of the
    dense zero-carrying layout this repo stores; the difference is the HBM a 2:4
    hardware format hands back as extra KV pages."""
    if not deploy_sparse:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(params))

    def walk(node) -> int:
        if isinstance(node, dict):
            if "qw" in node and "mask" in node:
                aux = sum(walk(v) for k, v in node.items() if k != "qw")
                kept = int(np.unpackbits(np.asarray(node["mask"])).sum())
                return aux + kept * node["qw"].dtype.itemsize
            return sum(walk(v) for v in node.values())
        if isinstance(node, list):
            return sum(walk(v) for v in node)
        return node.size * node.dtype.itemsize

    return walk(params)


def pad_head_params(params, cfg_from, cfg_to):
    """Transplant params into a head-padded layout (configs.with_padded_heads).

    Padding is PER KV GROUP (GQA maps head h to kv group h // G, so appending heads
    at the tail would reassign existing heads to different kv groups): each group
    gains zero q-columns (padded heads emit q=0) and zero wo-rows (padded heads
    contribute nothing) — the padded model computes exactly the same function, but
    its attention projections divide the TP degree.
    """
    import jax.numpy as jnp
    dh = cfg_to.head_dim
    hkv = cfg_from.n_kv_heads
    g0 = cfg_from.n_heads // hkv
    g1 = cfg_to.n_heads // hkv
    assert dh == cfg_from.head_dim and cfg_to.n_kv_heads == hkv
    if g0 == g1:
        return params

    def pad_wq(w):            # (..., d, H0*dh) -> (..., d, H1*dh)
        lead = w.shape[:-1]
        wg = w.reshape(*lead, hkv, g0, dh)
        pad = [(0, 0)] * wg.ndim
        pad[-2] = (0, g1 - g0)
        return jnp.pad(wg, pad).reshape(*lead, hkv * g1 * dh)

    def pad_wo(w):            # (..., H0*dh, d) -> (..., H1*dh, d)
        lead, d_out = w.shape[:-2], w.shape[-1]
        wg = w.reshape(*lead, hkv, g0, dh, d_out)
        pad = [(0, 0)] * wg.ndim
        pad[-3] = (0, g1 - g0)
        return jnp.pad(wg, pad).reshape(*lead, hkv * g1 * dh, d_out)

    def convert(node, parent=""):
        if isinstance(node, dict):
            if parent == "wq" and "w" in node:
                return {**node, "w": pad_wq(node["w"])}
            if parent == "wo" and "w" in node:
                return {**node, "w": pad_wo(node["w"])}
            return {k: convert(v, k) for k, v in node.items()}
        if isinstance(node, list):
            return [convert(v, parent) for v in node]
        return node

    return convert(params)
