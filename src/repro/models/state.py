"""Layer-polymorphic state registry (DESIGN.md §3.13).

Every sublayer kind declares, through a :class:`StateSpec`, how its decoding
state is laid out in the two cache layouts the serving stack supports:

  dense   per-slot leaves with a leading ``batch_size`` slot-table axis
          (DESIGN.md §3.6) — attention KV rows, SSM recurrent state + conv
          window.
  paged   fixed-size physical pools addressed through a top-level routing
          table whose ids come from the shared ref-counted ``PagePool``
          (serving/paging.py). Attention pages hold ``page_size`` tokens of
          KV and a slot needs ``ceil(len / page_size)`` of them; an SSM
          layer's state has no sequence axis, so its "page" is one
          fixed-size checkpoint — a recurrent-state slab plus the K-1-token
          pre-conv window — and a slot needs exactly one, shared across all
          its SSM layers (the same id indexes every layer's pool).

``models/model.py::init_cache`` builds cache pytrees from this registry
instead of hard-coding attention leaves, which is what lets ``ServeEngine``
treat mamba2/zamba2 slots identically to attention slots: admission plans
page needs per kind, the routing tables (``page_table`` (B, max_len/ps) for
token-paged kinds, ``state_table`` (B,) for checkpoint-paged kinds) travel
inside the cache pytree, and retirement decrefs both kinds of ids in the one
pool.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """How one sublayer kind stores decoding state.

    ``table``: cache key of the top-level routing table its paged leaves are
    addressed through. ``paged_kv``: True when pages hold per-token KV (page
    need grows with sequence length); False for fixed-size state checkpoints
    (one page per slot, length-independent).
    """
    kind: str
    table: str
    paged_kv: bool
    dense_leaves: Callable[..., dict]
    paged_leaves: Callable[..., dict]


def _attn_dense(cfg: ModelConfig, batch_size: int, max_len: int, dtype,
                kv_int8: bool) -> dict:
    kv_shape = (batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
    if kv_int8:
        return {
            "k": jnp.zeros(kv_shape, jnp.int8),
            "v": jnp.zeros(kv_shape, jnp.int8),
            "k_scale": jnp.zeros(kv_shape[:3] + (1,), jnp.float32),
            "v_scale": jnp.zeros(kv_shape[:3] + (1,), jnp.float32),
        }
    return {"k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype)}


def _attn_paged(cfg: ModelConfig, n_pages: int, page_size: int, dtype,
                kv_int8: bool) -> dict:
    pool = (n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    if kv_int8:
        return {
            "k_pages": jnp.zeros(pool, jnp.int8),
            "v_pages": jnp.zeros(pool, jnp.int8),
            "k_scale_pages": jnp.zeros(pool[:3] + (1,), jnp.float32),
            "v_scale_pages": jnp.zeros(pool[:3] + (1,), jnp.float32),
        }
    return {"k_pages": jnp.zeros(pool, dtype),
            "v_pages": jnp.zeros(pool, dtype)}


def _ssm_conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def _ssm_dense(cfg: ModelConfig, batch_size: int, max_len: int, dtype,
               kv_int8: bool) -> dict:
    # Recurrence state always stays f32 regardless of kv_int8 (DESIGN.md §3.3).
    return {
        "state": jnp.zeros((batch_size, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch_size, cfg.ssm_conv - 1,
                           _ssm_conv_channels(cfg)), jnp.float32),
    }


def _ssm_paged(cfg: ModelConfig, n_pages: int, page_size: int, dtype,
               kv_int8: bool) -> dict:
    return {
        "state_pages": jnp.zeros((n_pages, cfg.ssm_heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32),
        "conv_pages": jnp.zeros((n_pages, cfg.ssm_conv - 1,
                                 _ssm_conv_channels(cfg)), jnp.float32),
    }


_ATTN = StateSpec(kind="attn", table="page_table", paged_kv=True,
                  dense_leaves=_attn_dense, paged_leaves=_attn_paged)
_SSM = StateSpec(kind="ssm", table="state_table", paged_kv=False,
                 dense_leaves=_ssm_dense, paged_leaves=_ssm_paged)

REGISTRY: Dict[str, StateSpec] = {
    "attn": _ATTN,
    "attn_local": _ATTN,
    "attn_moe": _ATTN,
    "ssm": _SSM,
}


def spec_for(kind: str) -> StateSpec:
    return REGISTRY[kind]


def cache_kinds(block_spec) -> list:
    """All sublayer kinds a cache for ``block_spec`` (models.model.BlockSpec)
    must cover, including the hybrid shared-attention block."""
    kinds = list(block_spec.sublayers) + list(block_spec.tail)
    if block_spec.shared_attn:
        kinds.append("attn")
    return kinds


def family_flags(block_spec) -> tuple:
    """(has_paged_kv, has_state_checkpoint) for a BlockSpec: whether a paged
    cache for it carries token-paged KV pools / fixed-size state pools. Drives
    the engine's page-need arithmetic: a slot needs ``ceil(len / page_size)``
    KV pages when the first holds, plus exactly one state page when the
    second does."""
    kinds = cache_kinds(block_spec)
    has_kv = any(spec_for(k).paged_kv for k in kinds)
    has_state = any(not spec_for(k).paged_kv for k in kinds)
    return has_kv, has_state
