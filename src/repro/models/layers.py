"""Transformer building blocks: norms, RoPE, blockwise (flash-style) attention, MLPs.

All modules are functional: ``init_*`` builds a params dict, ``*_apply`` consumes it.
Quantized linears go through :mod:`repro.core.qlinear` so every GEMM obeys the model's
:class:`QuantConfig` (fp / fake CrossQuant / int8 static-c).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qlinear as ql
from repro.configs.base import ModelConfig
from repro.sharding import hints


@dataclasses.dataclass
class QuantContext:
    """Threaded through every layer: quant behaviour + (eager-only) calibration.

    ``int_exec`` picks the execution backend for *prepared* integer linears
    (``None``/"ref" | "dequant" | "pallas" — DESIGN.md §3.3); ``use_pallas=True``
    additionally routes prefill attention through the flash kernel.
    """
    cfg: ql.QuantConfig
    observer: object = None
    prefix: str = ""
    use_pallas: bool = False
    int_exec: Optional[str] = None

    def sub(self, name: str) -> "QuantContext":
        return QuantContext(self.cfg, self.observer, f"{self.prefix}/{name}",
                            self.use_pallas, self.int_exec)

    def linear(self, params: dict, x: jax.Array, name: str) -> jax.Array:
        return ql.apply(params, x, self.cfg, name=f"{self.prefix}/{name}",
                        observer=self.observer, use_pallas=self.use_pallas,
                        int_exec=self.int_exec)


# ======================================================================================
# Norms
# ======================================================================================

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        y = y * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ======================================================================================
# RoPE
# ======================================================================================

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ======================================================================================
# Attention
# ======================================================================================

def init_attention(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.n_heads * cfg.head_dim
    kvd = cfg.n_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": ql.init(ks[0], d, hd),
        "wk": ql.init(ks[1], d, kvd),
        "wv": ql.init(ks[2], d, kvd),
        "wo": ql.init(ks[3], hd, d),
    }


def _softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def _block_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Bq, Bk) boolean validity mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def blockwise_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool, window: Optional[int], softcap: Optional[float],
    q_offset: int | jax.Array = 0, kv_valid_len: Optional[jax.Array] = None,
    q_block: int = 1024, kv_block: int = 1024,
) -> jax.Array:
    """Memory-efficient multihead attention (online softmax over KV blocks).

    q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D). GQA handled by head-group reshape so the
    kv tensor is never materialized at H heads. O(Sq·Sk) FLOPs, O(block²) memory.
    This is the jnp oracle mirrored by the Pallas flash kernel.
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv                                   # query heads per kv head
    scale = D ** -0.5

    # Pad to block multiples.
    pq = (-Sq) % q_block
    pk = (-Sk) % kv_block
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_block, kp.shape[1] // kv_block

    qp = qp.reshape(B, nq, q_block, Hkv, G, D)
    kp = kp.reshape(B, nk, kv_block, Hkv, D)
    vp = vp.reshape(B, nk, kv_block, Hkv, D)

    q_pos_base = jnp.arange(q_block)
    k_pos_base = jnp.arange(kv_block)

    def q_step(iq):
        qb = qp[:, iq]                                            # (B, Bq, Hkv, G, D)
        q_pos = q_offset + iq * q_block + q_pos_base

        def kv_step(carry, jk):
            m, l, acc = carry
            kb, vb = kp[:, jk], vp[:, jk]                         # (B, Bk, Hkv, D)
            k_pos = jk * kv_block + k_pos_base
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb) * scale   # (B,Hkv,G,Bq,Bk)
            s = _softcap(s.astype(jnp.float32), softcap)
            valid = _block_mask(q_pos, k_pos, causal, window)
            # Padded key positions (Sk rounded up to kv_block) must never attend —
            # the causal mask happens to exclude them for suffix queries, but
            # non-causal/windowless paths would include the zero-padding otherwise.
            valid = valid & (k_pos[None, :] < Sk)
            valid = valid[None, None, None]                       # (1,1,1,Bq,Bk)
            if kv_valid_len is not None:
                # scalar or per-slot (B,) valid kv length (right-padded prompts)
                kvl = jnp.reshape(kv_valid_len, (-1, 1, 1, 1, 1))
                valid = valid & (k_pos[None, None, None, None, :] < kvl)
            s = jnp.where(valid, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, D), jnp.float32)
        # Nested remat: without it the scan's AD saves every (q_block, kv_block)
        # probability tile — a full S×S attention matrix per layer (1.75 GiB/device at
        # 4k on deepseek-33b, EXPERIMENTS.md §Perf) — which defeats the point of
        # blockwise attention. With it the backward recomputes tiles one at a time.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]              # (B,Hkv,G,Bq,D)
        return out

    outs = jax.lax.map(q_step, jnp.arange(nq))                    # (nq,B,Hkv,G,Bq,D)
    out = jnp.moveaxis(outs, 0, 1)                                # (B,nq,Hkv,G,Bq,D)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, nq * q_block, H, D)
    return out[:, :Sq].astype(q.dtype)


def kv_quantize(x: jax.Array):
    """Per-token int8 KV quantization (DESIGN.md §3.3): reduce absmax over the head
    dim, one f32 scale per (batch, position, kv-head). x (B, S, Hkv, D) →
    (codes (B, S, Hkv, D) int8, scale (B, S, Hkv, 1) f32)."""
    from repro.core import quantizers as Q
    qr = Q.per_token_quant(x.astype(jnp.float32), 8)
    return qr.codes, qr.scale


def _scale_to_scores(scale: jax.Array) -> jax.Array:
    """(B, T, Hkv, 1) per-token KV scale → (B, Hkv, 1, T) score-broadcast layout."""
    return jnp.transpose(scale[..., 0], (0, 2, 1))[:, :, None, :]


# --------------------------------------------------------------------- paged KV

def _pool_flat(pool: jax.Array) -> jax.Array:
    """(P, ps, Hkv, D|1) page pool → (P·ps, Hkv, D|1) flat-position view."""
    return pool.reshape((pool.shape[0] * pool.shape[1],) + pool.shape[2:])


def _pool_scatter(pool: jax.Array, flat_idx: jax.Array, rows: jax.Array) -> jax.Array:
    """Write ``rows`` (N, Hkv, D|1) at flat page positions ``flat_idx`` (N,) into
    a (P, ps, Hkv, D|1) pool. Indices ≥ P·ps (sentinel page-table entries, padded
    batch rows) are dropped — pages of other sequences are never touched because
    the engine hands every live position exactly one page slot."""
    flat = _pool_flat(pool).at[flat_idx].set(rows.astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


def _pool_gather(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize the logical (B, max_pages·ps, Hkv, D|1) view of a pool through
    the page table. Sentinel entries clamp to an arbitrary valid page — callers
    mask those positions before the softmax. With ``max_pages·ps == max_len``
    the result is positionally identical to a dense (B, T, ...) cache row.
    Warm-prefix *prefill* only (``paged_prefill_attention`` reads the shared
    prefix back once per admission): decode never gathers — it runs the
    gather-free Pallas paged kernel on every path (DESIGN.md §3.8)."""
    P, ps = pool.shape[0], pool.shape[1]
    gidx = page_table[:, :, None] * ps + jnp.arange(ps)[None, None, :]
    gidx = jnp.clip(gidx, 0, P * ps - 1).reshape(page_table.shape[0], -1)
    return _pool_flat(pool)[gidx]


def paged_prefill_attention(
    q: jax.Array, k_new: jax.Array, v_new: jax.Array, cache: dict,
    page_table: jax.Array, *, prefix_len: jax.Array, suffix_len: jax.Array,
    window: Optional[int], softcap: Optional[float],
) -> jax.Array:
    """Suffix prefill against a shared paged prefix (DESIGN.md §3.8).

    q/k_new/v_new: (B, S, H|Hkv, D) — the *suffix* tokens only, right-padded to
    S with per-slot valid count ``suffix_len``; ``prefix_len`` tokens per slot
    already live in the pool (mapped by ``page_table``). Prefix keys/values are
    read back from the pool (int8 codes dequantized with their per-token scale
    pages); suffix keys use the in-flight fp k/v — the same dense-prefill
    semantics as the cold path, so a zero-prefix row computes the cold result.
    Absolute positions: suffix query i sits at ``prefix_len[b] + i``.
    """
    B, S, H, D = q.shape
    Hkv = k_new.shape[2]
    G = H // Hkv
    kv_int8 = "k_scale_pages" in cache

    kf = _pool_gather(cache["k_pages"], page_table).astype(jnp.float32)
    vf = _pool_gather(cache["v_pages"], page_table).astype(jnp.float32)
    if kv_int8:
        kf = kf * _pool_gather(cache["k_scale_pages"], page_table)
        vf = vf * _pool_gather(cache["v_scale_pages"], page_table)
    T = kf.shape[1]

    pl_ = jnp.reshape(prefix_len, (-1,)).astype(jnp.int32)
    sl = jnp.reshape(suffix_len, (-1,)).astype(jnp.int32)
    abs_pos = pl_[:, None] + jnp.arange(S)[None, :]                  # (B, S)
    row_valid = jnp.arange(S)[None, :] < sl[:, None]
    # overlay the in-flight suffix at its absolute positions (invalid rows drop)
    tgt = jnp.where(row_valid, jnp.clip(abs_pos, 0, T), T)
    rows = jnp.arange(B)[:, None]
    kf = kf.at[rows, tgt].set(k_new.astype(jnp.float32), mode="drop")
    vf = vf.at[rows, tgt].set(v_new.astype(jnp.float32), mode="drop")

    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, kf) * (D ** -0.5)
    s = _softcap(s, softcap)
    k_pos = jnp.arange(T)[None, None, :]                             # (1, 1, T)
    valid = k_pos <= abs_pos[:, :, None]                             # causal
    valid &= k_pos < (pl_ + sl)[:, None, None]                       # total length
    if window is not None:
        valid &= (abs_pos[:, :, None] - k_pos) < window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p, vf)
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
    cur_len: jax.Array, window: Optional[int], softcap: Optional[float],
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token attention against a (B, T, Hkv, D) cache. The T axis may be
    sequence-sharded over the model mesh axis (flash-decoding via GSPMD partial
    softmax — see sharding/planner). ``cur_len`` is a scalar or per-slot (B,)
    vector of valid cache lengths (DESIGN.md §3.6).

    With ``k_scale``/``v_scale`` the cache holds int8 codes and per-token f32 scales:
    the QK product runs on raw codes and the scale is applied to the *score column*
    (one multiply per (t, kv-head) instead of dequantizing the (T, D) cache), and the
    V scale folds into the probability row the same way.
    """
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    kf = k_cache.astype(jnp.float32) if k_scale is not None else k_cache
    s = jnp.einsum("bhgd,bthd->bhgt", qg, kf) * (D ** -0.5)
    s = s.astype(jnp.float32)
    if k_scale is not None:
        s = s * _scale_to_scores(k_scale)
    s = _softcap(s, softcap)
    t_pos = jnp.arange(k_cache.shape[1])
    cl = jnp.reshape(cur_len, (-1, 1, 1, 1))                 # (B|1, 1, 1, 1)
    valid = t_pos[None, None, None, :] < cl
    if window is not None:
        valid &= (cl - 1 - t_pos[None, None, None, :]) < window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        out = jnp.einsum("bhgt,bthd->bhgd", p * _scale_to_scores(v_scale),
                         v_cache.astype(jnp.float32))
    else:
        out = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def verify_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
    cur_len: jax.Array, q_len: jax.Array, window: Optional[int],
    softcap: Optional[float],
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Draft-window attention against a (B, T, Hkv, D) cache (DESIGN.md §3.9):
    the W window tokens are already scattered, ``cur_len`` is each slot's total
    post-scatter length and ``q_len`` (1 ≤ q_len ≤ W) its valid window rows —
    window token i sits at absolute position ``cur_len - q_len + i`` and
    attends keys ≤ its own position (rows ≥ q_len clamp to the newest valid
    position; their output is garbage-but-finite and discarded). W == 1 is the
    single-token :func:`decode_attention` mask. int8-KV scales apply at the
    same score-column / probability-row points as decode.
    q: (B, W, H, D) → (B, W, H, D)."""
    B, W, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, W, Hkv, G, D)
    kf = k_cache.astype(jnp.float32) if k_scale is not None else k_cache
    s = jnp.einsum("bwhgd,bthd->bhwgt", qg, kf) * (D ** -0.5)
    s = s.astype(jnp.float32)
    if k_scale is not None:
        s = s * _scale_to_scores(k_scale)[:, :, None]        # (B,Hkv,1,1,T)
    s = _softcap(s, softcap)
    cl = jnp.broadcast_to(jnp.reshape(cur_len, (-1,)).astype(jnp.int32), (B,))
    qln = jnp.broadcast_to(jnp.reshape(q_len, (-1,)).astype(jnp.int32), (B,))
    q_pos = ((cl - qln)[:, None]
             + jnp.minimum(jnp.arange(W)[None, :], (qln - 1)[:, None]))  # (B,W)
    t_pos = jnp.arange(k_cache.shape[1])[None, None, None, None, :]
    qp = q_pos[:, None, :, None, None]
    valid = t_pos <= qp
    if window is not None:
        valid &= (qp - t_pos) < window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        out = jnp.einsum("bhwgt,bthd->bwhgd",
                         p * _scale_to_scores(v_scale)[:, :, None],
                         v_cache.astype(jnp.float32))
    else:
        out = jnp.einsum("bhwgt,bthd->bwhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, W, H, D).astype(q.dtype)


def _prefill_attention(q, k, v, cfg: ModelConfig, ctx: QuantContext, *,
                       window: Optional[int], seq_lens: Optional[jax.Array]):
    """Self-attention over a (right-padded) prefill window — the one codepath
    shared by the dense layout and the cold (no-prefix) paged layout, so the two
    stay bitwise-identical (DESIGN.md §3.8 parity argument)."""
    S = q.shape[1]
    if ctx.use_pallas and S >= 128:
        # Fused flash-attention kernel (kernels/flash_attention.py): removes the
        # S²-score-tile HBM traffic that dominates training cells (§Roofline).
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), kv_len=seq_lens, causal=cfg.causal,
            window=window, softcap=cfg.attn_softcap).transpose(0, 2, 1, 3)
    return blockwise_attention(
        q, k, v, causal=cfg.causal, window=window, softcap=cfg.attn_softcap,
        kv_valid_len=seq_lens,
        q_block=min(1024, max(S, 16)), kv_block=min(1024, max(S, 16)))


def _paged_attention(q, k, v, cache: dict, page_table: Optional[jax.Array],
                     cfg: ModelConfig, ctx: QuantContext, *,
                     cur_len, prefix_len, window: Optional[int], decode: bool,
                     q_len=None):
    """Attention against a paged pool (DESIGN.md §3.8): scatter the new K/V
    through the page table, then attend. Every decode path — fp pools and int8
    codes + per-token scale pools alike, on all serving paths — runs the
    gather-free Pallas paged kernel (``ops.paged_decode_attention``): the scale
    tiles ride the same scalar-prefetched page indices as the code tiles and
    dequantize in-kernel at the score/prob level, the dense
    ``decode_attention`` application points, so the dense (B, max_pages·ps, ...)
    view is never materialized at decode. Returns (out, new_cache)."""
    if page_table is None:
        raise ValueError("paged cache without a page_table")
    B, S = q.shape[0], q.shape[1]
    kv_int8 = "k_scale_pages" in cache
    P, ps = cache["k_pages"].shape[0], cache["k_pages"].shape[1]

    if q_len is not None:
        # ---- draft-window verify (DESIGN.md §3.9): scatter the whole window
        # through the table (rows ≥ q_len drop), then score every window row
        # in one fused-kernel pass. cur_len is the *total* post-scatter
        # length; window token i of slot b sits at cur_len[b] - q_len[b] + i.
        cl = jnp.broadcast_to(jnp.reshape(cur_len, (-1,)).astype(jnp.int32), (B,))
        qln = jnp.broadcast_to(jnp.reshape(q_len, (-1,)).astype(jnp.int32), (B,))
        abs_pos = (cl - qln)[:, None] + jnp.arange(S)[None, :]       # (B, S)
        row_valid = jnp.arange(S)[None, :] < qln[:, None]
        entry = jnp.take_along_axis(
            page_table, jnp.clip(abs_pos // ps, 0, page_table.shape[1] - 1),
            axis=1)
        flat = jnp.where(row_valid, entry * ps + abs_pos % ps, P * ps).reshape(-1)
        merge = lambda t: t.reshape((B * S,) + t.shape[2:])
        if kv_int8:
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            new_cache = {
                "k_pages": _pool_scatter(cache["k_pages"], flat, merge(kq)),
                "v_pages": _pool_scatter(cache["v_pages"], flat, merge(vq)),
                "k_scale_pages": _pool_scatter(cache["k_scale_pages"], flat,
                                               merge(ks)),
                "v_scale_pages": _pool_scatter(cache["v_scale_pages"], flat,
                                               merge(vs)),
            }
        else:
            new_cache = {
                "k_pages": _pool_scatter(cache["k_pages"], flat, merge(k)),
                "v_pages": _pool_scatter(cache["v_pages"], flat, merge(v)),
            }
        new_cache = {kk: hints.constrain_kv_pages(vv) for kk, vv in new_cache.items()}
        from repro.kernels import ops as kops
        out = kops.paged_verify_attention(
            q, new_cache["k_pages"], new_cache["v_pages"], page_table, cl, qln,
            k_scale_pages=new_cache.get("k_scale_pages"),
            v_scale_pages=new_cache.get("v_scale_pages"),
            window=window, softcap=cfg.attn_softcap)
        return out, new_cache

    if decode:
        cl = jnp.broadcast_to(jnp.reshape(cur_len, (-1,)).astype(jnp.int32), (B,))
        pos = jnp.clip(cl - 1, 0, page_table.shape[1] * ps - 1)
        entry = jnp.take_along_axis(page_table, (pos // ps)[:, None], axis=1)[:, 0]
        flat = entry * ps + pos % ps           # sentinel entry (==P) ⇒ dropped
        if kv_int8:
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            new_cache = {
                "k_pages": _pool_scatter(cache["k_pages"], flat, kq[:, 0]),
                "v_pages": _pool_scatter(cache["v_pages"], flat, vq[:, 0]),
                "k_scale_pages": _pool_scatter(cache["k_scale_pages"], flat, ks[:, 0]),
                "v_scale_pages": _pool_scatter(cache["v_scale_pages"], flat, vs[:, 0]),
            }
        else:
            new_cache = {
                "k_pages": _pool_scatter(cache["k_pages"], flat, k[:, 0]),
                "v_pages": _pool_scatter(cache["v_pages"], flat, v[:, 0]),
            }
        new_cache = {kk: hints.constrain_kv_pages(vv) for kk, vv in new_cache.items()}
        from repro.kernels import ops as kops
        out = kops.paged_decode_attention(
            q, new_cache["k_pages"], new_cache["v_pages"], page_table, cl,
            k_scale_pages=new_cache.get("k_scale_pages"),
            v_scale_pages=new_cache.get("v_scale_pages"),
            window=window, softcap=cfg.attn_softcap)
        return out, new_cache

    # ---- prefill: scatter the (suffix) window through the table, then attend
    sl = (jnp.full((B,), S, jnp.int32) if cur_len is None
          else jnp.broadcast_to(jnp.reshape(cur_len, (-1,)).astype(jnp.int32), (B,)))
    pl_ = (jnp.zeros((B,), jnp.int32) if prefix_len is None
           else jnp.broadcast_to(jnp.reshape(prefix_len, (-1,)).astype(jnp.int32),
                                 (B,)))
    abs_pos = pl_[:, None] + jnp.arange(S)[None, :]                  # (B, S)
    row_valid = jnp.arange(S)[None, :] < sl[:, None]
    entry = jnp.take_along_axis(
        page_table, jnp.clip(abs_pos // ps, 0, page_table.shape[1] - 1), axis=1)
    flat = jnp.where(row_valid, entry * ps + abs_pos % ps, P * ps).reshape(-1)
    merge = lambda t: t.reshape((B * S,) + t.shape[2:])
    if kv_int8:
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
        new_cache = {
            "k_pages": _pool_scatter(cache["k_pages"], flat, merge(kq)),
            "v_pages": _pool_scatter(cache["v_pages"], flat, merge(vq)),
            "k_scale_pages": _pool_scatter(cache["k_scale_pages"], flat, merge(ks)),
            "v_scale_pages": _pool_scatter(cache["v_scale_pages"], flat, merge(vs)),
        }
    else:
        new_cache = {
            "k_pages": _pool_scatter(cache["k_pages"], flat, merge(k)),
            "v_pages": _pool_scatter(cache["v_pages"], flat, merge(v)),
        }
    new_cache = {kk: hints.constrain_kv_pages(vv) for kk, vv in new_cache.items()}
    if prefix_len is None:
        # cold admission: exactly the dense prefill attention (bitwise parity)
        out = _prefill_attention(q, k, v, cfg, ctx, window=window,
                                 seq_lens=None if cur_len is None else sl)
    else:
        out = paged_prefill_attention(
            q, k, v, new_cache, page_table, prefix_len=pl_, suffix_len=sl,
            window=window, softcap=cfg.attn_softcap)
    return out, new_cache


def _chunked_attention(q, k, v, cache: dict, page_table: jax.Array,
                       cfg: ModelConfig, chunk: dict, *,
                       window: Optional[int]):
    """Packed ragged chunk step (DESIGN.md §3.10): scatter every packed token
    through the page table at its own absolute position, then score the whole
    ragged block in one ``ragged_prefill_attention`` launch. ``chunk`` carries
    per-slot extents (``q_start``/``q_len``/``kv_len`` (B,)) and per-token
    routing (``positions``/``slot_ids`` (Nt,), sentinel ``slot_ids == B`` for
    pad rows). Decode rows are 1-token chunks; prefill chunks, draft-verify
    windows and cold admissions are longer ones — one launch serves them all.
    Returns (out (1, Nt, H, D), new_cache)."""
    B_tab, maxP = page_table.shape
    Nt = q.shape[1]
    kv_int8 = "k_scale_pages" in cache
    P, ps = cache["k_pages"].shape[0], cache["k_pages"].shape[1]

    pos = jnp.reshape(chunk["positions"], (-1,)).astype(jnp.int32)    # (Nt,)
    sid = jnp.reshape(chunk["slot_ids"], (-1,)).astype(jnp.int32)     # (Nt,)
    row_valid = sid < B_tab
    entry = page_table[jnp.clip(sid, 0, B_tab - 1),
                       jnp.clip(pos // ps, 0, maxP - 1)]
    flat = jnp.where(row_valid, entry * ps + pos % ps, P * ps)
    if kv_int8:
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
        new_cache = {
            "k_pages": _pool_scatter(cache["k_pages"], flat, kq[0]),
            "v_pages": _pool_scatter(cache["v_pages"], flat, vq[0]),
            "k_scale_pages": _pool_scatter(cache["k_scale_pages"], flat, ks[0]),
            "v_scale_pages": _pool_scatter(cache["v_scale_pages"], flat, vs[0]),
        }
    else:
        new_cache = {
            "k_pages": _pool_scatter(cache["k_pages"], flat, k[0]),
            "v_pages": _pool_scatter(cache["v_pages"], flat, v[0]),
        }
    new_cache = {kk: hints.constrain_kv_pages(vv) for kk, vv in new_cache.items()}
    from repro.kernels import ops as kops
    out = kops.ragged_prefill_attention(
        q[0], k[0], v[0], new_cache["k_pages"], new_cache["v_pages"],
        page_table, chunk["q_start"], chunk["q_len"], chunk["kv_len"],
        chunk_cap=Nt,
        k_scale_pages=new_cache.get("k_scale_pages"),
        v_scale_pages=new_cache.get("v_scale_pages"),
        window=window, softcap=cfg.attn_softcap)
    return out[None], new_cache


def attention_apply(
    params: dict, x: jax.Array, cfg: ModelConfig, ctx: QuantContext, *,
    local: bool = False, positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None, cur_len: Optional[jax.Array] = None,
    page_table: Optional[jax.Array] = None, prefix_len: Optional[jax.Array] = None,
    q_len: Optional[jax.Array] = None, chunk: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full attention sublayer (pre-norm residual is handled by the caller).

    cache: {"k": (B,T,Hkv,D), "v": ...} — prefill writes it, decode reads+appends.
    Paged caches (``k_pages``/``v_pages`` pools + ``page_table``, DESIGN.md §3.8)
    scatter through the table instead; ``prefix_len`` marks suffix prefill
    against a shared paged prefix. Returns (output, new_cache).

    Per-slot length contract (DESIGN.md §3.6): ``cur_len`` may be a scalar (all
    slots aligned) or a (B,) int32 vector. Prefill prompts are right-padded —
    positions start at 0 (at ``prefix_len[b]`` on the paged suffix path),
    ``cur_len`` holds the valid prompt length per slot and masks padded keys;
    decode ``cur_len`` is the per-slot post-append length: the new token
    scatters into cache position ``cur_len - 1`` of its own slot.

    ``q_len`` (B,) marks a *draft-window verify* batch (DESIGN.md §3.9): the S
    axis is a speculative window — all S tokens scatter into the cache (rows ≥
    q_len[b] drop) and every window row is scored in one pass; ``cur_len`` is
    the per-slot *total* post-scatter length, so window token i sits at
    ``cur_len - q_len + i``. The flag is explicit because verify shares
    prefill's S > 1 shape while reading+appending a live cache like decode.

    ``chunk`` marks a *packed ragged chunk* batch (DESIGN.md §3.10): the S axis
    is a packed token row mixing decode tokens and prefill chunks of many
    slots; see :func:`_chunked_attention` for the dict contract. Paged caches
    only.
    """
    B, S, d = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = ctx.linear(params["wq"], x, "wq").reshape(B, S, H, D)
    k = ctx.linear(params["wk"], x, "wk").reshape(B, S, Hkv, D)
    v = ctx.linear(params["wv"], x, "wv").reshape(B, S, Hkv, D)

    is_chunked = cache is not None and chunk is not None
    is_verify = cache is not None and q_len is not None and not is_chunked
    is_decode = cache is not None and S == 1 and q_len is None and not is_chunked
    paged = cache is not None and "k_pages" in cache
    if is_chunked and not paged:
        raise ValueError("chunked serving needs a paged cache")
    if positions is None:
        if is_chunked:
            # every packed token carries its own absolute position
            positions = jnp.reshape(chunk["positions"], (1, -1))
        elif is_verify:
            # window token i at absolute position cur_len - q_len + i; rows ≥
            # q_len clamp to the newest valid position (dropped downstream)
            cl_ = jnp.reshape(cur_len, (-1, 1))
            ql_ = jnp.reshape(q_len, (-1, 1))
            positions = (cl_ - ql_) + jnp.minimum(jnp.arange(S)[None, :],
                                                  ql_ - 1)
        elif is_decode and cur_len is not None:
            positions = jnp.reshape(cur_len, (-1, 1)) - 1        # (B|1, 1)
        elif paged and prefix_len is not None:
            # paged suffix prefill: suffix token i of slot b is absolute
            # position prefix_len[b] + i
            positions = (jnp.reshape(prefix_len, (-1, 1))
                         + jnp.arange(S)[None, :])
        else:
            # train and (right-padded) prefill: absolute positions start at 0
            positions = jnp.arange(S)[None, :]
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    window = cfg.window if local else None
    new_cache = None
    if is_chunked:
        out, new_cache = _chunked_attention(q, k, v, cache, page_table, cfg,
                                            chunk, window=window)
        y = ctx.linear(params["wo"], out.reshape(B, S, H * D), "wo")
        return y, new_cache
    if paged:
        out, new_cache = _paged_attention(
            q, k, v, cache, page_table, cfg, ctx, cur_len=cur_len,
            prefix_len=prefix_len, window=window, decode=is_decode,
            q_len=q_len if is_verify else None)
        y = ctx.linear(params["wo"], out.reshape(B, S, H * D), "wo")
        return y, new_cache
    kv_int8 = cache is not None and "k_scale" in cache
    if is_verify:
        # dense draft-window verify (DESIGN.md §3.9): scatter all S window
        # tokens at their absolute positions (rows ≥ q_len drop via the T
        # sentinel), then score the window against the updated cache.
        cl = jnp.broadcast_to(jnp.reshape(cur_len, (-1,)).astype(jnp.int32), (B,))
        qln = jnp.broadcast_to(jnp.reshape(q_len, (-1,)).astype(jnp.int32), (B,))
        T = cache["k"].shape[1]
        abs_pos = (cl - qln)[:, None] + jnp.arange(S)[None, :]       # (B, S)
        row_valid = jnp.arange(S)[None, :] < qln[:, None]
        idx = jnp.where(row_valid, jnp.clip(abs_pos, 0, T - 1), T)   # T drops
        rows = jnp.arange(B)[:, None]
        if kv_int8:
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            new_cache = {
                "k": cache["k"].at[rows, idx].set(kq, mode="drop"),
                "v": cache["v"].at[rows, idx].set(vq, mode="drop"),
                "k_scale": cache["k_scale"].at[rows, idx].set(ks, mode="drop"),
                "v_scale": cache["v_scale"].at[rows, idx].set(vs, mode="drop"),
            }
            out = verify_attention(q, new_cache["k"], new_cache["v"],
                                   cur_len=cl, q_len=qln, window=window,
                                   softcap=cfg.attn_softcap,
                                   k_scale=new_cache["k_scale"],
                                   v_scale=new_cache["v_scale"])
        else:
            k_cache = cache["k"].at[rows, idx].set(
                k.astype(cache["k"].dtype), mode="drop")
            v_cache = cache["v"].at[rows, idx].set(
                v.astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": k_cache, "v": v_cache}
            out = verify_attention(q, k_cache, v_cache, cur_len=cl, q_len=qln,
                                   window=window, softcap=cfg.attn_softcap)
    elif is_decode:
        # decode: scatter the new token at each slot's own append position, then
        # attend over that slot's valid cache prefix.
        cl = jnp.broadcast_to(jnp.reshape(cur_len, (-1,)).astype(jnp.int32), (B,))
        idx = jnp.clip(cl - 1, 0, cache["k"].shape[1] - 1)       # (B,)
        rows = jnp.arange(B)
        if kv_int8:
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            new_cache = {
                "k": cache["k"].at[rows, idx].set(kq[:, 0]),
                "v": cache["v"].at[rows, idx].set(vq[:, 0]),
                "k_scale": cache["k_scale"].at[rows, idx].set(ks[:, 0]),
                "v_scale": cache["v_scale"].at[rows, idx].set(vs[:, 0]),
            }
            out = decode_attention(q, new_cache["k"], new_cache["v"],
                                   cur_len=cl, window=window,
                                   softcap=cfg.attn_softcap,
                                   k_scale=new_cache["k_scale"],
                                   v_scale=new_cache["v_scale"])
        else:
            k_cache = cache["k"].at[rows, idx].set(k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[rows, idx].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": k_cache, "v": v_cache}
            out = decode_attention(q, k_cache, v_cache, cur_len=cl,
                                   window=window, softcap=cfg.attn_softcap)
    else:
        seq_lens = None
        if cache is not None and cur_len is not None:
            # right-padded prefill: keys beyond each slot's prompt length are pad
            seq_lens = jnp.reshape(cur_len, (-1,))
        out = _prefill_attention(q, k, v, cfg, ctx, window=window,
                                 seq_lens=seq_lens)
        if cache is not None:
            # prefill: write kv into the cache prefix (in-flight attention above runs
            # on the unquantized k/v; only the *stored* cache is int8)
            T = cache["k"].shape[1]
            pad = ((0, 0), (0, T - S), (0, 0), (0, 0))
            if kv_int8:
                kq, ks = kv_quantize(k)
                vq, vs = kv_quantize(v)
                new_cache = {
                    "k": jnp.pad(kq, pad), "v": jnp.pad(vq, pad),
                    "k_scale": jnp.pad(ks, pad), "v_scale": jnp.pad(vs, pad),
                }
            else:
                new_cache = {
                    "k": jnp.pad(k.astype(cache["k"].dtype), pad),
                    "v": jnp.pad(v.astype(cache["v"].dtype), pad),
                }
    if new_cache is not None:
        # Keep the slot table's (B→dp, T→model) placement on the freshly written
        # cache leaves (codes AND int8-KV per-token scales): the decode-step scatter
        # otherwise loses the spec and GSPMD reshards the whole cache every step
        # (no-op outside a sharded serving plan — DESIGN.md §3.7).
        new_cache = {kk: hints.constrain_kv_cache(vv) for kk, vv in new_cache.items()}
    y = ctx.linear(params["wo"], out.reshape(B, S, H * D), "wo")
    return y, new_cache


# ======================================================================================
# MLP
# ======================================================================================

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"up": ql.init(ks[0], d, f), "down": ql.init(ks[1], f, d)}
    if cfg.act.endswith("_glu"):
        p["gate"] = ql.init(ks[2], d, f)
    return p


def mlp_apply(params: dict, x: jax.Array, cfg: ModelConfig, ctx: QuantContext) -> jax.Array:
    up = ctx.linear(params["up"], x, "up")
    if cfg.act == "silu_glu":
        h = jax.nn.silu(ctx.linear(params["gate"], x, "gate")) * up
    elif cfg.act == "gelu_glu":
        h = jax.nn.gelu(ctx.linear(params["gate"], x, "gate")) * up
    elif cfg.act == "gelu":
        h = jax.nn.gelu(up)
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(cfg.act)
    return ctx.linear(params["down"], h, "down")
