"""Shardable host data loader with background prefetch.

Production layout: each host loads only its shard of the global batch
(``host_id / num_hosts``), determinism comes from (seed, step) so restarts resume at
the exact batch without replaying the stream, and a daemon thread keeps a bounded
queue of ready batches ahead of the training loop (overlapping host data work with
device compute).

The dry-run never touches this module (it lowers against ShapeDtypeStructs); training
examples and integration tests run it for real.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator

import numpy as np

from repro.data.synthetic import markov_corpus


def make_train_batches(vocab: int, seq_len: int, global_batch: int, *,
                       host_id: int = 0, num_hosts: int = 1, seed: int = 0,
                       ) -> Callable[[int], Dict[str, np.ndarray]]:
    """Returns ``batch_fn(step) -> {"tokens": (local_batch, seq_len) int32}``.

    Deterministic in (seed, step, host_id): restart-safe, elastic-safe (a host that
    takes over another's shard regenerates identical data).
    """
    assert global_batch % num_hosts == 0, (global_batch, num_hosts)
    local = global_batch // num_hosts

    def batch_fn(step: int) -> Dict[str, np.ndarray]:
        # Fold (step, host) into the seed; each call regenerates deterministically.
        s = seed + 1_000_003 * step + 7919 * host_id
        toks = markov_corpus(vocab, seq_len, local, seed=s)
        return {"tokens": toks}

    return batch_fn


class HostDataLoader:
    """Bounded background prefetcher around a ``batch_fn(step)``.

    ``depth`` batches are produced ahead of consumption on a daemon thread. ``stop()``
    is idempotent; the loader is also a context manager. On worker failure the
    supervisor recreates the loader at the restored step — no stream state to rescue.
    """

    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self._fn = batch_fn
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self._fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple]:
        return self

    def __next__(self) -> tuple:
        if self._stop.is_set():
            raise StopIteration
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        # Drain so the worker unblocks.
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)

    def __enter__(self) -> "HostDataLoader":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
