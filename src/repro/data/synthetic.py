"""Deterministic synthetic data generators.

Two kinds of data drive the reproduction (DESIGN.md §5.2 — no pretrained 7B–70B
checkpoints offline, so we reproduce the paper's *phenomena* rather than its absolute
perplexities):

1. **Markov corpus** — token sequences from a fixed sparse first-order Markov chain.
   Small models trained on it reach low perplexity quickly, giving a real model whose
   activations (and quantized-accuracy deltas) the paper's benchmarks can measure.

2. **Outlier-planted activation ensembles** — activation matrices X (T × I) matching
   the outlier statistics the paper builds on (App. A / Dettmers et al. 2022): ~0.1 %
   of channels carry values ≥20× the typical magnitude, emerging past the 6.7B scale.
   The OPT-like regime plants stronger/more outliers than the LLaMA-like regime,
   reproducing the paper's OPT (43 % per-token kernel) vs LLaMA (11 %) split.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


# --------------------------------------------------------------------------------------
# Markov-chain corpus
# --------------------------------------------------------------------------------------

def _chain(vocab: int, branching: int, seed: int) -> np.ndarray:
    """Sparse transition table: each token can be followed by `branching` tokens."""
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, size=(vocab, branching))
    return nxt


def markov_corpus(vocab: int, seq_len: int, n_seqs: int, *, branching: int = 4,
                  seed: int = 0, skew: float = 0.0, chain_seed: int = 0) -> np.ndarray:
    """(n_seqs, seq_len) int32 token array, deterministic in ``seed``.

    The transition table depends only on ``chain_seed`` — batches drawn with
    different ``seed`` values sample the SAME language (otherwise there is nothing
    stable to learn). ``skew`` > 0 biases transitions toward each token's first
    successor with probability ``skew`` (rest uniform), giving the corpus a
    predictable mode so top-1 next-token accuracy is a meaningful metric."""
    nxt = _chain(vocab, branching, chain_seed)
    rng = np.random.default_rng(seed + 1)
    out = np.empty((n_seqs, seq_len), np.int32)
    tok = rng.integers(0, vocab, size=n_seqs)
    for t in range(seq_len):
        out[:, t] = tok
        if skew > 0:
            take_mode = rng.random(n_seqs) < skew
            pick = np.where(take_mode, 0, rng.integers(0, branching, size=n_seqs))
        else:
            pick = rng.integers(0, branching, size=n_seqs)
        tok = nxt[tok, pick]
    return out


# --------------------------------------------------------------------------------------
# Outlier-planted activation ensembles (App. A statistics)
# --------------------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OutlierSpec:
    """Statistics of the planted outlier channels.

    ``frac_channels``: fraction of channels that are outlier channels (paper: ~0.1 %).
    ``magnitude``: outlier scale relative to the base std (paper: ≥20×).
    ``row_frac``: fraction of rows (tokens) in which an outlier channel actually fires
    (outliers are token-dependent in real models, not constant columns).
    """
    frac_channels: float = 0.001
    magnitude: float = 40.0
    row_frac: float = 0.7
    base_std: float = 1.0


# Regimes matching the paper's two model families (Fig. 4): OPT activations carry
# many/strong outliers (→ per-token kernel 40–55 %); LLaMA's are milder (→ ~11 %).
OPT_LIKE = OutlierSpec(frac_channels=0.004, magnitude=80.0, row_frac=0.9)
LLAMA_LIKE = OutlierSpec(frac_channels=0.001, magnitude=20.0, row_frac=0.3)


def outlier_activations(n_tokens: int, n_channels: int, spec: OutlierSpec = OPT_LIKE,
                        *, seed: int = 0, laplace: bool = True) -> np.ndarray:
    """(T, I) float32 activation matrix with planted outlier channels.

    Base values are Laplace-distributed (heavy-ish tails, like real pre-GEMM
    activations); outlier channels get ``magnitude``× values on ``row_frac`` of rows.
    """
    rng = np.random.default_rng(seed)
    if laplace:
        x = rng.laplace(0.0, spec.base_std / np.sqrt(2), size=(n_tokens, n_channels))
    else:
        x = rng.normal(0.0, spec.base_std, size=(n_tokens, n_channels))
    n_out = max(1, int(round(spec.frac_channels * n_channels)))
    out_ch = rng.choice(n_channels, size=n_out, replace=False)
    fire = rng.random((n_tokens, n_out)) < spec.row_frac
    boost = rng.normal(0.0, spec.base_std * spec.magnitude, size=(n_tokens, n_out))
    x[:, out_ch] = np.where(fire, boost, x[:, out_ch])
    return x.astype(np.float32)


def calibration_set(n_batches: int, n_tokens: int, n_channels: int,
                    spec: OutlierSpec = OPT_LIKE, *, seed: int = 0
                    ) -> Iterator[np.ndarray]:
    for b in range(n_batches):
        yield outlier_activations(n_tokens, n_channels, spec, seed=seed + 17 * b)
