"""Data substrate: deterministic synthetic corpora, outlier-planted activation
ensembles (the paper's App. A statistics), and a shardable host loader with prefetch."""
from repro.data.synthetic import (  # noqa: F401
    markov_corpus, outlier_activations, OutlierSpec,
)
from repro.data.pipeline import HostDataLoader, make_train_batches  # noqa: F401
