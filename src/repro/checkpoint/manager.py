"""Async sharded checkpoint manager.

Layout (one directory per step):

    ckpt_dir/
      step_000042/
        manifest.json        # leaf paths, shapes, dtypes, content hashes, step
        arrays.npz           # flattened { "a/b/0/w": array } archive
      step_000042.tmp/       # staging dir — renamed atomically on commit
      LATEST                 # text file naming the last committed step

Design points that matter at cluster scale (kept in the single-host edition):

* **Atomic commit** — writes land in ``.tmp``, the manifest is written last, and the
  directory is renamed into place; a crash mid-write can never leave a half-readable
  checkpoint that LATEST points to.
* **Async save** — ``save()`` snapshots to host RAM (device_get) and hands the disk
  I/O to a writer thread; training resumes immediately. ``wait()`` joins outstanding
  writes (called before exit and by tests).
* **Integrity** — every leaf carries a content hash (crc via np) checked on restore.
* **Elastic restore** — arrays are saved unsharded-logical (host-gathered); restore
  takes target ``shardings`` for *any* mesh and lays the arrays out via
  ``jax.device_put``. Changing dp/tp between runs needs no reshard tool.
* **keep_n GC** — old committed steps beyond the retention window are deleted after a
  successful commit, never before.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Dict, List, Optional

import jax
import numpy as np


def jnp_dtype(name: str):
    """np.dtype for a manifest dtype string, including ml_dtypes extras."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            parts.append(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)))
        flat["/".join(parts)] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        parts = []
        for p in path:
            parts.append(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)))
        key = "/".join(parts)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._pending: List[threading.Thread] = []
        self._lock = threading.Lock()

    # -- save --------------------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot ``tree`` at ``step``. Async by default."""
        flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
        t = threading.Thread(target=self._write, args=(step, flat), daemon=True)
        with self._lock:
            self._pending.append(t)
        t.start()
        if blocking:
            t.join()

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "leaves": {
                k: {
                    "shape": list(v.shape),
                    "dtype": str(v.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
                } for k, v in flat.items()
            },
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)                       # atomic commit
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                name = f.read().strip()
            if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                return int(name[5:])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, *, step: Optional[int] = None,
                shardings=None, verify: bool = True):
        """Load into the structure of ``template``. ``shardings``: matching pytree of
        NamedSharding (any mesh) → arrays are device_put against it (elastic restore);
        None → host numpy arrays."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        # npz stores ml_dtypes (bfloat16, ...) as raw void records; re-view them
        # using the dtype recorded in the manifest.
        for k, meta in manifest["leaves"].items():
            want = meta["dtype"]
            if str(flat[k].dtype) != want and flat[k].dtype.kind == "V":
                flat[k] = flat[k].view(jnp_dtype(want))
        if verify:
            for k, meta in manifest["leaves"].items():
                got = zlib.crc32(np.ascontiguousarray(flat[k]).tobytes())
                if got != meta["crc32"]:
                    raise IOError(f"checkpoint corruption at leaf {k!r} "
                                  f"(crc {got} != {meta['crc32']})")
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step
