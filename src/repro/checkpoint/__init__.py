"""Checkpoint substrate: async sharded checkpoints with atomic manifests and
elastic (mesh-changing) restore."""
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
