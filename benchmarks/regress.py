"""Bench regression gate: compare a fresh BENCH snapshot against baselines.

CI runs this after the quick bench pass (``.github/workflows/ci.yml``): the fresh
``results/BENCH_ci.json`` is compared against the committed
``results/BENCH_run.json`` baseline and, when the artifact download succeeded,
against the previous main-branch run's snapshot. The gate fails (exit 1) with a
readable per-row diff when continuous-batching serving throughput (tok/s) or slot
occupancy drops more than ``--max-drop`` (default 15%) versus a baseline.

What gates, against what:

* Only ``scheduler=continuous`` rows gate; grouped-baseline rows and ``@tpN``
  sharded twins (emulated-collective-bound wall-clock) are informational.
* Shared-prefix rows (``serving_bench_prefix`` — DESIGN.md §3.8): paged-layout
  rows gate on prefix **hit rate** against every baseline (a deterministic
  indexing invariant, like occupancy) and on paged **tok/s** against
  same-runner baselines; dense rows are informational. The **paged/dense
  tok/s ratio** per path also gates, on same-runner baselines: it catches the
  paged layout sliding back toward the gather-per-step regime the in-kernel
  paged decode removed. Against cross-machine baselines the ratio reports
  informationally — it is same-run relative, but both its noise floor and the
  interpret-mode kernel overhead are machine-dependent.
* Scheduler invariant (new snapshot only, no baseline needed): continuous
  tok/s must be ≥ grouped tok/s for every non-``@tpN`` path — the slot-table
  batcher exists to beat drain-to-completion grouping, and the one measured
  inversion (fused-int8+kv8) came from the decode step copying the whole
  4-leaf int8-KV cache every token (fixed by buffer donation,
  ``serving/engine.py``).
* Paged/dense floor (new snapshot only): the fp prefix paged/dense tok/s
  ratio must be ≥ 0.90 — the level the reference-execution kernel dispatch
  (``REPRO_KERNEL_EXEC=ref``) recovered; ``chunked`` layout rows are
  informational.
* Burst-latency invariant (new snapshot only — step latencies never compare
  across machines): per path, chunked p95 step latency under an admission
  burst must not exceed unchunked p95 (``serving_bench_latency`` rows,
  DESIGN.md §3.10). Baselines without latency rows predate the schema bump.
* Async-server invariant (new snapshot only — both checks are same-run
  comparisons): the prefix-affinity router's fleet hit rate must be ≥ the
  seeded-random router's at steady load, steady runs must not reject, and the
  overload run must (``serving_bench_server`` rows, DESIGN.md §3.11).
  Baselines without server rows predate the schema bump.
* Config-zoo invariant (new snapshot only — same-run scheduler pair): the
  mamba2 ``serving_bench_zoo`` rows must hold continuous ≥ grouped tok/s —
  the §3.13 state-page scheduler replaced exact-length grouping for SSM
  families and must not cost throughput doing it. The granite-moe
  (``@ep2``) rows are informational, like the ``@tpN`` twins. Baselines
  without zoo rows predate the schema bump.
* Block-sparse kernel invariant (new snapshot only — same-run timing pair):
  on every ``qgemm_sparse`` row with occupancy < 1, the §3.12 sparse kernel's
  wall-clock must not exceed the dense kernel's — skipping all-zero K-blocks
  is the kernel's whole claim. The occupancy=1.00 row (bookkeeping overhead)
  is informational. Pre-sparsity snapshots have no rows and skip.
* Sparse pruning ppl gate: in the first snapshot carrying ``table2_ppl`` rows
  (the fresh one on a full pass, else the committed baseline — the CI quick
  lane's ``--only`` pass doesn't re-run table2), the plan-gated
  ``crossquant_w8a8_sparse24`` ppl must stay within ``SPARSE_PPL_CEILING`` of
  the dense ``crossquant_w8a8`` row per regime.
* A snapshot without usable ``serving_bench`` rows — module missing, its
  subprocess failed (``ok: false``), or no data lines — is an **error**, for
  baselines too: a partial ``--only`` run that dropped the serving module must
  fail the gate, not pass it silently.
* ``--baseline`` gates tok/s *and* occupancy — use it for snapshots from the
  same runner class (the previous main-branch CI artifact).
* ``--occupancy-baseline`` gates occupancy only — use it for the committed
  dev-machine snapshot: occupancy is a scheduling invariant and
  machine-independent, but comparing a CI runner's wall-clock against a dev
  box's is a systematic hardware diff no threshold absorbs (its tok/s rows are
  still printed, informationally).

    PYTHONPATH=src python -m benchmarks.regress results/BENCH_ci.json \
        --occupancy-baseline results/BENCH_run.json \
        [--baseline prev/BENCH_ci.json] [--max-drop 0.15]
"""

from __future__ import annotations

import argparse
import json
import sys


def serving_rows(snapshot: dict) -> dict:
    """``(path, scheduler) -> {"tok_s": float, "occupancy": float}`` from the
    ``serving_bench`` CSV lines of a BENCH snapshot."""
    rows = {}
    lines = snapshot.get("modules", {}).get("serving_bench", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 5 or parts[0] != "serving_bench" or parts[1] == "path":
            continue
        rows[(parts[1], parts[2])] = {
            "tok_s": float(parts[3]),
            "occupancy": float(parts[4]),
        }
    return rows


def check_complete(snapshot: dict, label: str) -> list:
    """Errors that make a snapshot unusable for serving gates: a missing /
    failed / empty ``serving_bench`` module. Returned as failure lines."""
    mod = snapshot.get("modules", {}).get("serving_bench")
    if mod is None:
        return [f"  {label}: incomplete snapshot — no serving_bench module"]
    if not mod.get("ok", False):
        return [f"  {label}: incomplete snapshot — serving_bench failed (ok: false)"]
    if not serving_rows(snapshot):
        return [f"  {label}: incomplete snapshot — serving_bench has no data rows"]
    return []


def scheduler_invariant(rows: dict) -> tuple[list, list]:
    """continuous tok/s ≥ grouped tok/s per path (new snapshot only; ``@tpN``
    twins are emulated-collective-bound and never gate)."""
    report, failures = [], []
    for path in sorted({p for p, _ in rows}):
        if "@" in path:
            continue
        g, c = rows.get((path, "grouped")), rows.get((path, "continuous"))
        if not g or not c:
            continue
        line = f"  {path}: continuous {c['tok_s']:.1f} vs grouped {g['tok_s']:.1f} tok/s"
        if c["tok_s"] < g["tok_s"]:
            line += "  REGRESSION (continuous < grouped)"
            failures.append(line)
        report.append(line)
    return report, failures


def prefix_rows(snapshot: dict) -> dict:
    """``(path, layout) -> {"tok_s", "hit_rate"}`` from the shared-prefix
    section (``serving_bench_prefix`` lines — DESIGN.md §3.8)."""
    rows = {}
    lines = snapshot.get("modules", {}).get("serving_bench", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 5 or parts[0] != "serving_bench_prefix" or parts[1] == "path":
            continue
        rows[(parts[1], parts[2])] = {
            "tok_s": float(parts[3]),
            "hit_rate": float(parts[4]),
        }
    return rows


def compare_prefix(
    new: dict, base: dict, max_drop: float, tag: str, wall_clock: bool
) -> tuple[list, list]:
    """Shared-prefix gates: paged-layout rows gate on prefix hit rate (a
    scheduling/indexing invariant, machine-independent — gated against every
    baseline) and on paged tok/s (wall-clock baselines only). Dense rows are
    informational. The paged/dense tok/s *ratio* per path also gates on
    wall-clock (same-runner) baselines — it is same-run relative, but its
    noise floor tracks the machine's interference profile and the interpret
    overhead differs systematically across hardware, so against cross-machine
    baselines it reports informationally like absolute tok/s."""
    report, failures = [], []
    for path in sorted({p for p, _ in base}):
        pairs = []
        for rows in (base, new):
            d, pg = rows.get((path, "dense")), rows.get((path, "paged"))
            ratio = pg["tok_s"] / d["tok_s"] if d and pg and d["tok_s"] > 0 else None
            pairs.append(ratio)
        b_ratio, n_ratio = pairs
        if b_ratio is None or n_ratio is None:
            continue
        drop = 1.0 - n_ratio / b_ratio
        line = (
            f"  prefix {path} paged/dense ratio: {b_ratio:.2f} -> {n_ratio:.2f} "
            f"({-drop:+.1%} vs {tag})"
        )
        if wall_clock and drop > max_drop:
            line += f"  REGRESSION (>{max_drop:.0%} drop)"
            failures.append(line)
        report.append(line)
    for key in sorted(base):
        path, layout = key
        if key not in new:
            report.append(f"  prefix {path}/{layout}: missing from new snapshot (skip)")
            continue
        for metric in ("hit_rate", "tok_s"):
            b, n = base[key][metric], new[key][metric]
            if b <= 0:
                continue
            drop = 1.0 - n / b
            line = (
                f"  prefix {path}/{layout} {metric}: {b:.2f} -> {n:.2f} "
                f"({-drop:+.1%} vs {tag})"
            )
            gate = (
                layout == "paged"
                and (wall_clock or metric == "hit_rate")
                and drop > max_drop
            )
            if gate:
                line += f"  REGRESSION (>{max_drop:.0%} drop)"
                failures.append(line)
            report.append(line)
    return report, failures


def prefix_ratio_floor(rows: dict) -> tuple[list, list]:
    """Same-snapshot paged/dense tok/s floor (no baseline needed): the fp
    paged row must hold ≥ 0.90 of dense throughput. The fp ratio sat at
    ~0.76 while the off-TPU bench timed the Pallas interpret emulation of
    the paged decode kernel; the bench now serves through the XLA reference
    execution (``REPRO_KERNEL_EXEC=ref``, kernels/ops.py), and the floor
    pins the recovered gap so it cannot silently reopen — a paged row
    sliding back under it means either the emulator crept back onto the
    serving path or the paged stack regressed structurally. int8 paths
    report informationally (the relative gates cover them). ``chunked``
    rows never gate here: their tok/s-vs-jitter tradeoff is gated in the
    latency section instead."""
    floor = 0.90
    report, failures = [], []
    for path in sorted({p for p, _ in rows}):
        if "@" in path:
            continue
        d, pg = rows.get((path, "dense")), rows.get((path, "paged"))
        if not d or not pg or d["tok_s"] <= 0:
            continue
        ratio = pg["tok_s"] / d["tok_s"]
        line = f"  prefix {path} paged/dense ratio {ratio:.2f} (floor {floor:.2f})"
        if path == "fp" and ratio < floor:
            line += "  REGRESSION (below floor)"
            failures.append(line)
        report.append(line)
    return report, failures


def latency_rows(snapshot: dict) -> dict:
    """``(path, mode, phase) -> {"p50", "p95", "ttft"}`` from the latency
    section (``serving_bench_latency`` lines — DESIGN.md §3.10). Empty for
    pre-chunked snapshots (schema bump, like ``spec_rows``)."""
    rows = {}
    lines = snapshot.get("modules", {}).get("serving_bench", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 7 or parts[0] != "serving_bench_latency" or parts[1] == "path":
            continue
        rows[(parts[1], parts[2], parts[3])] = {
            "p50": float(parts[4]),
            "p95": float(parts[5]),
            "ttft": float(parts[6]),
        }
    return rows


def latency_invariant(rows: dict) -> tuple[list, list]:
    """Same-snapshot latency gate (no baseline needed — step latencies are
    machine wall-clock, never comparable across runners): under an admission
    burst, chunked p95 step latency must not exceed unchunked p95. Bounding
    that spike is the point of the token-budget scheduler — an unchunked
    refill stalls every in-flight decode behind a whole-prompt prefill
    launch. Steady-phase rows and TTFT report informationally."""
    report, failures = [], []
    for path in sorted({p for p, _, _ in rows}):
        c = rows.get((path, "chunked", "burst"))
        u = rows.get((path, "unchunked", "burst"))
        if not c or not u:
            continue
        line = (
            f"  {path} burst p95: chunked {c['p95']:.2f} ms vs "
            f"unchunked {u['p95']:.2f} ms "
            f"(ttft {c['ttft']:.1f} vs {u['ttft']:.1f} ms)"
        )
        if c["p95"] > u["p95"]:
            line += "  REGRESSION (chunked p95 > unchunked under burst)"
            failures.append(line)
        report.append(line)
    return report, failures


def server_rows(snapshot: dict) -> dict:
    """``(router, load) -> {"reject_rate", "hit_rate"}`` from the async-server
    section (``serving_bench_server`` lines — DESIGN.md §3.11). Empty for
    snapshots predating the server (schema bump, like ``spec_rows``)."""
    rows = {}
    lines = snapshot.get("modules", {}).get("serving_bench", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 10 or parts[0] != "serving_bench_server" or parts[1] == "path":
            continue
        rows[(parts[2], parts[3])] = {
            "reject_rate": float(parts[8]),
            "hit_rate": float(parts[9]),
        }
    return rows


def server_invariant(rows: dict) -> tuple[list, list]:
    """Same-snapshot async-server gates (no baseline needed — both are
    same-run comparisons under the bench's paused-fleet submission, so they
    never depend on machine speed): at steady offered load the
    prefix-affinity router's fleet hit rate must be ≥ the seeded-random
    router's — routing a prefix family back to the replica whose radix index
    holds it is the policy's whole claim — and neither steady run may reject
    (the admission queue is sized for the workload; a steady reject means
    backpressure fired spuriously). The overload run must reject at least one
    request — a zero rate there means the bounded queue silently stopped
    bounding. Latency columns report in the snapshot only (CPU wall-clock).
    Pre-server snapshots have no rows and skip informationally."""
    report, failures = [], []
    a = rows.get(("affinity", "steady"))
    r = rows.get(("random", "steady"))
    if a and r:
        line = (
            f"  steady hit rate: affinity {a['hit_rate']:.3f} vs "
            f"random {r['hit_rate']:.3f}"
        )
        if a["hit_rate"] < r["hit_rate"]:
            line += "  REGRESSION (affinity < random)"
            failures.append(line)
        report.append(line)
        for router, row in (("affinity", a), ("random", r)):
            if row["reject_rate"] > 0.0:
                line = (
                    f"  steady {router} reject rate {row['reject_rate']:.3f}"
                    "  REGRESSION (rejects at steady load)"
                )
                failures.append(line)
                report.append(line)
    o = rows.get(("affinity", "overload"))
    if o:
        line = f"  overload reject rate: {o['reject_rate']:.3f}"
        if o["reject_rate"] <= 0.0:
            line += "  REGRESSION (bounded queue never rejected)"
            failures.append(line)
        report.append(line)
    return report, failures


def zoo_rows(snapshot: dict) -> dict:
    """``(config, mode) -> {"tok_s", "occupancy"}`` from the config-zoo
    section (``serving_bench_zoo`` lines — DESIGN.md §3.13). Empty for
    pre-zoo snapshots (schema bump, like ``spec_rows``)."""
    rows = {}
    lines = snapshot.get("modules", {}).get("serving_bench", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 5 or parts[0] != "serving_bench_zoo" or parts[1] == "config":
            continue
        rows[(parts[1], parts[2])] = {
            "tok_s": float(parts[3]),
            "occupancy": float(parts[4]),
        }
    return rows


def zoo_invariant(rows: dict) -> tuple[list, list]:
    """Same-snapshot config-zoo gate (no baseline needed — the two schedulers'
    interleaved passes sample the same machine): per non-meshed zoo config with
    both scheduler rows, continuous tok/s must be ≥ grouped — the slot-table
    scheduler with state pages and masked-dt padded prefill replaced the
    exact-length grouping that was the only way to serve SSM families, and it
    must not cost throughput against what it replaced. MoE/``@ep2`` rows (no
    grouped twin) report informationally."""
    report, failures = [], []
    for config in sorted({c for c, _ in rows}):
        g = rows.get((config, "grouped"))
        c = rows.get((config, "continuous"))
        if not g or not c:
            r = c or g
            if r:
                mode = "continuous" if c else "grouped"
                report.append(f"  zoo {config}/{mode}: {r['tok_s']:.1f} tok/s "
                              f"(occupancy {r['occupancy']:.2f}, informational)")
            continue
        line = (f"  zoo {config}: continuous {c['tok_s']:.1f} vs "
                f"grouped {g['tok_s']:.1f} tok/s "
                f"(occupancy {c['occupancy']:.2f} vs {g['occupancy']:.2f})")
        if c["tok_s"] < g["tok_s"]:
            line += "  REGRESSION (continuous < grouped)"
            failures.append(line)
        report.append(line)
    return report, failures


def sparse_kernel_rows(snapshot: dict) -> dict:
    """``occupancy -> {"dense_us", "sparse_us"}`` from the block-sparse kernel
    section (``qgemm_sparse`` lines in the ``qgemm_bench`` module — DESIGN.md
    §3.12). Empty for pre-sparsity snapshots (schema bump, like spec_rows)."""
    rows = {}
    lines = snapshot.get("modules", {}).get("qgemm_bench", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 5 or parts[0] != "qgemm_sparse" or parts[1] == "occupancy":
            continue
        rows[float(parts[1])] = {
            "dense_us": float(parts[2]),
            "sparse_us": float(parts[3]),
        }
    return rows


def sparse_kernel_invariant(rows: dict) -> tuple[list, list]:
    """Same-snapshot block-sparse kernel gate (no baseline needed — both
    timings come from the same run on the same machine): on every
    skipped-block row (occupancy < 1) the sparse kernel must not lose to the
    dense kernel — skipping all-zero K-blocks is the kernel's whole claim,
    and in interpret mode the gated dots are genuinely not executed. The
    occupancy=1.00 row reports the bookkeeping overhead informationally (the
    ops wrapper routes full-occupancy inputs to the dense kernel at runtime,
    so production never pays it on dense traffic)."""
    report, failures = [], []
    for occ in sorted(rows, reverse=True):
        r = rows[occ]
        line = (f"  sparse occ={occ:.2f}: sparse {r['sparse_us']:.0f}us vs "
                f"dense {r['dense_us']:.0f}us")
        if occ < 1.0 and r["sparse_us"] > r["dense_us"]:
            line += "  REGRESSION (sparse slower on skipped-block workload)"
            failures.append(line)
        report.append(line)
    return report, failures


def table2_rows(snapshot: dict) -> dict:
    """``(regime, method) -> ppl`` from the ``table2_ppl`` module. Empty when
    the snapshot never ran table2 (e.g. the CI quick lane's ``--only`` pass)."""
    rows = {}
    lines = snapshot.get("modules", {}).get("table2_ppl", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 4 or parts[0] != "table2" or parts[1] == "regime":
            continue
        rows[(parts[1], parts[2])] = float(parts[3])
    return rows


# 2:4 pruning may cost at most this fraction of ppl over dense CrossQuant W8A8
# per regime — measured headroom: the plan-gated bench rows sit ~1-2% over
# dense, so 10% both absorbs eval noise and still catches a mis-scored mask
# (unweighted or inverted scores blow ppl up by far more than this).
SPARSE_PPL_CEILING = 0.10


def sparse_ppl_gate(snapshots: list) -> tuple[list, list]:
    """Plan-gated pruning quality gate: in the first snapshot that carries
    table2 rows (the fresh one when a full pass ran; otherwise the committed
    baseline — the CI quick lane's ``--only`` pass doesn't re-run table2),
    the ``crossquant_w8a8_sparse24`` ppl must stay within
    ``SPARSE_PPL_CEILING`` of the dense ``crossquant_w8a8`` row per regime.
    No snapshot with table2 rows at all → informational skip (pre-sparsity
    baselines)."""
    for tag, snapshot in snapshots:
        rows = table2_rows(snapshot)
        pairs = [(regime, rows[(regime, "crossquant_w8a8")], ppl)
                 for (regime, method), ppl in sorted(rows.items())
                 if method == "crossquant_w8a8_sparse24"
                 and (regime, "crossquant_w8a8") in rows]
        if pairs:
            report, failures = [], []
            for regime, dense, sp in pairs:
                delta = sp / dense - 1.0
                line = (f"  {regime}: sparse24 ppl {sp:.3f} vs dense {dense:.3f} "
                        f"({delta:+.1%}, ceiling {SPARSE_PPL_CEILING:.0%}, "
                        f"from {tag})")
                if delta > SPARSE_PPL_CEILING:
                    line += "  REGRESSION (pruning ppl cost above ceiling)"
                    failures.append(line)
                report.append(line)
            return report, failures
    return ["  (no table2 sparse rows in any snapshot, skip)"], []


def spec_rows(snapshot: dict) -> dict:
    """``(path, mode) -> {"tok_s", "accept_rate", "tokens_per_step"}`` from the
    speculative section (``serving_bench_spec`` lines — DESIGN.md §3.9).
    Empty for pre-speculative snapshots (schema bump: the section was added
    with the speculative-decoding PR) — callers treat that as "no spec gates",
    not as an incomplete snapshot."""
    rows = {}
    lines = snapshot.get("modules", {}).get("serving_bench", {}).get("lines", [])
    for line in lines:
        parts = line.split(",")
        if len(parts) < 6 or parts[0] != "serving_bench_spec" or parts[1] == "path":
            continue
        rows[(parts[1], parts[2])] = {
            "tok_s": float(parts[3]),
            "accept_rate": float(parts[4]),
            "tokens_per_step": float(parts[5]),
        }
    return rows


def spec_invariant(rows: dict) -> tuple[list, list]:
    """Same-snapshot speculative gates (no baseline needed): per path,
    ``spec`` tok/s must be ≥ ``nospec`` tok/s — on the repetition-heavy bench
    workload a verify step amortizes over ~2-3 emitted tokens, so speculation
    losing to plain decode means the verify path got expensive or acceptance
    collapsed — and the draft acceptance rate must be positive (a zero rate
    means the drafter never landed a token and the tok/s row silently measures
    pure overhead)."""
    report, failures = [], []
    for path in sorted({p for p, _ in rows}):
        s, n = rows.get((path, "spec")), rows.get((path, "nospec"))
        if not s or not n:
            continue
        line = (
            f"  {path}: spec {s['tok_s']:.1f} vs nospec {n['tok_s']:.1f} tok/s "
            f"(accept {s['accept_rate']:.2f}, {s['tokens_per_step']:.2f} tok/step)"
        )
        if s["tok_s"] < n["tok_s"]:
            line += "  REGRESSION (spec < nospec)"
            failures.append(line)
        if s["accept_rate"] <= 0.0:
            line += "  REGRESSION (zero acceptance)"
            failures.append(line)
        report.append(line)
    return report, failures


def compare_spec(
    new: dict, base: dict, max_drop: float, tag: str, wall_clock: bool
) -> tuple[list, list]:
    """Speculative gates against a baseline: ``spec`` rows gate on **accept
    rate** (deterministic drafter/workload invariant, machine-independent —
    every baseline) and on tok/s against same-runner baselines only, mirroring
    the prefix section. A baseline without spec rows predates the schema bump
    and reports informationally instead of failing."""
    report, failures = [], []
    if new and not base:
        skip = f"  spec: no serving_bench_spec rows in {tag} (pre-speculative baseline, skip)"
        return [skip], []
    for key in sorted(base):
        path, mode = key
        if key not in new:
            report.append(f"  spec {path}/{mode}: missing from new snapshot (skip)")
            continue
        for metric in ("accept_rate", "tok_s"):
            b, n = base[key][metric], new[key][metric]
            if b <= 0:
                continue
            drop = 1.0 - n / b
            line = f"  spec {path}/{mode} {metric}: {b:.2f} -> {n:.2f} ({-drop:+.1%} vs {tag})"
            gate = (
                mode == "spec"
                and (wall_clock or metric == "accept_rate")
                and drop > max_drop
            )
            if gate:
                line += f"  REGRESSION (>{max_drop:.0%} drop)"
                failures.append(line)
            report.append(line)
    return report, failures


def compare(
    new: dict, base: dict, max_drop: float, tag: str, wall_clock: bool
) -> tuple[list, list]:
    """Readable diff lines + gating failures for one baseline.

    ``wall_clock=False`` reports tok/s but never gates on it (cross-machine
    baseline). ``@tpN`` rows never gate (sharded twins measure that the path
    serves, not speed)."""
    report, failures = [], []
    for key in sorted(base):
        path, scheduler = key
        if key not in new:
            report.append(f"  {path}/{scheduler}: missing from new snapshot (skip)")
            continue
        for metric in ("tok_s", "occupancy"):
            b, n = base[key][metric], new[key][metric]
            if b <= 0:
                continue
            drop = 1.0 - n / b
            line = (
                f"  {path}/{scheduler} {metric}: {b:.2f} -> {n:.2f} "
                f"({-drop:+.1%} vs {tag})"
            )
            gate = (
                scheduler == "continuous"
                and "@" not in path
                and (wall_clock or metric == "occupancy")
                and drop > max_drop
            )
            if gate:
                line += f"  REGRESSION (>{max_drop:.0%} drop)"
                failures.append(line)
            report.append(line)
    return report, failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh BENCH_*.json snapshot")
    ap.add_argument(
        "--baseline",
        action="append",
        default=[],
        metavar="PATH",
        help="same-runner-class baseline (gates tok/s + occupancy); repeatable",
    )
    ap.add_argument(
        "--occupancy-baseline",
        action="append",
        default=[],
        metavar="PATH",
        help="cross-machine baseline (gates occupancy only); repeatable",
    )
    ap.add_argument("--max-drop", type=float, default=0.15)
    args = ap.parse_args()
    if not args.baseline and not args.occupancy_baseline:
        ap.error("need at least one --baseline / --occupancy-baseline")

    with open(args.new) as fh:
        new_snapshot = json.load(fh)
    new = serving_rows(new_snapshot)
    new_prefix = prefix_rows(new_snapshot)
    all_failures = check_complete(new_snapshot, args.new)
    if all_failures:
        print("\n".join(all_failures))
        sys.exit(1)

    inv_report, inv_failures = scheduler_invariant(new)
    print("scheduler invariant (continuous >= grouped):")
    print("\n".join(inv_report) if inv_report else "  (no paired rows)")
    all_failures += inv_failures

    new_spec = spec_rows(new_snapshot)
    s_report, s_failures = spec_invariant(new_spec)
    print("speculative invariant (spec >= nospec tok/s, accept > 0):")
    print("\n".join(s_report) if s_report else "  (no spec rows)")
    all_failures += s_failures

    f_report, f_failures = prefix_ratio_floor(new_prefix)
    print("paged/dense ratio floor (fp >= 0.90, ref-exec paged serving):")
    print("\n".join(f_report) if f_report else "  (no prefix rows)")
    all_failures += f_failures

    l_report, l_failures = latency_invariant(latency_rows(new_snapshot))
    print("burst latency invariant (chunked p95 <= unchunked p95):")
    print("\n".join(l_report) if l_report else "  (no latency rows)")
    all_failures += l_failures

    sv_report, sv_failures = server_invariant(server_rows(new_snapshot))
    print("async-server invariant (affinity >= random hit rate, overload rejects):")
    print("\n".join(sv_report) if sv_report else "  (no server rows)")
    all_failures += sv_failures

    z_report, z_failures = zoo_invariant(zoo_rows(new_snapshot))
    print("config-zoo invariant (SSM continuous >= grouped tok/s):")
    print("\n".join(z_report) if z_report else "  (no zoo rows)")
    all_failures += z_failures

    sk_report, sk_failures = sparse_kernel_invariant(
        sparse_kernel_rows(new_snapshot))
    print("block-sparse kernel invariant (sparse <= dense at occupancy < 1):")
    print("\n".join(sk_report) if sk_report else "  (no qgemm_sparse rows)")
    all_failures += sk_failures

    baselines = [(p, True) for p in args.baseline] + [
        (p, False) for p in args.occupancy_baseline
    ]
    loaded = [(args.new, new_snapshot)]
    for path, wall_clock in baselines:
        try:
            with open(path) as fh:
                base_snapshot = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            # an unreadable baseline is the same failure mode as a partial one
            # (check_complete below): it must fail the gate, not shrink it
            line = f"  {path}: unreadable baseline ({e})"
            print(line)
            all_failures.append(line)
            continue
        incomplete = check_complete(base_snapshot, path)
        if incomplete:
            # an overwritten/partial baseline must fail the gate, not skip it
            print("\n".join(incomplete))
            all_failures += incomplete
            continue
        loaded.append((path, base_snapshot))
        base = serving_rows(base_snapshot)
        scope = (
            "tok/s + occupancy + prefix + spec"
            if wall_clock
            else "occupancy + prefix + spec accept"
        )
        report, failures = compare(new, base, args.max_drop, path, wall_clock)
        p_report, p_failures = compare_prefix(
            new_prefix, prefix_rows(base_snapshot), args.max_drop, path, wall_clock
        )
        report += p_report
        failures += p_failures
        sp_report, sp_failures = compare_spec(
            new_spec, spec_rows(base_snapshot), args.max_drop, path, wall_clock
        )
        report += sp_report
        failures += sp_failures
        print(f"vs {path} (gating {scope}):")
        print("\n".join(report) if report else "  (no comparable rows)")
        all_failures += failures

    pp_report, pp_failures = sparse_ppl_gate(loaded)
    print(f"sparse pruning ppl gate (sparse24 within {SPARSE_PPL_CEILING:.0%} "
          "of dense crossquant, first snapshot with table2 rows):")
    print("\n".join(pp_report))
    all_failures += pp_failures

    if all_failures:
        print(f"\nFAIL: {len(all_failures)} regression(s) beyond {args.max_drop:.0%}:")
        print("\n".join(all_failures))
        sys.exit(1)
    print("\nbench regression gate: OK")


if __name__ == "__main__":
    main()
