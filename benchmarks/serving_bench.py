"""Continuous-batching serving benchmark (DESIGN.md §3.6 / §7).

Serves one *mixed-length* workload (three prompt lengths, staggered ``max_new`` —
the realistic occupancy case that equal-length grouping cannot batch well) through
both schedulers of ``serving/engine.py``:

* ``grouped``    — the pre-§3.6 baseline: equal-exact-length groups, each drained
                   to completion before the next starts.
* ``continuous`` — slot-table batcher: length-bucketed padded prefill into free
                   slots, retirement + refill mid-decode, per-slot ``cur_len``.

Reported per (path × scheduler): tokens/sec, slot occupancy (active-slot decode
steps / total decode-step slots) and mid-decode refill count. CPU wall-clock —
the structural win is occupancy; the kernel-level TPU projection lives in
``qgemm_bench``. Paths: fp baseline and the fused int8 kernels (+ int8 KV cache
in the full pass). Every tok/s figure is the best of ``TIMED_PASSES``
interleaved serves (grouped/continuous, and dense/paged in the prefix section,
alternate passes) — the gated comparisons are ratios between rows, and on a
shared runner a single ~1 s serve is hostage to whichever interference window
it lands in.

On hosts exposing ≥ 2 devices (the CI ``sharded-serving`` job forces 8 via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) every variant also runs
TP-sharded through a ``(n_dev/2, 2)`` host mesh (DESIGN.md §3.7), reported with
an ``@tp2`` path suffix — wall-clock is dominated by host-mesh collective
emulation, so these lines measure *that the sharded path serves*, not speedup.

A second section serves a **shared-system-prompt** workload (one common prefix,
per-request suffixes — the fleet-traffic shape) through the dense layout and
the paged pool + radix prefix index (DESIGN.md §3.8), measuring what paging
buys beyond scheduling: prefix hit rate (prompt tokens mapped copy-free from
cached pages / total prompt tokens), prefill tokens actually computed vs
saved, and the peak page footprint against the dense-equivalent capacity —
``capacity_x = dense_pages / peak_pages`` is how many times more concurrent
sequences the same HBM could hold at the observed sharing. Off-TPU this
section (and the latency section — every section except the speculative one)
runs with ``REPRO_KERNEL_EXEC=ref`` (kernels/ops.py): the paged rows measure
the XLA reference execution of the paged kernels, not the
Pallas interpret emulation whose overhead is a property of the emulator —
that dispatch is what holds the fp paged/dense tok/s ratio at the regress.py
floor (≥ 0.90). A third ``chunked`` row serves the same paged pool under the
token-budget scheduler (DESIGN.md §3.10), reported informationally: on an
overhead-bound CPU host the mixed ragged steps trade some throughput for the
bounded per-step latency the latency section gates.

A third section serves a **repetition-heavy** workload (tiled prompt motifs —
the templated/code traffic shape) with speculative decoding (DESIGN.md §3.9):
``speculate=4`` draft windows from the self-drafting n-gram drafter, verified
through the paged kernel's multi-token window, against the same engine at
``speculate=1``. Reported per variant and mode: tok/s, draft acceptance rate,
and emitted tokens per model step — acceptance is a deterministic
drafter/workload property (gated across runs like occupancy), while the
spec/nospec tok/s comparison gates within the snapshot (the two modes'
interleaved passes sample the same interference windows). Unlike the rest of
the benchmark, this section keeps the default kernel execution off-TPU: the
speculative win is launch amortization, which the interpret emulation's
per-launch cost preserves and the ref execution erases (see ``_spec_lines``).

A fourth section measures **latency**, not throughput: a cold-prompt workload
is driven step-by-step through ``ServeEngine.step`` and each call is timed —
once with every request submitted up front (``steady``) and once with half the
requests injected as a mid-run admission burst (``burst``) — for the unchunked
paged engine and the chunked token-budget scheduler (DESIGN.md §3.10).
Reported per (path × mode × phase): p50/p95 per-step latency and mean TTFT
(submit to first emitted token). The burst-phase p95 is the jitter win chunked
prefill exists for — an unchunked refill stalls every in-flight decode behind
a whole-prompt prefill launch — and gates snapshot-locally in ``regress.py``
(chunked ≤ unchunked).

A fifth section drives the **async server** (DESIGN.md §3.11): a
shared-prefix-family workload through the 2-replica ``AsyncServer``, measuring
what replica routing moves — the fleet prefix hit rate under prefix-affinity
vs seeded-random placement (affinity keeps a family's requests on the replica
whose radix index already holds their system prompt; random splits them and
each replica prefills the prefix cold) — plus an overload run where the
bounded admission queue rejects the excess past its deadline instead of
queueing it. The affinity ≥ random hit-rate comparison gates snapshot-locally
in ``regress.py``; TTFT/TPOT percentiles are informational on CPU hosts.

A sixth section serves the **config zoo** (DESIGN.md §3.13): mamba2 — an SSM
family the pre-§3.13 engine could only serve through exact-length grouping —
through both schedulers (the continuous ≥ grouped tok/s comparison gates
snapshot-locally in ``regress.py``: slot-table admission with masked-dt padded
prefill must not cost throughput against the grouped baseline it replaced,
and the occupancy column shows the win it exists for), and granite-moe
fused-int8 single-device vs expert-parallel on a ``(data, 1, expert=2)`` mesh
(informational wall-clock, like the ``@tp2`` rows: host-mesh collective
emulation dominates; the row measures *that* EP serves, parity is pinned by
tests/test_sharded_serving.py).

CSV (after the header rows):
``serving_bench,<path>[@tpN],<scheduler>,<tok_s>,<occupancy>,<refills_mid_decode>``
``serving_bench_prefix,<path>,<layout>,<tok_s>,<hit_rate>,<prefill_tokens>,<prefill_saved>,<peak_pages>,<capacity_x>``
``serving_bench_spec,<path>,<spec|nospec>,<tok_s>,<accept_rate>,<tokens_per_step>``
``serving_bench_latency,<path>,<chunked|unchunked>,<steady|burst>,<p50_step_ms>,<p95_step_ms>,<ttft_ms>``
``serving_bench_server,<path>,<router>,<steady|overload>,<ttft_p50_ms>,<ttft_p95_ms>,<tpot_p50_ms>,<tpot_p95_ms>,<reject_rate>,<hit_rate>``
``serving_bench_zoo,<config>,<mode>,<tok_s>,<occupancy>,<refills_mid_decode>``
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

PROMPT_LENS = (6, 10, 14)
BATCH_SIZE = 4
MAX_LEN = 64
PAGE_SIZE = 8
#: timed passes per row (best-of): one pass of this workload serves in ~1 s,
#: which on a shared CI runner is hostage to scheduler interference — observed
#: 5× tok/s swings between identical runs. Max-of-5 estimates the uncontended
#: throughput; the compile caches are shared (``_prep``) so extra passes cost
#: serve time only, and the gated occupancy/hit-rate invariants are
#: deterministic per pass anyway.
TIMED_PASSES = 5
#: per-step token budget for chunked serving rows (DESIGN.md §3.10): must be
#: ≥ BATCH_SIZE (every generating slot's decode row lands each step) with
#: headroom for prefill chunks — 16 splits the prefix workload's cold 27-30
#: token prompts across two page-aligned chunks while keeping the packed
#: ragged launch small enough that pure-decode steps stay cheap
CHUNK_BUDGET = 16
#: steps served before the latency section's mid-run admission burst lands
BURST_AT_STEP = 3


def _workload(cfg, n_req: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab,
                            size=PROMPT_LENS[i % len(PROMPT_LENS)]).astype(np.int32)
               for i in range(n_req)]
    # Budgets decorrelated from the length cycle (period 4 vs 3): equal-length
    # groups carry mixed budgets, so the grouped baseline idles slots behind the
    # longest request of each group — the occupancy gap continuous batching
    # closes. Budgets are decode-dominated (the serving-relevant regime; a
    # prefill-dominated workload mostly measures per-call dispatch overhead).
    max_new = [14 + 6 * (i % 4) for i in range(n_req)]
    return prompts, max_new


def _prefix_workload(cfg, n_req: int, shared_len: int = 24, seed: int = 1):
    """One shared system prompt + short per-request suffixes: the prefix-reuse
    case the paged layout (DESIGN.md §3.8) exists for."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab, size=shared_len).astype(np.int32)
    prompts = [np.concatenate([
        shared, rng.integers(1, cfg.vocab, size=3 + (i % 4)).astype(np.int32)])
        for i in range(n_req)]
    max_new = [10 + 4 * (i % 3) for i in range(n_req)]
    return prompts, max_new


def _spec_workload(cfg, n_req: int, seed: int = 2):
    """Repetition-heavy prompts (tiled motifs, the templated/code regime
    prompt-lookup drafting exists for — DESIGN.md §3.9) with decode-dominated
    budgets: the self-drafting n-gram drafter fills verify windows from the
    request's own history, so acceptance — and therefore the spec/nospec
    tok/s ratio — is a property of the workload's repetitiveness."""
    rng = np.random.default_rng(seed)
    prompts, max_new = [], []
    for i in range(n_req):
        motif = rng.integers(1, cfg.vocab, size=3 + i % 3).astype(np.int32)
        prompts.append(np.tile(motif, 4)[: PROMPT_LENS[i % len(PROMPT_LENS)]])
        # long decode budgets: greedy streams settle into attractor loops the
        # prompt-lookup drafter then rides — short budgets would mostly
        # measure the pre-loop transient where acceptance is poor
        max_new.append(36 + 4 * (i % 4))
    return prompts, max_new


def _latency_workload(cfg, n_req: int, seed: int = 3):
    """Cold long prompts, no sharing: the admission-cost shape. An unchunked
    refill runs the whole prompt as one bucketed prefill launch — the step
    every co-resident decode waits behind — while the chunked scheduler
    spreads it across budgeted steps. Decode budgets keep the tail
    decode-dominated so steady-state steps are measured too."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab, size=20 + 4 * (i % 4)).astype(np.int32)
               for i in range(n_req)]
    max_new = [8 + 2 * (i % 3) for i in range(n_req)]
    return prompts, max_new


def _drive(eng, prompts, max_new, burst_at=None):
    """Serve the workload through ``ServeEngine.step``, timing each call.
    With ``burst_at``, only the first half of the requests is submitted up
    front and the rest land as one mid-run admission burst after that many
    steps. Returns ``(per-step latencies, per-request TTFTs)`` in ms — TTFT
    is submit-to-first-emitted-token, so for burst requests it includes the
    queue wait behind the in-flight decodes."""
    n = len(prompts)
    cut = n if burst_at is None else n // 2

    def submit(lo, hi):
        eng.submit([p.copy() for p in prompts[lo:hi]],
                   max_new=list(max_new[lo:hi]))
        now = time.perf_counter()
        return {r.rid: now for r in eng.queue[-(hi - lo):]}

    t_sub = submit(0, cut)
    finished, step_ms, ttft = [], [], {}
    k = 0
    while True:
        if burst_at is not None and k == burst_at:
            t_sub.update(submit(cut, n))
            burst_at = None
        t0 = time.perf_counter()
        alive = eng.step(finished)
        dt = time.perf_counter() - t0
        if not alive:
            assert burst_at is None, "engine idled before the burst landed"
            return step_ms, list(ttft.values())
        step_ms.append(dt * 1e3)
        k += 1
        now = time.perf_counter()
        for r in list(eng._slots) + finished:
            if r is not None and r.out and r.rid not in ttft:
                ttft[r.rid] = (now - t_sub[r.rid]) * 1e3


def _latency_lines(cfg, variants, n_req: int, steps):
    """The latency section: per-step p50/p95 and mean TTFT, chunked vs
    unchunked paged serving, in a steady phase (all requests up front) and a
    burst phase (half the requests injected mid-decode). The burst-phase p95
    is the jitter claim of DESIGN.md §3.10 — an unchunked admission runs the
    full prompt prefill as one launch between decode steps, so the burst
    shows up as p95 spikes the token-budget scheduler bounds away —
    and regress.py gates it snapshot-locally (chunked ≤ unchunked). Passes
    interleave across modes and phases; best-of keeps the per-metric MIN
    (the uncontended estimate, like the tok/s rows' max)."""
    from repro.serving.config import EngineConfig
    from repro.serving.engine import ServeEngine
    prompts, max_new = _latency_workload(cfg, n_req)
    lines = ["serving_bench_latency,path,mode,phase,p50_step_ms,p95_step_ms,"
             "ttft_ms"]
    modes = {"unchunked": {}, "chunked": dict(chunked=True,
                                              token_budget=CHUNK_BUDGET)}
    for tag, p, quant, path, kv in variants:
        kws, best = {}, {}
        for mode, extra in modes.items():
            config = EngineConfig(batch_size=BATCH_SIZE, max_len=MAX_LEN,
                                  path=path, kv_cache=kv,
                                  scheduler="continuous", cache_layout="paged",
                                  page_size=PAGE_SIZE, **extra)
            key = (tag, "", "paged-chunked" if extra else "paged")
            weng = ServeEngine(cfg, p, config=config, quant=quant)
            if key in steps:
                _attach_steps(weng, steps[key])
            # warm on THIS workload: the unchunked engines' bucketed prefill
            # lowerings depend on the prompt-length buckets, which differ
            # from the earlier sections' workloads
            _drive(weng, prompts, max_new, burst_at=BURST_AT_STEP)
            steps[key] = _extract_steps(weng)
            kws[mode] = (config, steps[key])
        for _ in range(TIMED_PASSES):
            for phase, burst_at in (("steady", None), ("burst", BURST_AT_STEP)):
                for mode, (config, shared) in kws.items():
                    eng = ServeEngine(cfg, p, config=config, quant=quant)
                    _attach_steps(eng, shared)
                    step_ms, ttfts = _drive(eng, prompts, max_new,
                                            burst_at=burst_at)
                    got = (float(np.percentile(step_ms, 50)),
                           float(np.percentile(step_ms, 95)),
                           float(np.mean(ttfts)))
                    prev = best.get((mode, phase))
                    best[(mode, phase)] = (got if prev is None else
                                           tuple(map(min, prev, got)))
        for (mode, phase), (p50, p95, tf) in best.items():
            lines.append(f"serving_bench_latency,{tag},{mode},{phase},"
                         f"{p50:.2f},{p95:.2f},{tf:.2f}")
    return lines


def _spec_lines(cfg, variants, n_req: int, steps):
    """The speculative section: speculate=4 vs plain decode per serving
    variant, through the paged layout (the verify window scores against the
    same paged pools + in-kernel int8 dequant as decode — DESIGN.md §3.9).
    spec/nospec timed passes interleave for the same reason the other
    sections' do: the regression gate compares their tok/s as a same-run
    ratio, so adjacent passes must see the same machine.

    This section runs under the *default* kernel execution (Mosaic on TPU,
    interpret emulation elsewhere), not the ref-exec the rest of the bench
    opts into: the speculative win is launch amortization — one verify launch
    replaces up to k decode launches — and the interpret emulation preserves
    that per-launch cost structure, while the ref execution's fused XLA
    decode erases launch cost on a toy CPU model and with it the signal the
    spec/nospec gate checks. The exec mode bakes into each engine step's jit
    trace, so this section's step-cache keys are its own (``specK``) — the
    other sections' ref-mode steps must not be reused here."""
    prompts, max_new = _spec_workload(cfg, n_req)
    lines = ["serving_bench_spec,path,mode,tok_s,accept_rate,tokens_per_step"]
    prev = os.environ.pop("REPRO_KERNEL_EXEC", None)
    try:
        for tag, p, quant, path, kv in variants:
            passes = {
                mode: _prep(cfg, p, prompts, max_new, quant=quant, path=path,
                            kv_cache=kv, scheduler="continuous",
                            cache_layout="paged", speculate=k, steps=steps,
                            key=(tag, f"spec{k}", "paged"))
                for mode, k in (("nospec", 1), ("spec", 4))}
            best = dict.fromkeys(passes, 0.0)
            engs = {}
            for _ in range(TIMED_PASSES):
                for mode, one_pass in passes.items():
                    tok_s, engs[mode] = one_pass()
                    best[mode] = max(best[mode], tok_s)
            for mode, eng in engs.items():
                lines.append(
                    f"serving_bench_spec,{tag},{mode},{best[mode]:.1f},"
                    f"{eng.accept_rate():.3f},{eng.tokens_per_step():.2f}")
    finally:
        if prev is not None:
            os.environ["REPRO_KERNEL_EXEC"] = prev
    return lines


#: jit'd step attributes shared across engines of one (variant, mesh, layout)
#: — sharing the function objects shares their compile caches, so each
#: lowering compiles once per process instead of once per engine
_STEP_ATTRS = {"decode": "_decode_step", "cold": "_admit_cold",
               "warm": "_admit_warm", "copy": "_copy_step",
               "admit": "_admit_step", "verify": "_verify_step",
               "chunk": "_chunk_step"}


def _extract_steps(eng):
    return {k: getattr(eng, a) for k, a in _STEP_ATTRS.items()
            if hasattr(eng, a)}


def _attach_steps(eng, shared):
    # hasattr guard both ways: a dense engine must not gain paged steps and a
    # chunked entry's "chunk" step must not land on an unchunked engine
    for k, a in _STEP_ATTRS.items():
        if k in shared and hasattr(eng, a):
            setattr(eng, a, shared[k])


def _prep(cfg, params, prompts, max_new, *, quant, path, kv_cache, scheduler,
          mesh=None, cache_layout="dense", speculate=1, chunked=False,
          token_budget=None, steps=None, key=None):
    """Warm the compile caches on one throwaway serve, then return a
    ``one_pass()`` closure that serves the workload on a fresh engine and
    returns ``(tok_s, engine)``. ``steps``/``key`` share the jit'd step
    objects — and therefore their compile caches — across engines of the same
    (variant, mesh, layout): the step functions do not depend on the scheduler
    or on which bench section runs them, so grouped/continuous and the
    main/shared-prefix sections compile each lowering once per process instead
    of once per engine (the quick-CI wall-clock was dominated by those
    recompiles)."""
    from repro.serving.config import EngineConfig
    from repro.serving.engine import ServeEngine
    config = EngineConfig(batch_size=BATCH_SIZE, max_len=MAX_LEN, path=path,
                          kv_cache=kv_cache, scheduler=scheduler,
                          cache_layout=cache_layout, page_size=PAGE_SIZE,
                          speculate=speculate, chunked=chunked,
                          token_budget=(token_budget or CHUNK_BUDGET)
                          if chunked else 64)

    shared = steps.get(key) if steps is not None and key is not None else None
    eng = ServeEngine(cfg, params, config=config, quant=quant, mesh=mesh)
    if shared is not None:
        _attach_steps(eng, shared)
    eng.submit([p.copy() for p in prompts], max_new=list(max_new))
    eng.run()                      # warm compile caches (fresh engines re-time)
    if steps is not None and key is not None and shared is None:
        steps[key] = _extract_steps(eng)

    def one_pass():
        eng2 = ServeEngine(cfg, params, config=config, quant=quant, mesh=mesh)
        _attach_steps(eng2, _extract_steps(eng))
        eng2.submit([p.copy() for p in prompts], max_new=list(max_new))
        t0 = time.perf_counter()
        done = eng2.run()
        dt = time.perf_counter() - t0
        return sum(len(r.out) for r in done) / dt, eng2

    return one_pass


def _prefix_lines(cfg, variants, n_req: int, steps):
    """The shared-prefix section: dense vs paged vs chunked (the §3.10
    token-budget scheduler on the paged pool, informational) per serving
    variant. The layouts' timed passes are *interleaved* (dense, paged,
    chunked, dense, ...): the regression gate compares their tok/s as a
    ratio, and on a shared runner an interference window spanning one
    layout's whole best-of block would skew the ratio arbitrarily — adjacent
    passes see the same machine."""
    prompts, max_new = _prefix_workload(cfg, n_req)
    lines = ["serving_bench_prefix,path,layout,tok_s,hit_rate,prefill_tokens,"
             "prefill_saved,peak_pages,capacity_x"]
    dense_pages = BATCH_SIZE * MAX_LEN // PAGE_SIZE
    for tag, p, quant, path, kv in variants:
        # three rows per variant: dense, paged (the gated configuration — the
        # regress.py floor holds fp paged/dense ≥ 0.90, which the ref-exec
        # kernel dispatch recovers on CPU hosts), and the §3.10 chunked
        # scheduler on the same paged pool, reported informationally — on an
        # overhead-bound CPU host the ragged mixed steps trade a little
        # throughput for the bounded per-step latency the latency section
        # measures (its win is the burst p95 gate, not tok/s)
        passes = {
            layout: _prep(cfg, p, prompts, max_new, quant=quant, path=path,
                          kv_cache=kv, scheduler="continuous",
                          cache_layout="paged" if layout == "chunked"
                          else layout, chunked=layout == "chunked",
                          steps=steps,
                          key=(tag, "", "paged-chunked"
                               if layout == "chunked" else layout))
            for layout in ("dense", "paged", "chunked")}
        best = dict.fromkeys(passes, 0.0)
        engs = {}
        for _ in range(TIMED_PASSES):
            for layout, one_pass in passes.items():
                tok_s, engs[layout] = one_pass()
                best[layout] = max(best[layout], tok_s)
        for layout, eng in engs.items():
            saved = eng.counters["prefix_tokens_reused"]
            peak = eng.counters["peak_pages_in_use"] or dense_pages
            lines.append(
                f"serving_bench_prefix,{tag},{layout},{best[layout]:.1f},"
                f"{eng.prefix_hit_rate():.3f},{eng.counters['prefill_tokens']},"
                f"{saved},{peak},{dense_pages / peak:.2f}")
    return lines


def _server_workload(cfg, n_families: int = 4, per_family: int = 3,
                     shared_len: int = 16, seed: int = 4):
    """Fleet-traffic shape for the router section: ``n_families`` distinct
    shared system prompts (each two pages long), ``per_family`` requests each,
    submitted family-interleaved — random routing splits a family's requests
    across replicas (each replica prefills the shared prefix cold) while
    prefix-affinity keeps families together and the radix index pays off."""
    rng = np.random.default_rng(seed)
    fams = [rng.integers(1, cfg.vocab, size=shared_len).astype(np.int32)
            for _ in range(n_families)]
    return [np.concatenate([fams[f],
                            rng.integers(1, cfg.vocab,
                                         size=3 + (f + r) % 4).astype(np.int32)])
            for r in range(per_family) for f in range(n_families)]


def _fleet_hit_rate(metrics: dict) -> float:
    """Aggregate prefix hit rate across the fleet: prompt tokens mapped
    copy-free from cached pages / total prompt tokens, summed over replicas —
    the quantity routing policy actually moves."""
    engines = [r["engine"] for r in metrics["replicas"] if r["engine"]]
    reused = sum(e["prefix_tokens_reused"] for e in engines)
    prompt = sum(e["prompt_tokens"] for e in engines)
    return reused / prompt if prompt else 0.0


def _serve_async(cfg, params, prompts, *, router, steps, max_queue=None,
                 admission_timeout=1.0):
    """Drive one workload through a 2-replica ``AsyncServer`` and return its
    ``metrics()`` snapshot. The server is paused while every request is
    submitted, so routing decisions and (in the overload run) admission
    rejects are decided against a frozen fleet — deterministic per snapshot,
    which is what lets regress.py gate affinity-vs-random as a same-run
    comparison. Replica engines adopt the process-wide shared step objects
    (same shapes as the prefix section's paged fp engines)."""
    import asyncio

    from repro.serving.api import AdmissionError, Request
    from repro.serving.config import EngineConfig
    from repro.serving.server import AsyncServer

    config = EngineConfig(batch_size=BATCH_SIZE, max_len=MAX_LEN,
                          cache_layout="paged", page_size=PAGE_SIZE)

    async def drive():
        async with AsyncServer(cfg, params, config=config, replicas=2,
                               router=router, router_seed=0,
                               max_queue=max_queue,
                               admission_timeout=admission_timeout) as srv:
            shared = steps.get(("fp", "", "paged"))
            if shared is not None:
                for rep in srv.replicas:
                    _attach_steps(rep.engine, shared)
            srv.pause()

            async def one(p):
                try:
                    async for _ in srv.submit(Request(prompt=p.tolist(),
                                                      max_new=6)):
                        pass
                except AdmissionError:
                    pass

            tasks = [asyncio.ensure_future(one(p)) for p in prompts]
            # let every submission route (or reject) against the paused fleet
            await asyncio.sleep(2 * admission_timeout + 0.1)
            srv.resume()
            await asyncio.gather(*tasks)
            return srv.metrics()

    return asyncio.run(drive())


def _server_lines(cfg, params, steps):
    """The async-server section (DESIGN.md §3.11): one shared-prefix-family
    workload through the 2-replica ``AsyncServer`` under three loads —
    prefix-affinity vs seeded-random routing at steady offered load (the
    affinity ≥ random fleet hit-rate comparison regress.py gates
    snapshot-locally), plus an overload run (``max_queue`` = one engine batch,
    20 ms admission deadline) where backpressure rejects the excess instead of
    queueing it — the nonzero reject-rate row. TTFT/TPOT percentiles are
    informational on a CPU host (they include the deterministic pause window);
    the gated signal is the hit-rate ratio and that rejects stay 0 off
    overload."""
    prompts = _server_workload(cfg)
    lines = ["serving_bench_server,path,router,load,ttft_p50_ms,ttft_p95_ms,"
             "tpot_p50_ms,tpot_p95_ms,reject_rate,hit_rate"]
    runs = [("affinity", "steady", {}),
            ("random", "steady", {}),
            ("affinity", "overload", dict(max_queue=BATCH_SIZE,
                                          admission_timeout=0.02))]
    for router, load, kw in runs:
        m = _serve_async(cfg, params, prompts, router=router, steps=steps, **kw)
        srv, lat = m["server"], m["latency"]
        offered = srv["submitted"] + srv["rejected"]   # admitted + rejected
        rej = srv["rejected"] / offered if offered else 0.0
        lines.append(
            f"serving_bench_server,fp,{router},{load},"
            f"{lat['ttft_p50_s'] * 1e3:.1f},{lat['ttft_p95_s'] * 1e3:.1f},"
            f"{lat['tpot_p50_s'] * 1e3:.2f},{lat['tpot_p95_s'] * 1e3:.2f},"
            f"{rej:.3f},{_fleet_hit_rate(m):.3f}")
    return lines


def _zoo_lines(quick: bool, steps):
    """The config-zoo section (DESIGN.md §3.13): serving families the engine
    learned through the layer-polymorphic ``StateSpec`` registry.

    mamba2 (SSM: recurrent-state + conv-buffer pages, no KV) serves the main
    mixed-length workload through both schedulers — the continuous ≥ grouped
    tok/s comparison gates snapshot-locally in ``regress.py`` (slot-table
    admission with masked-dt padded prefill must not cost throughput against
    the exact-length grouping it replaced, while the occupancy column shows
    the structural win). Passes interleave like the main section's: the gate
    is a same-snapshot ratio.

    granite-moe serves fused-int8 through the continuous scheduler
    single-device and — when the host exposes ≥ 2 devices — expert-parallel on
    a ``(n_dev/2, 1, expert=2)`` mesh (``@ep2``). Like the ``@tp2`` rows these
    are informational wall-clock (host-mesh collective emulation dominates);
    bitwise parity vs single-device is pinned by tests/test_sharded_serving.py.
    Skipped in quick mode with the other quantized variants (quantize_tree
    dominates the quick-CI budget); the gated mamba2 pair runs in both modes.
    """
    from repro.configs import get
    from repro.core import qlinear as ql
    from repro.models import model as M
    from repro.models.quantize import quantize_tree

    lines = ["serving_bench_zoo,config,mode,tok_s,occupancy,refills_mid_decode"]

    zcfg = get("mamba2-130m", smoke=True)
    zparams = M.init_params(jax.random.PRNGKey(0), zcfg)
    prompts, max_new = _workload(zcfg, 10)
    passes = {
        scheduler: _prep(zcfg, zparams, prompts, max_new, quant=ql.FP,
                         path=None, kv_cache="fp", scheduler=scheduler,
                         steps=steps, key=("zoo-mamba2", "", "dense"))
        for scheduler in ("grouped", "continuous")}
    best = dict.fromkeys(passes, 0.0)
    engs = {}
    for _ in range(TIMED_PASSES):
        for scheduler, one_pass in passes.items():
            tok_s, engs[scheduler] = one_pass()
            best[scheduler] = max(best[scheduler], tok_s)
    for scheduler, eng in engs.items():
        lines.append(f"serving_bench_zoo,mamba2,{scheduler},"
                     f"{best[scheduler]:.1f},{eng.occupancy():.2f},"
                     f"{eng.counters['mid_decode_admissions']}")

    if quick:
        return lines

    mcfg = get("granite-moe-3b-a800m", smoke=True)
    mparams = quantize_tree(M.init_params(jax.random.PRNGKey(0), mcfg),
                            ql.W8A8_INT8)
    mprompts, mmax_new = _workload(mcfg, 10)
    mmeshes = [("", None)]
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import make_debug_mesh
        mmeshes.append(("@ep2",
                        make_debug_mesh(len(jax.devices()) // 2, 1, 2)))
    for mesh_tag, mesh in mmeshes:
        one_pass = _prep(mcfg, mparams, mprompts, mmax_new,
                         quant=ql.W8A8_INT8, path="fused-int8", kv_cache="fp",
                         scheduler="continuous", mesh=mesh, steps=steps,
                         key=("zoo-granite-moe", mesh_tag, "dense"))
        best_m, eng = 0.0, None
        for _ in range(TIMED_PASSES):
            tok_s, eng = one_pass()
            best_m = max(best_m, tok_s)
        lines.append(f"serving_bench_zoo,granite-moe{mesh_tag},continuous,"
                     f"{best_m:.1f},{eng.occupancy():.2f},"
                     f"{eng.counters['mid_decode_admissions']}")
    return lines


def run(quick: bool = False):
    # Off-TPU, serve through the pure-jnp reference execution of the paged
    # kernels (kernels/ops.py _exec_mode): interpret emulation is a
    # correctness harness and its per-launch overhead would otherwise be the
    # dominant term in every paged row — emulator cost, not a serving signal.
    # On TPU the variable is ignored and the Mosaic kernels run. The
    # speculative section opts back out (_spec_lines): its gate measures
    # launch amortization, which needs the per-launch cost structure.
    prev = os.environ.get("REPRO_KERNEL_EXEC")
    os.environ["REPRO_KERNEL_EXEC"] = "ref"
    try:
        return _run(quick)
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_EXEC", None)
        else:
            os.environ["REPRO_KERNEL_EXEC"] = prev


def _run(quick: bool = False):
    from repro.configs import get
    from repro.core import qlinear as ql
    from repro.models import model as M
    from repro.models.quantize import quantize_tree

    cfg = get("starcoder2-7b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # one workload size for quick AND full passes: occupancy is a deterministic
    # scheduling invariant gated across runs (benchmarks/regress.py), so the
    # quick-CI snapshot must serve the exact workload of the committed full-run
    # baseline — quick only trims the variant grid below
    n_req = 10
    prompts, max_new = _workload(cfg, n_req)

    variants = [("fp", params, ql.FP, None, "fp")]
    if not quick:
        qparams = quantize_tree(params, ql.W8A8_INT8)
        variants += [("fused-int8", qparams, ql.W8A8_INT8, "fused-int8", "fp"),
                     ("fused-int8+kv8", qparams, ql.W8A8_INT8, "fused-int8", "int8")]

    # TP-sharded twins (DESIGN.md §3.7) whenever the host exposes enough devices
    # (CI: XLA_FLAGS=--xla_force_host_platform_device_count=8 → tp=2 on (4, 2)).
    meshes = [("", None)]
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import make_debug_mesh
        tp = 2
        meshes.append((f"@tp{tp}", make_debug_mesh(len(jax.devices()) // tp, tp)))

    # one process-wide step cache: every (variant, mesh, layout) compiles its
    # decode/admit lowerings once, shared across schedulers AND the
    # shared-prefix section below (identical workloads and engine shapes, so
    # the reuse cannot perturb the gated occupancy / hit-rate invariants)
    steps: dict = {}
    lines = ["serving_bench,path,scheduler,tok_s,occupancy,refills_mid_decode"]
    for tag, p, quant, path, kv in variants:
        for mesh_tag, mesh in meshes:
            # both schedulers' timed passes interleave, mirroring
            # _prefix_lines: the regress.py invariant gate compares
            # continuous against grouped tok/s directly, so the two must
            # sample the same interference windows
            passes = {
                scheduler: _prep(cfg, p, prompts, max_new, quant=quant,
                                 path=path, kv_cache=kv, scheduler=scheduler,
                                 mesh=mesh, steps=steps,
                                 key=(tag, mesh_tag, "dense"))
                for scheduler in ("grouped", "continuous")}
            best = dict.fromkeys(passes, 0.0)
            engs = {}
            for _ in range(TIMED_PASSES):
                for scheduler, one_pass in passes.items():
                    tok_s, engs[scheduler] = one_pass()
                    best[scheduler] = max(best[scheduler], tok_s)
            for scheduler, eng in engs.items():
                lines.append(f"serving_bench,{tag}{mesh_tag},{scheduler},"
                             f"{best[scheduler]:.1f},{eng.occupancy():.2f},"
                             f"{eng.counters['mid_decode_admissions']}")

    # shared-system-prompt workload: dense vs paged prefix reuse (§3.8);
    # single-device only — the paged capacity story is layout, not TP. Like
    # occupancy, the hit rate is a gated deterministic invariant: quick and
    # full passes must serve the same workload (quick trims variants only).
    lines += _prefix_lines(cfg, variants, n_req=12, steps=steps)

    # speculative decoding (§3.9): speculate=4 vs plain decode on a
    # repetition-heavy workload, paged layout; accept rate is a deterministic
    # drafter/workload invariant gated across runs like occupancy, the
    # spec/nospec tok/s ratio gates same-snapshot (regress.py)
    lines += _spec_lines(cfg, variants, n_req=10, steps=steps)

    # latency (§3.10): per-step p50/p95 + TTFT, chunked vs unchunked paged
    # serving, with and without an admission burst mid-run; the burst-phase
    # p95 (chunked ≤ unchunked) gates snapshot-locally in regress.py. Runs
    # after the prefix section so its engines reuse the ref-mode paged and
    # chunked steps warmed there (the spec section's steps are pallas-mode
    # and keyed separately — see _spec_lines).
    lines += _latency_lines(cfg, variants, n_req=8, steps=steps)

    # async server (§3.11): prefix-affinity vs random routing through the
    # 2-replica AsyncServer on a shared-prefix-family workload, plus an
    # overload run exercising bounded-admission backpressure; the fleet
    # hit-rate comparison (affinity ≥ random) gates snapshot-locally. fp
    # only — routing moves prefix reuse, which is layout, not quantization.
    lines += _server_lines(cfg, params, steps)

    # config zoo (§3.13): mamba2 through both schedulers (continuous ≥ grouped
    # gates snapshot-locally) and granite-moe fused-int8 single-device vs
    # expert-parallel — the zoo configs' own step caches key under "zoo-*".
    lines += _zoo_lines(quick, steps)
    return lines
