"""Paper Table 2: perplexity under W8A8 / W4A8-g128 / W4A4 across methods
(per-token, SmoothQuant, CrossQuant; weight side per-channel or g128 groups), on the
llama-like and opt-like outlier regimes.

Reproduced claims: (1) CrossQuant >= SmoothQuant >= per-token at W8A8; (2) per-token
collapses at W4A4 while CrossQuant degrades gracefully; (3) group-wise W4 with
CrossQuant activations tracks the fp baseline.

One beyond-paper row per regime: ``crossquant_w8a8_sparse24`` — CrossQuant W8A8
after plan-gated 2:4 weight pruning (DESIGN.md §3.12; only the linears whose §4.1
quantization-kernel proportion stays under the plan threshold are pruned). The
regress gate pins its ppl delta vs the dense ``crossquant_w8a8`` row.
"""
from __future__ import annotations

from benchmarks import common as C
from benchmarks.regimes import REGIMES
from repro.core import qlinear as ql
from repro.models import quantize as MQ

SPARSE_THRESHOLD = 0.10     # §4.1 kernel-proportion ceiling for pruning a layer

GROUPS = [
    ("fp16", None),
    ("per_token_w8a8", ql.W8A8_PER_TOKEN),
    ("smoothquant_w8a8", ql.W8A8_SMOOTHQUANT),
    ("crossquant_w8a8", ql.W8A8_CROSSQUANT),
    ("per_token_w4a8_g128", ql.W4A8_G128_PER_TOKEN),
    ("awq_w4a8_g128", ql.W4A8_G128_AWQ),
    ("crossquant_w4a8_g128", ql.W4A8_G128),
    ("crossquant+awq_w4a8_g128", ql.W4A8_G128_CQ_AWQ),
    ("per_token_w4a4", ql.W4A4_PER_TOKEN),
    ("crossquant_w4a4", ql.W4A4),
    ("crossquant_w+a_w4a4", ql.W4A4_CQW),
]


def run(quick: bool = False):
    cfg, params = C.get_bench_model()
    nb = 2 if quick else 6
    lines = ["table2,regime,method,ppl"]
    regimes = ["llama_like", "opt_like"] if not quick else ["opt_like"]
    for regime in regimes:
        planted = (params if REGIMES[regime] is None
                   else C.plant_outliers(params, cfg, **REGIMES[regime]))
        for name, qc in GROUPS:
            ppl = C.eval_ppl(cfg, planted, qc, n_batches=nb)
            lines.append(f"table2,{regime},{name},{ppl:.3f}")
        plan = MQ.make_sparsity_plan(cfg, planted, C.eval_batches(1),
                                     threshold=SPARSE_THRESHOLD)
        sparams = MQ.sparsify_tree(planted, plan)
        ppl = C.eval_ppl(cfg, sparams, ql.W8A8_CROSSQUANT, n_batches=nb)
        lines.append(f"table2,{regime},crossquant_w8a8_sparse24,{ppl:.3f}")
        lines.append(f"table2_sparse_plan,{regime},pruned_layers,"
                     f"{len(plan.layers)}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
