"""Paper Table 2: perplexity under W8A8 / W4A8-g128 / W4A4 across methods
(per-token, SmoothQuant, CrossQuant; weight side per-channel or g128 groups), on the
llama-like and opt-like outlier regimes.

Reproduced claims: (1) CrossQuant >= SmoothQuant >= per-token at W8A8; (2) per-token
collapses at W4A4 while CrossQuant degrades gracefully; (3) group-wise W4 with
CrossQuant activations tracks the fp baseline.
"""
from __future__ import annotations

from benchmarks import common as C
from benchmarks.regimes import REGIMES
from repro.core import qlinear as ql

GROUPS = [
    ("fp16", None),
    ("per_token_w8a8", ql.W8A8_PER_TOKEN),
    ("smoothquant_w8a8", ql.W8A8_SMOOTHQUANT),
    ("crossquant_w8a8", ql.W8A8_CROSSQUANT),
    ("per_token_w4a8_g128", ql.W4A8_G128_PER_TOKEN),
    ("awq_w4a8_g128", ql.W4A8_G128_AWQ),
    ("crossquant_w4a8_g128", ql.W4A8_G128),
    ("crossquant+awq_w4a8_g128", ql.W4A8_G128_CQ_AWQ),
    ("per_token_w4a4", ql.W4A4_PER_TOKEN),
    ("crossquant_w4a4", ql.W4A4),
    ("crossquant_w+a_w4a4", ql.W4A4_CQW),
]


def run(quick: bool = False):
    cfg, params = C.get_bench_model()
    nb = 2 if quick else 6
    lines = ["table2,regime,method,ppl"]
    regimes = ["llama_like", "opt_like"] if not quick else ["opt_like"]
    for regime in regimes:
        planted = (params if REGIMES[regime] is None
                   else C.plant_outliers(params, cfg, **REGIMES[regime]))
        for name, qc in GROUPS:
            ppl = C.eval_ppl(cfg, planted, qc, n_batches=nb)
            lines.append(f"table2,{regime},{name},{ppl:.3f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
