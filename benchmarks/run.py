"""Benchmark runner: one module per paper table/figure (+ beyond-paper benches).

    PYTHONPATH=src python -m benchmarks.run            # full pass
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed subset

Each module runs in its own subprocess: a single long-lived process accumulates
XLA-CPU JIT dylibs across hundreds of compiled graphs and eventually fails with
"Failed to materialize symbols"; process isolation resets the JIT per module.

Prints CSV sections; each line is ``<bench>,<key...>,<value...>``. The mapping to
the paper's tables/figures is in DESIGN.md §7; EXPERIMENTS.md quotes these outputs.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

MODULES = [
    "table1_alpha", "table2_ppl", "table3_tasks", "fig4_kernels",
    "fig67_threshold", "fig8_alpha_sweep", "grad_compression", "qgemm_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated module subset")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    t_all = time.time()
    failures = []
    env = {**os.environ, "PYTHONPATH": os.environ.get("PYTHONPATH", "src")}
    for name in mods:
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        code = (f"from benchmarks.{name} import run\n"
                f"print('\\n'.join(run(quick={args.quick!r})))")
        r = subprocess.run([sys.executable, "-c", code], env=env, text=True,
                           capture_output=True, timeout=3600)
        sys.stdout.write(r.stdout)
        if r.returncode != 0:
            failures.append((name, r.stderr.strip().splitlines()[-1][:200]
                             if r.stderr.strip() else "unknown"))
            print(f"{name},ERROR,see stderr", flush=True)
            sys.stderr.write(r.stderr[-2000:])
        print(f"# {name} took {time.time() - t0:.0f}s", flush=True)
    print(f"# total {time.time() - t_all:.0f}s")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
