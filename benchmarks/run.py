"""Benchmark runner: one module per paper table/figure (+ beyond-paper benches).

    PYTHONPATH=src python -m benchmarks.run            # full pass
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed subset

Each module runs in its own subprocess: a single long-lived process accumulates
XLA-CPU JIT dylibs across hundreds of compiled graphs and eventually fails with
"Failed to materialize symbols"; process isolation resets the JIT per module.

Prints CSV sections; each line is ``<bench>,<key...>,<value...>``. The mapping to
the paper's tables/figures is in DESIGN.md §7 and benchmarks/README.md; EXPERIMENTS.md
quotes these outputs. ``--json PATH`` additionally writes the machine-readable
``BENCH_*.json`` snapshot (schema in benchmarks/README.md) used for cross-PR
trajectory tracking. A partial run (``--only``) *merges* into an existing
snapshot at PATH — modules not re-run keep their previous entries — and
``total_seconds`` is always recomputed as the sum of the per-module seconds, so
an ``--only`` pass can never shrink the committed baseline to its own runtime
(the staleness the pre-merge writer produced: modules summing to 177.7s under a
``total_seconds`` of 25.0).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

MODULES = [
    "table1_alpha", "table2_ppl", "table3_tasks", "fig4_kernels",
    "fig67_threshold", "fig8_alpha_sweep", "grad_compression", "qgemm_bench",
    "serving_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated module subset")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_*.json snapshot (benchmarks/README.md)")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    t_all = time.time()
    failures = []
    snapshot = {"schema": 2, "quick": args.quick, "modules": {}}
    if args.json and args.only and os.path.exists(args.json):
        # partial run: merge into the existing snapshot so the modules this run
        # skips keep their entries (and their seconds) instead of vanishing
        try:
            with open(args.json) as fh:
                prev = json.load(fh)
            snapshot["modules"].update(prev.get("modules", {}))
        except (OSError, json.JSONDecodeError) as e:
            print(f"# existing snapshot {args.json} unreadable ({e}); rewriting")
    env = {**os.environ, "PYTHONPATH": os.environ.get("PYTHONPATH", "src")}
    for name in mods:
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        code = (f"from benchmarks.{name} import run\n"
                f"print('\\n'.join(run(quick={args.quick!r})))")
        r = subprocess.run([sys.executable, "-c", code], env=env, text=True,
                           capture_output=True, timeout=3600)
        sys.stdout.write(r.stdout)
        ok = r.returncode == 0
        if not ok:
            failures.append((name, r.stderr.strip().splitlines()[-1][:200]
                             if r.stderr.strip() else "unknown"))
            print(f"{name},ERROR,see stderr", flush=True)
            sys.stderr.write(r.stderr[-2000:])
        dt = time.time() - t0
        snapshot["modules"][name] = {
            "ok": ok, "seconds": round(dt, 1), "quick": args.quick,
            "lines": [ln for ln in r.stdout.splitlines() if ln.strip()],
        }
        print(f"# {name} took {dt:.0f}s", flush=True)
    # total = sum over *recorded* modules (merged entries included), never this
    # invocation's wall clock alone
    snapshot["total_seconds"] = round(
        sum(m.get("seconds", 0.0) for m in snapshot["modules"].values()), 1)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(snapshot, fh, indent=1)
        print(f"# wrote {args.json}")
    print(f"# this run {time.time() - t_all:.0f}s; "
          f"snapshot modules total {snapshot['total_seconds']:.0f}s")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
