"""Outlier regimes for the benchmark model (tuned so the *per-token* quantization
kernel reproduces the paper's Fig. 4 bands).

  llama_like : mild outliers  -> per-token kernel ~10-15%% (paper: ~11%% for LLaMA)
  opt_like   : strong         -> per-token kernel ~45-50%% (paper: 40-55%% for OPT)
  opt_xl     : extreme        -> per-token kernel ~65%%    (the Fig. 1 regime where
               per-token A8 accuracy collapses to chance while CrossQuant holds)

CrossQuant's kernel stays ~4%% in all regimes (paper: ~16%% OPT / <0.1%% LLaMA; the
ordering and the collapse threshold are the reproduced phenomena — DESIGN.md §5.2).
"""
REGIMES = {
    "none": None,
    "llama_like": dict(frac=0.03, magnitude=40.0),
    "opt_like": dict(frac=0.08, magnitude=150.0),
    "opt_xl": dict(frac=0.12, magnitude=300.0),
}
