"""Shared benchmark infrastructure.

The paper evaluates pretrained 7B–70B checkpoints, which are unavailable offline
(DESIGN.md §5.2). The benchmarks reproduce the paper's *phenomena* on

  1. a small LM trained in-repo on a skewed Markov corpus (real model, real ppl), and
  2. **function-preserving planted outliers**: after training, a chosen fraction of
     channels has its pre-linear activation scaled by ``m`` (norm gain × m) while the
     consuming linear's rows are divided by m — the fp16 model computes the *same
     function*, but its activation matrices now carry the ≥20×-magnitude outlier
     channels of App. A / Dettmers et al. This reproduces the OPT-vs-LLaMA split:
     per-token quantization collapses on the outlier-planted model, CrossQuant holds.

The trained model is cached under results/bench_model/ so re-runs are fast.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core import qlinear as ql
from repro.data.synthetic import markov_corpus
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.training import optimizer as opt_lib, trainer

CACHE_DIR = os.environ.get("BENCH_CACHE", "results/bench_model")

BENCH_CFG = ModelConfig(
    name="bench-llama", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=256, act="silu_glu", norm="rmsnorm", tie_embeddings=True,
)

VOCAB, SEQ, BATCH = 256, 64, 16
SKEW = 0.75


def train_batches(step: int, *, seed: int = 0) -> Dict[str, jnp.ndarray]:
    toks = markov_corpus(VOCAB, SEQ, BATCH, seed=seed + 7919 * step, skew=SKEW)
    return {"tokens": jnp.asarray(toks)}


def eval_batches(n: int, *, seed: int = 10_000):
    for i in range(n):
        yield train_batches(0, seed=seed + 31 * i)


def get_bench_model(steps: int = 400, force: bool = False):
    """Train (or load the cached) benchmark LM. Returns (cfg, params)."""
    cm = CheckpointManager(CACHE_DIR, keep_n=1)
    cfg = BENCH_CFG
    template = M.init_params(jax.random.PRNGKey(0), cfg)
    if not force and cm.latest_step() is not None:
        params, _ = cm.restore(template)
        return cfg, params
    opt_cfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps)
    step_fn = jax.jit(trainer.make_train_step(cfg, opt_cfg))
    params = template
    opt = opt_lib.init(params)
    for s in range(steps):
        params, opt, metrics = step_fn(params, opt, train_batches(s))
    cm.save(steps, params, blocking=True)
    print(f"# bench model trained to loss={float(metrics['loss']):.3f}")
    return cfg, params


# --------------------------------------------------------------------------------------
# Function-preserving outlier planting
# --------------------------------------------------------------------------------------

def plant_outliers(params, cfg: ModelConfig, *, frac: float = 0.03,
                   magnitude: float = 40.0, seed: int = 0):
    """Scale ``frac`` of channels by ``magnitude`` in every pre-linear norm gain and
    divide the consuming linear rows by the same factor — function-preserving, but
    the activation matrices now carry App.-A-style outlier channels."""
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    n_out = max(1, int(round(frac * d)))
    ch = rng.choice(d, size=n_out, replace=False)
    mult = np.ones(d, np.float32)
    mult[ch] = magnitude

    def scale_block(block):
        out = jax.tree_util.tree_map(lambda x: x, block)   # shallow-ish copy
        mult_j = jnp.asarray(mult)
        out["norm1"] = {**block["norm1"], "scale": block["norm1"]["scale"] * mult_j}
        out["norm2"] = {**block["norm2"], "scale": block["norm2"]["scale"] * mult_j}
        attn = dict(block["attn"])
        for k in ("wq", "wk", "wv"):
            attn[k] = {"w": block["attn"][k]["w"] / mult_j[:, None]}
        out["attn"] = attn
        mlp = dict(block["mlp"])
        for k in ("up", "gate"):
            if k in mlp:
                mlp[k] = {"w": block["mlp"][k]["w"] / mult_j[:, None]}
        out["mlp"] = mlp
        return out

    new = dict(params)
    new["blocks"] = [jax.vmap(scale_block)(params["blocks"][0])]
    return new


# --------------------------------------------------------------------------------------
# Evaluation
# --------------------------------------------------------------------------------------

def eval_ppl(cfg, params, quant: Optional[ql.QuantConfig] = None, n_batches: int = 8,
             ) -> float:
    ctx = QuantContext(quant or ql.FP)
    total, count = 0.0, 0
    for batch in eval_batches(n_batches):
        loss, m = M.loss_fn(params, batch, cfg, ctx=ctx, remat=False)
        total += float(m["ce"])
        count += 1
    return float(np.exp(total / count))


def eval_acc(cfg, params, quant: Optional[ql.QuantConfig] = None, n_batches: int = 8,
             ) -> float:
    """Top-1 next-token accuracy (the zero-shot-task stand-in; skewed chain ->
    ceiling ≈ SKEW + (1-SKEW)/branching)."""
    ctx = QuantContext(quant or ql.FP)
    hits, total = 0, 0
    for batch in eval_batches(n_batches, seed=20_000):
        logits, _ = M.apply(params, batch, cfg, ctx=ctx, mode="train")
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        want = batch["tokens"][:, 1:]
        hits += int(jnp.sum(pred == want))
        total += int(np.prod(want.shape))
    return hits / total


def mean_kernel_fraction(cfg, params, *, alpha: float = 0.15, bits: int = 8,
                         per_token: bool = False, n_batches: int = 2) -> float:
    """Average activation quantization-kernel fraction across every linear input in
    the model (eager capture via the calibration observer path)."""
    from repro.core import kernel_analysis as KA
    from repro.core import quantizers as Q

    fractions = []

    class KObserver:
        def observe(self, name, x):
            x2 = jnp.asarray(x).reshape(-1, x.shape[-1]).astype(jnp.float32)
            s = (Q.per_token_scale(x2, bits) if per_token
                 else Q.crossquant_scale(x2, bits, alpha))
            fractions.append(float(KA.kernel_fraction(x2, s)))

    ctx = QuantContext(ql.W8A8_CROSSQUANT, observer=KObserver())
    for batch in eval_batches(n_batches):
        M.apply(params, batch, cfg, ctx=ctx, mode="train", unroll=True)
    return float(np.mean(fractions))
