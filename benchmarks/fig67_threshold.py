"""Paper Figures 6/7: the quantization-kernel threshold.

"W8-Remove Kernel": weights quantized to INT8, activations untouched except that the
smallest-|x| ``frac`` of entries is zeroed. Sweeping ``frac`` traces perplexity vs
kernel proportion; the threshold is the largest fraction with <5%% ppl degradation.
Reproduced claims: a sharp knee exists (paper: 19-25%% for OPT, 1-2%% for LLaMA —
the knee location is model-dependent; the *existence and sharpness* of the knee and
its role as the safe-operation bound are the reproduced phenomena).
"""
from __future__ import annotations

from benchmarks import common as C
from benchmarks.regimes import REGIMES
from repro.core import qlinear as ql


def run(quick: bool = False):
    cfg, params = C.get_bench_model()
    nb = 2 if quick else 4
    fracs = [0.0, 0.1, 0.25, 0.4, 0.6] if quick else \
        [0.0, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4, 0.5, 0.6, 0.7]
    lines = ["fig67,regime,removed_frac,ppl"]
    thresholds = []
    for regime in (["opt_like"] if quick else ["llama_like", "opt_like"]):
        planted = C.plant_outliers(params, cfg, **REGIMES[regime])
        base = C.eval_ppl(cfg, planted, ql.remove_kernel_cfg(0.0), n_batches=nb)
        thr = 0.0
        for frac in fracs:
            ppl = C.eval_ppl(cfg, planted, ql.remove_kernel_cfg(frac), n_batches=nb)
            lines.append(f"fig67,{regime},{frac},{ppl:.3f}")
            if ppl <= 1.05 * base:
                thr = frac
        thresholds.append(f"fig67,{regime},threshold,{thr}")
    return lines + thresholds


if __name__ == "__main__":
    print("\n".join(run()))
