"""Paper Table 1: per-alpha statistics on the opt-like regime.

Columns: proportion of positions with c_j >= t_i (case II of the §4.2 proof),
proportion with shrunken zero-bound B̃ < B, quantization-kernel fraction, and W8A8
perplexity. alpha = 1 degenerates to per-token quantization (the paper's 3e+4-ppl
row; here the collapse magnitude tracks the planted-outlier strength).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from benchmarks.regimes import REGIMES
from repro.core import kernel_analysis as KA
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.layers import QuantContext


def _captured_stats(cfg, params, alpha: float):
    stats = []

    class Obs:
        def observe(self, name, x):
            x2 = jnp.asarray(x).reshape(-1, x.shape[-1]).astype(jnp.float32)
            stats.append({k: float(v) for k, v in
                          KA.table1_stats(x2, 8, alpha).items()})

    ctx = QuantContext(ql.W8A8_CROSSQUANT, observer=Obs())
    for batch in C.eval_batches(2):
        M.apply(params, batch, cfg, ctx=ctx, mode="train", unroll=True)
    return {k: float(np.mean([s[k] for s in stats])) for k in stats[0]}


def run(quick: bool = False):
    cfg, params = C.get_bench_model()
    planted = C.plant_outliers(params, cfg, **REGIMES["opt_like"])
    lines = ["table1,alpha,c_ge_t,b_shrunk,kernel_cq,kernel_pt,ppl_w8a8"]
    alphas = [0.15, 0.45] if quick else [0.15, 0.45, 0.75, 1.0]
    for alpha in alphas:
        s = _captured_stats(cfg, planted, alpha)
        qc = dataclasses.replace(ql.W8A8_CROSSQUANT, alpha=alpha)
        ppl = C.eval_ppl(cfg, planted, qc, n_batches=2 if quick else 4)
        lines.append(
            f"table1,{alpha},{s['c_ge_t']:.4f},{s['bcq_lt_bpt']:.4f},"
            f"{s['kernel_crossquant']:.4f},{s['kernel_per_token']:.4f},{ppl:.3f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
