"""Paper Figure 4: quantization-kernel proportion of Per-token vs CrossQuant across
"model scales".

Scale is stood in for by outlier strength (App. A: outliers emerge past 6.7B), via
(a) the planted-outlier bench model at increasing magnitude, and (b) synthetic
activation ensembles with the paper's outlier statistics. Reproduced claims: the
per-token kernel jumps from ~15%% to 40-65%% as outliers strengthen (OPT side of
Fig. 4) while CrossQuant stays flat and small; mild regimes keep per-token ~10%%
with CrossQuant near zero (LLaMA side).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common as C
from repro.core import kernel_analysis as KA
from repro.data.synthetic import OutlierSpec, outlier_activations


def run(quick: bool = False):
    lines = ["fig4,source,scale,kernel_per_token,kernel_crossquant"]

    # (a) planted bench model at increasing outlier magnitude
    cfg, params = C.get_bench_model()
    mags = [1.0, 20.0, 80.0, 150.0] if quick else [1.0, 10.0, 40.0, 80.0, 150.0, 300.0]
    for mag in mags:
        planted = (params if mag == 1.0
                   else C.plant_outliers(params, cfg, frac=0.08, magnitude=mag))
        k_pt = C.mean_kernel_fraction(cfg, planted, per_token=True, n_batches=1)
        k_cq = C.mean_kernel_fraction(cfg, planted, per_token=False, n_batches=1)
        lines.append(f"fig4,model,mag{mag:g},{k_pt:.4f},{k_cq:.4f}")

    # (b) synthetic ensembles sweeping the outlier channel fraction
    for frac in ([0.0005, 0.004] if quick else [0.0002, 0.001, 0.002, 0.004, 0.008]):
        spec = OutlierSpec(frac_channels=frac, magnitude=60.0, row_frac=0.8)
        x = jnp.asarray(outlier_activations(1024, 2048, spec, seed=0))
        from repro.core import quantizers as Q
        k_pt = float(KA.kernel_fraction(x, Q.per_token_scale(x, 8)))
        k_cq = float(KA.kernel_fraction(x, Q.crossquant_scale(x, 8, 0.15)))
        lines.append(f"fig4,ensemble,frac{frac:g},{k_pt:.4f},{k_cq:.4f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
