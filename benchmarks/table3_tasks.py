"""Paper Table 3/5 + Figure 1/9: task accuracy across methods and regimes.

Accuracy = top-1 next-token accuracy on held-out skewed-Markov data (the zero-shot
stand-in; ceiling ≈ 0.81). Reproduced claims:

  * per-token A8 accuracy collapses once outliers are strong (OPT-30B/66B rows where
    Lambada -> 0.00%), while CrossQuant stays at the fp ceiling;
  * "Remove Kernel" — zeroing ONLY the kernel elements, quantizing nothing — tracks
    the per-token A8 accuracy (Fig. 1/9: the kernel is the cause of the loss);
  * W4A4: per-token at chance, CrossQuant degrades but stays far above.
"""
from __future__ import annotations

from benchmarks import common as C
from benchmarks.regimes import REGIMES
from repro.core import qlinear as ql
from repro.models import quantize as MQ


def run(quick: bool = False):
    cfg, params = C.get_bench_model()
    nb = 2 if quick else 5
    lines = ["table3,regime,method,acc"]
    regimes = ["opt_like", "opt_xl"] if not quick else ["opt_xl"]
    for regime in regimes:
        planted = C.plant_outliers(params, cfg, **REGIMES[regime])
        kf_pt = C.mean_kernel_fraction(cfg, planted, per_token=True, n_batches=1)
        rows = [
            ("fp16", None),
            ("per_token_w8a8", ql.W8A8_PER_TOKEN),
            ("smoothquant_w8a8", ql.W8A8_SMOOTHQUANT),
            ("crossquant_w8a8", ql.W8A8_CROSSQUANT),
            # Fig. 1 ablation: zero exactly K(Q_per-token), quantize nothing else in
            # the activations (weights still W8) — must track per_token_w8a8.
            ("remove_true_kernel", ql.REMOVE_TRUE_KERNEL),
            # Fig. 6/7-style global-quantile removal at the same mass, for contrast.
            (f"remove_frac@{kf_pt:.2f}", ql.remove_kernel_cfg(kf_pt)),
            ("per_token_w4a4", ql.W4A4_PER_TOKEN),
            ("crossquant_w4a4", ql.W4A4),
        ]
        for name, qc in rows:
            acc = C.eval_acc(cfg, planted, qc, n_batches=nb)
            lines.append(f"table3,{regime},{name},{acc:.4f}")
        # Beyond-paper: plan-gated 2:4 pruning under CrossQuant W8A8
        # (DESIGN.md §3.12) — accuracy should track crossquant_w8a8.
        plan = MQ.make_sparsity_plan(cfg, planted, C.eval_batches(1),
                                     threshold=0.10)
        sparams = MQ.sparsify_tree(planted, plan)
        acc = C.eval_acc(cfg, sparams, ql.W8A8_CROSSQUANT, n_batches=nb)
        lines.append(f"table3,{regime},crossquant_w8a8_sparse24,{acc:.4f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
