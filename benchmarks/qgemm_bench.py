"""Kernel-level roofline micro-benchmark for the Pallas qgemm/act-quantize kernels,
plus an end-to-end fp-vs-fused-int8 serving comparison (DESIGN.md §3.3/§7).

No TPU is attached, so wall-clock numbers are CPU-interpret sanity only; the
*derived* columns are the structural roofline terms for TPU v5e per kernel call:
bytes moved (HBM), int8 MXU ops, arithmetic intensity, and the projected
compute-vs-memory-bound time. GEMM shapes are the hot projections of the assigned
archs at the paper's W8A8 setting.

Reported speedup logic (recorded in §Perf): against a bf16 GEMM of the same shape,
the int8 path moves ~half the weight bytes and runs the MXU at 2x throughput —
projected_bf16 / projected_int8 is the kernel-level headline.

The ``e2e`` section serves the same request batch through the continuous batcher on
the fp path and the fused-int8 path (ServeEngine path="fused-int8"): measured CPU
tokens/sec for both, plus the projected TPU step-time ratio from the model's
decode-GEMM shapes. On CPU the fused path *loses* wall-clock (Pallas interpret
overhead) — the projected column is the deployment-relevant number.

The ``qgemm_sparse`` section times the §3.12 block-sparse kernel against the dense
kernel at varying K-block occupancy (the regress gate pins sparse <= dense on the
skipped-block rows), and ``e2e_sparse`` serves a 2:4-sparsified tree vs the dense
int8 tree plus the deployment-capacity column (extra KV pages per device at fixed
HBM, from ``quantized_bytes(deploy_sparse=True)``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

PEAK_INT8 = 394e12
PEAK_BF16 = 197e12
HBM_BW = 819e9

# (arch tag, M=tokens-per-chip-step, K=d_model, N=output dim of the hot projection)
SHAPES = [
    ("deepseek33b.ffn_up", 4096, 7168, 19200 // 16),
    ("gemma2_9b.ffn_up", 4096, 3584, 14336 // 16),
    ("nemotron15b.ffn_up", 4096, 6144, 24576 // 16),
    ("llama4.expert_up", 5120, 5120, 8192),
    ("starcoder2.qkv", 4096, 4608, 6144 // 16),
]


def derived(M, K, N, w_bits=8):
    bytes_moved = M * K + (K * N) * (w_bits / 8) + M * N * 4 + M * 4 + N * 4
    ops = 2 * M * K * N
    t_compute_int8 = ops / PEAK_INT8
    t_mem = bytes_moved / HBM_BW
    t_int8 = max(t_compute_int8, t_mem)
    bf16_bytes = 2 * (M * K + K * N + M * N)
    t_bf16 = max(ops / PEAK_BF16, bf16_bytes / HBM_BW)
    return bytes_moved, ops, ops / bytes_moved, t_int8, t_bf16


def _serve_tok_s(cfg, params, *, quant, path, kv_cache, n_req, max_new) -> float:
    from repro.serving.config import EngineConfig
    from repro.serving.engine import ServeEngine
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=8).astype(np.int32)
               for _ in range(n_req)]
    config = EngineConfig(batch_size=min(4, n_req), max_len=32, eos_id=-1,
                          path=path, kv_cache=kv_cache)
    eng = ServeEngine(cfg, params, config=config, quant=quant)
    eng.submit(prompts, max_new=max_new)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    return sum(len(r.out) for r in done) / dt


def sparse(quick: bool = False):
    """Block-sparse int8 GEMM (DESIGN.md §3.12) vs the dense kernel at varying
    K-block occupancy, both through the ops dispatch in interpret mode.

    The occupancy=1.00 row measures pure bookkeeping overhead (the wrapper's
    runtime cond routes it to the dense kernel); the sub-full rows measure the
    win from skipped MXU dots — interpret mode genuinely skips the gated work,
    so the regress gate pins ``sparse <= dense`` wall-clock there. Projected
    TPU columns scale the roofline terms by occupancy (compute and weight
    bytes shrink together; activations and output do not)."""
    from repro.kernels import ops

    M, K, N = (256, 1024, 256) if quick else (256, 2048, 256)
    bk = 256
    key = jax.random.PRNGKey(0)
    qx = jax.random.randint(key, (M, K), -127, 128, jnp.int8)
    qw = jax.random.randint(key, (K, N), -127, 128, jnp.int8)
    a = jnp.ones((M, 1), jnp.float32)
    sw = jnp.ones((N,), jnp.float32)

    def t_us(f):
        f().block_until_ready()
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            f().block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6

    lines = ["qgemm_sparse,occupancy,cpu_dense_us,cpu_sparse_us,ratio,"
             "proj_tpu_us_dense,proj_tpu_us_sparse"]
    n_k = K // bk
    bytes_d, ops_d, _, t_dense_tpu, _ = derived(M, K, N)
    for occ_frac in (1.0, 0.5, 0.25):
        keep = jnp.repeat(jnp.arange(n_k) < round(occ_frac * n_k), bk)[:, None]
        mask = keep & jnp.ones((K, N), bool)
        qwm = jnp.where(mask, qw, 0)
        cpu_s = t_us(lambda: ops.qgemm_w8a8_sparse(qx, qwm, a, sw, mask,
                                                   bm=256, bn=256, bk=bk))
        cpu_d = t_us(lambda: ops.qgemm_w8a8(qx, qwm, a, sw, bm=256, bn=256,
                                            bk=bk))
        sp_bytes = M * K + K * N * occ_frac + K * N / 8 + M * N * 4 + M * 4 + N * 4
        t_sp_tpu = max(ops_d * occ_frac / PEAK_INT8, sp_bytes / HBM_BW)
        lines.append(f"qgemm_sparse,{occ_frac:.2f},{cpu_d:.0f},{cpu_s:.0f},"
                     f"{cpu_s / cpu_d:.2f},{t_dense_tpu * 1e6:.1f},"
                     f"{t_sp_tpu * 1e6:.1f}")
    return lines


def e2e_sparse(quick: bool = False):
    """Sparse-vs-dense fused-int8 serving on the smoke model: CPU tok/s for
    both, plus the §3.12 capacity column — the HBM a 2:4 deployment format
    hands back, expressed as extra KV pages per device at fixed HBM."""
    from repro.configs import get
    from repro.core import qlinear as ql
    from repro.models import model as M2
    from repro.models import quantize as MQ

    cfg = get("starcoder2-7b", smoke=True)
    params = M2.init_params(jax.random.PRNGKey(0), cfg)
    qparams = MQ.quantize_tree(params, ql.W8A8_INT8)
    sparams = MQ.sparsify_tree(qparams, MQ.SparsityPlan(nm=(2, 4)))
    n_req, max_new = (2, 4) if quick else (4, 8)
    dense = _serve_tok_s(cfg, qparams, quant=ql.W8A8_INT8, path="fused-int8",
                         kv_cache="int8", n_req=n_req, max_new=max_new)
    sp = _serve_tok_s(cfg, sparams, quant=ql.W8A8_INT8, path="fused-int8",
                      kv_cache="int8", n_req=n_req, max_new=max_new)
    dense_b = MQ.quantized_bytes(qparams)
    deploy_b = MQ.quantized_bytes(sparams, deploy_sparse=True)
    # one int8-KV page: page_size tokens x (k + v) x kv heads x head_dim x
    # n_layers bytes (scales are amortized per page row and negligible here)
    page_b = 8 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers
    extra_pages = (dense_b - deploy_b) / page_b
    return [
        "e2e_sparse,arch,cpu_dense_tok_s,cpu_sparse_tok_s,ratio,dense_bytes,"
        "deploy_bytes,extra_pages_per_dev",
        f"e2e_sparse,{cfg.name},{dense:.1f},{sp:.1f},{sp / dense:.2f},"
        f"{dense_b},{deploy_b},{extra_pages:.0f}",
    ]


def e2e(quick: bool = False):
    """End-to-end continuous-batching comparison: fp vs fused-int8 (+ int8 KV)."""
    from repro.configs import get
    from repro.core import qlinear as ql
    from repro.models import model as M2
    from repro.models.quantize import quantize_tree

    cfg = get("starcoder2-7b", smoke=True)
    params = M2.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params, ql.W8A8_INT8)
    n_req, max_new = (2, 4) if quick else (4, 8)
    fp = _serve_tok_s(cfg, params, quant=ql.FP, path=None, kv_cache="fp",
                      n_req=n_req, max_new=max_new)
    fused = _serve_tok_s(cfg, qparams, quant=ql.W8A8_INT8, path="fused-int8",
                         kv_cache="int8", n_req=n_req, max_new=max_new)
    # Projected TPU ratio from the decode hot GEMMs of this config (structural —
    # the same roofline terms as the qgemm section, summed over the layer's dots).
    d, f = cfg.d_model, cfg.d_ff
    shapes = [(n_req, d, cfg.n_heads * cfg.head_dim),
              (n_req, cfg.n_heads * cfg.head_dim, d),
              (n_req, d, f), (n_req, f, d)]
    t8 = sum(derived(M, K, N)[3] for M, K, N in shapes)
    t16 = sum(derived(M, K, N)[4] for M, K, N in shapes)
    return [
        "e2e,arch,cpu_fp_tok_s,cpu_int8_tok_s,cpu_ratio,proj_tpu_ratio",
        f"e2e,{cfg.name},{fp:.1f},{fused:.1f},{fused / fp:.2f},{t16 / t8:.2f}",
    ]


def run(quick: bool = False):
    lines = ["qgemm,shape,bytes,int8_ops,intensity,proj_tpu_us,proj_bf16_us,speedup,"
             "cpu_ref_us"]
    shapes = SHAPES[:2] if quick else SHAPES
    for tag, M, K, N in shapes:
        b, ops, inten, t8, t16 = derived(M, K, N)
        # CPU sanity timing of the jnp reference int8 GEMM (not a TPU number).
        qx = jnp.ones((min(M, 256), K), jnp.int8)
        qw = jnp.ones((K, min(N, 256)), jnp.int8)
        a = jnp.ones((min(M, 256), 1), jnp.float32)
        sw = jnp.ones((min(N, 256),), jnp.float32)
        from repro.kernels.ref import qgemm_w8a8_ref
        f = jax.jit(qgemm_w8a8_ref)
        f(qx, qw, a, sw).block_until_ready()
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            f(qx, qw, a, sw).block_until_ready()
        cpu_us = (time.perf_counter() - t0) / reps * 1e6
        lines.append(f"qgemm,{tag},{b:.3g},{ops:.3g},{inten:.0f},"
                     f"{t8 * 1e6:.1f},{t16 * 1e6:.1f},{t16 / t8:.2f},{cpu_us:.0f}")
    lines.extend(sparse(quick))
    lines.extend(e2e(quick))
    lines.extend(e2e_sparse(quick))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
