"""Beyond-paper benchmark: CrossQuant geometry applied to gradient compression
(DESIGN.md §3.5).

Measures (a) the quantization-kernel fraction of real training gradients under
per-tensor vs CrossQuant int8 scaling, and (b) end-to-end training-loss impact of
int8 gradient compression with/without error feedback. The claim transplanted from
the paper: row^alpha x col^(1-alpha) scaling shrinks the gradient quantization
kernel by an order of magnitude, making int8 DP all-reduce payloads nearly lossless.
"""
from __future__ import annotations

import jax
from benchmarks import common as C
from repro.training import compression as comp_lib
from repro.training import optimizer as opt_lib, trainer
from repro.models import model as M


def run(quick: bool = False):
    cfg = C.BENCH_CFG
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    steps = 15 if quick else 40
    lines = ["gradcomp,scheme,error_feedback,final_loss,grad_kernel_frac"]

    # kernel fraction of an actual early-training gradient
    batch = C.train_batches(0)
    (_, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg, remat=False), has_aux=True)(params)
    g = grads["blocks"][0]["attn"]["wq"]["w"]          # (L, d, hd) stacked
    g2 = g.reshape(-1, g.shape[-1])
    fr = comp_lib.gradient_kernel_fractions(g2)

    for scheme, ef in [("none", False), ("per_tensor", False), ("per_tensor", True),
                       ("crossquant", False), ("crossquant", True)]:
        ccfg = comp_lib.CompressionConfig(scheme=scheme, error_feedback=ef)
        opt_cfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
        step_fn = jax.jit(trainer.make_train_step(cfg, opt_cfg, compression=ccfg))
        p = M.init_params(jax.random.PRNGKey(0), cfg)
        opt = opt_lib.init(p)
        err = comp_lib.init_error_state(p)
        loss = float("nan")
        for s in range(steps):
            p, opt, err, m = step_fn(p, opt, err, C.train_batches(s))
            loss = float(m["loss"])
        kf = (0.0 if scheme == "none"
              else float(fr[scheme] if scheme in fr else 0.0))
        lines.append(f"gradcomp,{scheme},{ef},{loss:.4f},{kf:.4f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
