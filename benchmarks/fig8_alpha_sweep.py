"""Paper Figure 8: accuracy / perplexity as a function of alpha.

Reproduced claims: performance is flat-good for alpha <= ~0.55 and collapses as
alpha -> 1 (per-token limit); the optimum sits at small alpha. Left panel: W8A8
accuracy (paper: OPT-6.7B Lambada); right: W4A8 perplexity (paper: LLaMA2-13B).
"""
from __future__ import annotations

import dataclasses

from benchmarks import common as C
from benchmarks.regimes import REGIMES
from repro.core import qlinear as ql


def run(quick: bool = False):
    cfg, params = C.get_bench_model()
    planted = C.plant_outliers(params, cfg, **REGIMES["opt_xl"])
    nb = 2 if quick else 4
    alphas = [0.15, 0.55, 0.95] if quick else \
        [0.05, 0.15, 0.25, 0.35, 0.45, 0.55, 0.65, 0.75, 0.85, 0.95, 1.0]
    lines = ["fig8,alpha,acc_w8a8,ppl_w4a8"]
    for alpha in alphas:
        qc8 = dataclasses.replace(ql.W8A8_CROSSQUANT, alpha=alpha)
        qc4 = dataclasses.replace(ql.W4A8_G128, alpha=alpha)
        acc = C.eval_acc(cfg, planted, qc8, n_batches=nb)
        ppl = C.eval_ppl(cfg, planted, qc4, n_batches=nb)
        lines.append(f"fig8,{alpha},{acc:.4f},{ppl:.3f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
