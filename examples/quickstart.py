"""Quickstart: the paper in 60 seconds.

Builds a small LLaMA-style model, plants App.-A-style outlier channels
(function-preserving), and shows the paper's core result: per-token INT8 activation
quantization collapses because of its quantization kernel; CrossQuant — same bits,
smaller kernel — matches fp16.

    PYTHONPATH=src:. python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core import kernel_analysis as KA
from repro.core import qlinear as ql
from repro.core import quantizers as Q
from repro.data.synthetic import OPT_LIKE, outlier_activations
from repro.models import model as M
from repro.models.layers import QuantContext


def main() -> None:
    # --- 1. the quantization kernel on an outlier-heavy activation matrix ----------
    x = jnp.asarray(outlier_activations(512, 1024, OPT_LIKE, seed=0))
    k_pt = float(KA.kernel_fraction(x, Q.per_token_scale(x, 8)))
    k_cq = float(KA.kernel_fraction(x, Q.crossquant_scale(x, 8, alpha=0.15)))
    print(f"quantization kernel |K(Q)|/|X|:  per-token={k_pt:.1%}  "
          f"CrossQuant(a=0.15)={k_cq:.1%}")

    # --- 2. quantization error on the same matrix -----------------------------------
    err_pt = float(jnp.linalg.norm(Q.fake_per_token(x, 8) - x) / jnp.linalg.norm(x))
    err_cq = float(jnp.linalg.norm(Q.fake_crossquant(x, 8, 0.15) - x)
                   / jnp.linalg.norm(x))
    print(f"relative quantization error:     per-token={err_pt:.4f}  "
          f"CrossQuant={err_cq:.4f}")

    # --- 3. end-to-end on a model: logits drift under W8A8 --------------------------
    cfg = get("deepseek-coder-33b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab)}
    logits_fp, _ = M.apply(params, batch, cfg, mode="train")
    for name, qc in [("per-token W8A8", ql.W8A8_PER_TOKEN),
                     ("CrossQuant W8A8", ql.W8A8_CROSSQUANT)]:
        logits_q, _ = M.apply(params, batch, cfg, ctx=QuantContext(qc), mode="train")
        drift = float(jnp.linalg.norm(logits_q - logits_fp)
                      / jnp.linalg.norm(logits_fp))
        print(f"{name}: logit drift vs fp = {drift:.4f}")


if __name__ == "__main__":
    main()
