"""Batched serving of a PTQ-quantized model.

Calibrates CrossQuant's static column statistics on synthetic traffic, folds them
into true-int8 weights (quantize_tree), and serves a batch of requests through the
continuous-batching engine. ``--path`` selects the integer execution backend
(DESIGN.md §3.3) and ``--kv-cache int8`` stores decode K/V as int8 codes +
per-token scales; ``--compare`` serves the same workload through the fp baseline
and the fused int8 path and reports both tokens/sec.

    PYTHONPATH=src:. python examples/serve_batch.py [--quant int8|fake|fp]
        [--path ref|dequant-fp|fused-int8] [--kv-cache fp|int8] [--compare]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import calibration, qlinear as ql
from repro.data import make_train_batches
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.models.quantize import quantize_tree, quantized_bytes
from repro.serving.engine import ServeEngine


def calibrate_and_quantize(cfg, params, quant):
    print("calibrating static-c column stats on 2 batches ...")
    obs = calibration.Observer()
    batch_fn = make_train_batches(cfg.vocab, 16, 4, seed=1)
    for b in range(2):
        batch = {k: jnp.asarray(v) for k, v in batch_fn(b).items()}
        M.apply(params, batch, cfg, ctx=QuantContext(quant, observer=obs),
                mode="train", unroll=True)
    before = quantized_bytes(params)
    qparams = quantize_tree(params, quant,
                            tables=calibration.stack_tables(obs.tables()))
    after = quantized_bytes(qparams)
    print(f"weights {before / 2**20:.1f} MiB -> {after / 2**20:.1f} MiB "
          f"({before / after:.2f}x smaller)")
    return qparams


def serve(cfg, params, prompts, *, quant, path=None, kv_cache="fp",
          max_new=12, tag=""):
    engine = ServeEngine(cfg, params, batch_size=4, max_len=48, quant=quant,
                         eos_id=-1, path=path, kv_cache=kv_cache)
    engine.submit([p.copy() for p in prompts], max_new=max_new)
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"[{tag or (path or 'ref')}] served {len(done)} requests / {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s, kv={kv_cache})")
    return done, total / dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default="int8", choices=["fp", "fake", "int8"])
    ap.add_argument("--path", default="fused-int8",
                    choices=["ref", "dequant-fp", "fused-int8"],
                    help="integer execution backend (int8 quant only)")
    ap.add_argument("--kv-cache", default="fp", choices=["fp", "int8"])
    ap.add_argument("--compare", action="store_true",
                    help="also serve the fp baseline and report both tok/s")
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--n-requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    quant = {"fp": ql.FP, "fake": ql.W8A8_CROSSQUANT, "int8": ql.W8A8_INT8}[args.quant]

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=12).astype(np.int32)
               for _ in range(args.n_requests)]

    if args.quant != "int8":
        # The int8 KV cache is independent of weight quantization and applies to
        # fp/fake serving too; only --path needs a prepared integer tree.
        if args.path != "fused-int8":
            print(f"note: --path {args.path} only applies to --quant int8; ignored")
        done, _ = serve(cfg, params, prompts, quant=quant, kv_cache=args.kv_cache,
                        tag=args.quant)
    else:
        qparams = calibrate_and_quantize(cfg, params, quant)
        path = None if args.path == "ref" else args.path
        done, int8_tps = serve(cfg, qparams, prompts, quant=quant, path=path,
                               kv_cache=args.kv_cache)
        if args.compare:
            _, fp_tps = serve(cfg, params, prompts, quant=ql.FP, tag="fp-baseline")
            print(f"end-to-end tokens/sec: fp={fp_tps:.1f} "
                  f"{args.path}={int8_tps:.1f} ({int8_tps / fp_tps:.2f}x; "
                  "CPU-interpret numbers — the kernel-level TPU projection is in "
                  "benchmarks/qgemm_bench.py)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.prompt[:4].tolist()}... -> {r.out[:6]}")


if __name__ == "__main__":
    main()
