"""Continuous-batched serving of a PTQ-quantized model on a mixed-length workload.

Calibrates CrossQuant's static column statistics on synthetic traffic, folds them
into true-int8 weights (quantize_tree), and serves a *mixed-length* batch of
requests (three prompt lengths, staggered ``max_new``) through the slot-table
continuous batcher (DESIGN.md §3.6): prompts are admitted into free slots via
length-bucketed padded prefill and retired slots refill mid-decode. ``--path``
selects the integer execution backend (DESIGN.md §3.3) and ``--kv-cache int8``
stores decode K/V as int8 codes + per-token scales; ``--compare`` serves the same
workload through the fp baseline and the fused int8 path and reports both
tokens/sec plus slot occupancy. ``--quant-kernel-stats`` replays the served
traffic (prompt + generated tokens) through the model eagerly and reports the
paper's per-layer quantization-kernel proportion (core/kernel_analysis.py) for
per-token quantization vs CrossQuant — the §4.1 statistic, measured on what the
engine actually served rather than a calibration set. For MoE configs
(``--arch granite-moe-3b-a800m`` / ``llama4-scout-17b-a16e``) the report adds
per-expert rows: each expert quantizes its own routed-token block of the
stacked (E, C, d) dispatch buffer, so the kernel proportion is a per-expert
property (padding rows excluded).

``--cache-layout paged`` serves through the paged KV pool with radix prefix
reuse (DESIGN.md §3.8); with ``--shared-prefix N`` every prompt carries an
N-token shared system prompt, so admissions past the first map the cached
prefix pages copy-free and only prefill their suffix (the printed
``prefix_hit_rate`` / ``prefill_saved`` stats).

``--speculate K`` serves speculative (DESIGN.md §3.9): each model step verifies
a K-token draft window proposed by the self-drafting prompt-lookup drafter —
token-exact vs plain decode by greedy acceptance, with accept rate and emitted
tokens/step printed. Pays off on repetitive traffic (templates, code); combine
with ``--cache-layout paged --kv-cache int8`` for the full paged-int8 verify
path.

``--chunked --token-budget N`` serves with chunked prefill + prefill-decode
interleaving (DESIGN.md §3.10): every step packs each generating slot's decode
row first, then fills the leftover budget with prompt chunks through the
ragged flash-prefill kernel — token-exact vs unchunked admission. Combined
with ``--quant-kernel-stats``, the replay additionally reports the per-chunk
CrossQuant kernel proportion (the §4.1 statistic computed over each
token_budget-sized admission slice) and its token-weighted aggregate against
the whole-prompt figure — chunked admission leaves the metric unchanged.

``--sparsity 2:4`` prunes every eligible linear to N:M structured sparsity at
engine build (DESIGN.md §3.12) — scales refit to the survivors, a bit-packed
keep-mask rides the tree, and the fused path serves through the block-sparse
int8 kernel. The report prints pruned-linear count, kept fraction, and the
dense-layout vs N:M-deploy weight bytes.

``--mesh data,model`` serves TP-sharded on a host mesh (DESIGN.md §3.7) — set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.

Engine flags derive from the :class:`EngineConfig` dataclass fields
(``add_config_args``, DESIGN.md §3.11) and ``--config path.json`` loads a JSON
EngineConfig first with explicit flags layered on top; leaving ``--path`` unset
serves on the jnp ref backend.

    PYTHONPATH=src:. python examples/serve_batch.py [--quant int8|fake|fp]
        [--path dequant-fp|fused-int8] [--kv-cache fp|int8] [--compare]
        [--prompt-lens 6,10,14] [--eos-id N] [--quant-kernel-stats]
        [--mesh 4,2] [--speculate 4] [--cache-layout paged]
        [--chunked --token-budget 16] [--sparsity 2:4] [--config engine.json]
"""
import argparse
import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import calibration, kernel_analysis as KA, qlinear as ql
from repro.data import make_train_batches
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.models.quantize import quantize_tree, quantized_bytes
from repro.serving.config import EngineConfig, add_config_args, config_from_args
from repro.serving.engine import ServeEngine


def calibrate_and_quantize(cfg, params, quant):
    print("calibrating static-c column stats on 2 batches ...")
    obs = calibration.Observer()
    batch_fn = make_train_batches(cfg.vocab, 16, 4, seed=1)
    for b in range(2):
        batch = {k: jnp.asarray(v) for k, v in batch_fn(b).items()}
        M.apply(params, batch, cfg, ctx=QuantContext(quant, observer=obs),
                mode="train", unroll=True)
    before = quantized_bytes(params)
    qparams = quantize_tree(params, quant,
                            tables=calibration.stack_tables(obs.tables()))
    after = quantized_bytes(qparams)
    print(f"weights {before / 2**20:.1f} MiB -> {after / 2**20:.1f} MiB "
          f"({before / after:.2f}x smaller)")
    return qparams


def mixed_workload(cfg, n_requests, prompt_lens, seed=0, shared_prefix=0):
    """Mixed prompt lengths + staggered max_new: the continuous-batching case.
    ``shared_prefix`` prepends that many identical tokens to every prompt (a
    shared system prompt) — the paged layout's prefix-reuse case."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab, size=shared_prefix).astype(np.int32)
    prompts = [np.concatenate([
        shared, rng.integers(1, cfg.vocab,
                             size=prompt_lens[i % len(prompt_lens)]).astype(np.int32)])
        for i in range(n_requests)]
    max_new = [8 + 4 * (i % 3) for i in range(n_requests)]
    return prompts, max_new


def serve(cfg, params, prompts, max_new, *, config, quant, tag="", mesh=None):
    engine = ServeEngine(cfg, params, config=config, quant=quant, mesh=mesh)
    engine.submit([p.copy() for p in prompts], max_new=list(max_new))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    st = engine.stats()
    shard = f", tp={engine.plan.tp} tier={engine.plan.tier}" if engine.plan else ""
    paged = ""
    if config.cache_layout == "paged":
        paged = (f", prefix_hit_rate={st.prefix_hit_rate:.2f}, "
                 f"prefill_saved={st.counters['prefix_tokens_reused']}, "
                 f"peak_pages={st.counters['peak_pages_in_use']}"
                 f"/{engine.pool.n_pages}")
    spec = ""
    if config.speculate > 1:
        spec = (f", speculate={config.speculate} "
                f"accept_rate={st.accept_rate:.2f} "
                f"tok/step={st.tokens_per_step:.2f}")
    if config.chunked:
        spec += (f", token_budget={config.token_budget} "
                 f"chunk_steps={st.counters['chunk_steps']} "
                 f"prefill_rows={st.counters['chunk_prefill_rows']}")
    print(f"[{tag or (config.path or 'ref')}] served {len(done)} requests / "
          f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s, "
          f"kv={config.kv_cache}, occupancy={st.occupancy:.2f}, "
          f"refills_mid_decode={st.counters['mid_decode_admissions']}"
          f"{paged}{spec}{shard})")
    return done, total / dt


class _KernelStatsObserver:
    """Observer shim (calibration.Observer protocol): per-layer kernel fractions."""

    def __init__(self, bits: int, alpha: float, chunk: int = 0):
        self.bits, self.alpha, self.chunk = bits, alpha, chunk
        self.stats: dict = {}

    def observe(self, name, x):
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        rec = self.stats.setdefault(name, {"pt": [], "cq": [], "chunks": [],
                                           "experts": {}})
        if x.ndim == 3 and "/moe/" in name:
            # Stacked (E, C, d) expert dispatch buffer (moe_apply serves the
            # observer replay with one global dispatch): row e of the leading
            # axis is expert e's routed tokens, zero rows are capacity padding.
            # The §4.1 proportion is computed per expert over its *routed* rows
            # only — each expert quantizes its own (C, d) activation block, so
            # the kernel statistic is a per-expert property (DESIGN.md §4).
            for e in range(x.shape[0]):
                rows = jnp.asarray(x[e], jnp.float32)
                rows = rows[jnp.any(rows != 0.0, axis=-1)]
                er = rec["experts"].setdefault(e, {"pt": [], "cq": [], "n": 0})
                er["n"] += int(rows.shape[0])
                if rows.shape[0]:
                    er["pt"].append(
                        float(KA.per_token_kernel_fraction(rows, self.bits)))
                    er["cq"].append(
                        float(KA.crossquant_kernel_fraction(rows, self.bits,
                                                            self.alpha)))
            x2 = x2[jnp.any(x2 != 0.0, axis=-1)]   # layer row: routed rows only
            if x2.shape[0] == 0:
                return
        rec["pt"].append(float(KA.per_token_kernel_fraction(x2, self.bits)))
        rec["cq"].append(float(KA.crossquant_kernel_fraction(x2, self.bits,
                                                             self.alpha)))
        if self.chunk:
            # token_budget-sized row slices: the activation rows one chunked
            # admission step quantizes together. CrossQuant's column max c_j
            # is re-derived from only the chunk's rows — the dynamic-c view
            # of chunked admission (static-c serving is chunk-invariant by
            # construction: its c_j comes from calibration, not the chunk).
            for lo in range(0, x2.shape[0], self.chunk):
                part = x2[lo: lo + self.chunk]
                rec["chunks"].append(
                    (part.shape[0],
                     float(KA.crossquant_kernel_fraction(part, self.bits,
                                                         self.alpha))))


def report_kernel_stats(cfg, params, quant, done, chunk: int = 0):
    """Replay the served traffic eagerly and print per-layer kernel proportions.

    The replay runs each request's prompt + generated tokens through the model in
    unroll mode (observers cannot run under scan) on the ref backend — the
    activations feeding every quantized linear are exactly those of the served
    sequences, so the reported proportions are traffic-faithful (paper §4.1).

    With ``chunk`` (the ``--chunked`` serve's token budget), a second table
    slices each layer's activation rows into token_budget-sized chunks — the
    rows one chunked admission step quantizes together — and compares the
    token-weighted aggregate of per-chunk CrossQuant proportions against the
    whole-prompt figure. Causal attention makes the activations themselves
    identical either way, so any gap is purely the dynamic column statistic
    c_j seeing fewer rows per chunk; the aggregate staying at the whole-prompt
    value is the §4.1 metric's invariance under chunked admission.
    """
    bits = getattr(quant, "a_bits", 8) or 8
    alpha = getattr(quant, "alpha", 0.15)
    obs = _KernelStatsObserver(bits, alpha, chunk=chunk)
    ctx = QuantContext(quant, observer=obs)
    for r in done:
        toks = np.concatenate([r.prompt, np.asarray(r.out, np.int32)])
        M.apply(params, {"tokens": jnp.asarray(toks[None])}, cfg, ctx=ctx,
                mode="train", unroll=True)
    print(f"quantization-kernel proportion on served traffic "
          f"(bits={bits}, alpha={alpha}):")
    print(f"  {'layer':<28} {'per-token':>10} {'crossquant':>11} {'shrink':>7}")
    for name, rec in sorted(obs.stats.items()):
        pt = float(np.mean(rec["pt"]))
        cq = float(np.mean(rec["cq"]))
        shrink = (1 - cq / pt) if pt > 0 else 0.0
        print(f"  {name:<28} {pt:>9.2%} {cq:>10.2%} {shrink:>6.1%}")
    moe_layers = {n: r for n, r in obs.stats.items() if r["experts"]}
    if moe_layers:
        print("per-expert crossquant proportion (routed tokens only; the "
              "kernel statistic is per-expert for MoE layers, DESIGN.md §4):")
        print(f"  {'layer[expert]':<28} {'tokens':>6} {'per-token':>10} "
              f"{'crossquant':>11} {'shrink':>7}")
        for name, rec in sorted(moe_layers.items()):
            for e, er in sorted(rec["experts"].items()):
                if not er["pt"]:
                    print(f"  {name + f'[e{e}]':<28} {er['n']:>6d} "
                          f"{'-':>10} {'-':>11} {'-':>7}")
                    continue
                pt = float(np.mean(er["pt"]))
                cq = float(np.mean(er["cq"]))
                shrink = (1 - cq / pt) if pt > 0 else 0.0
                print(f"  {name + f'[e{e}]':<28} {er['n']:>6d} "
                      f"{pt:>9.2%} {cq:>10.2%} {shrink:>6.1%}")
    if chunk:
        print(f"per-chunk crossquant proportion (token_budget={chunk} "
              f"admission slices, dynamic c_j per chunk):")
        print(f"  {'layer':<28} {'chunks':>6} {'per-chunk':>10} "
              f"{'whole':>8} {'|delta|':>8} {'spread':>7}")
        for name, rec in sorted(obs.stats.items()):
            ws = [w for w, _ in rec["chunks"]]
            fs = [f for _, f in rec["chunks"]]
            agg = float(np.average(fs, weights=ws))
            cq = float(np.mean(rec["cq"]))
            print(f"  {name:<28} {len(fs):>6d} {agg:>9.2%} {cq:>7.2%} "
                  f"{abs(agg - cq):>7.4f} {max(fs) - min(fs):>6.2%}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="PATH.json",
                    help="load an EngineConfig from JSON; explicit engine "
                         "flags below override its fields")
    add_config_args(ap)
    ap.add_argument("--quant", default="int8", choices=["fp", "fake", "int8"])
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend N identical tokens to every prompt (shared "
                         "system prompt — exercises paged prefix reuse)")
    ap.add_argument("--compare", action="store_true",
                    help="also serve the fp baseline and report both tok/s")
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--n-requests", type=int, default=6)
    ap.add_argument("--prompt-lens", default="6,10,14", metavar="L1,L2,...",
                    help="prompt lengths cycled over requests (mixed-length "
                         "continuous batching)")
    ap.add_argument("--quant-kernel-stats", action="store_true",
                    help="replay served traffic and report per-layer "
                         "quantization-kernel proportion (paper §4.1)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL[,EXPERT]",
                    help="serve sharded on a (data, model[, expert]) host mesh "
                         "(TP §3.7, expert-parallel MoE §3.13), e.g. "
                         "--mesh 4,2 or --mesh 2,2,2; needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=<product>")
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    quant = {"fp": ql.FP, "fake": ql.W8A8_CROSSQUANT, "int8": ql.W8A8_INT8}[args.quant]
    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_arg
        mesh = parse_mesh_arg(args.mesh)

    base = (EngineConfig.from_json(pathlib.Path(args.config).read_text())
            if args.config else None)
    defaults = dict(batch_size=4, max_len=48)
    if args.quant == "int8":
        defaults["path"] = "fused-int8"
    config = config_from_args(args, base=base, **defaults)
    prompt_lens = [int(x) for x in args.prompt_lens.split(",")]
    prompts, max_new = mixed_workload(cfg, args.n_requests, prompt_lens,
                                      shared_prefix=args.shared_prefix)

    if args.quant != "int8":
        # The int8 KV cache is independent of weight quantization and applies to
        # fp/fake serving too; only the integer backends need a prepared tree.
        if config.path in ("dequant-fp", "fused-int8"):
            print(f"note: path={config.path} needs --quant int8; serving on "
                  f"the ref backend instead")
            config = dataclasses.replace(config, path=None)
        serve_params = params
        done, _ = serve(cfg, params, prompts, max_new, config=config,
                        quant=quant, tag=args.quant, mesh=mesh)
    else:
        qparams = calibrate_and_quantize(cfg, params, quant)
        if config.sparsity != "none":
            # Prune up front with the same default plan the engine would build,
            # so the report below describes exactly the tree being served (the
            # engine's own sparsify_tree pass is idempotent on a masked tree).
            from repro.models import quantize as MQ
            qparams = MQ.sparsify_tree(
                qparams, MQ.SparsityPlan(nm=MQ.parse_nm(config.sparsity)))
            summ = MQ.sparsity_summary(qparams)
            kept = float(np.mean(list(summ.values()))) if summ else 1.0
            dense_b = quantized_bytes(qparams)
            deploy_b = quantized_bytes(qparams, deploy_sparse=True)
            print(f"sparsity {config.sparsity}: {len(summ)} linears pruned, "
                  f"kept fraction {kept:.2f}; weights "
                  f"{dense_b / 2**20:.2f} MiB dense-layout -> "
                  f"{deploy_b / 2**20:.2f} MiB in the N:M deploy format")
        serve_params = qparams
        done, int8_tps = serve(cfg, qparams, prompts, max_new, config=config,
                               quant=quant, mesh=mesh)
        if args.compare:
            fp_config = dataclasses.replace(config, path=None)
            _, fp_tps = serve(cfg, params, prompts, max_new, config=fp_config,
                              quant=ql.FP, tag="fp-baseline", mesh=mesh)
            print(f"end-to-end tokens/sec: fp={fp_tps:.1f} "
                  f"{config.path or 'ref'}={int8_tps:.1f} "
                  f"({int8_tps / fp_tps:.2f}x; "
                  "CPU-interpret numbers — the kernel-level TPU projection is in "
                  "benchmarks/qgemm_bench.py)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.prompt[:4].tolist()}... -> {r.out[:6]}")
    if args.quant_kernel_stats:
        report_kernel_stats(cfg, serve_params, quant, done,
                            chunk=config.token_budget if config.chunked else 0)


if __name__ == "__main__":
    main()
