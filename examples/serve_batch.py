"""Batched serving of a PTQ-quantized model.

Calibrates CrossQuant's static column statistics on synthetic traffic, folds them
into true-int8 weights (quantize_tree), and serves a batch of requests through the
continuous-batching engine — the int8 deployment path of DESIGN.md §3.1.

    PYTHONPATH=src:. python examples/serve_batch.py [--quant int8|fake|fp]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import calibration, qlinear as ql
from repro.data import make_train_batches
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.models.quantize import quantize_tree, quantized_bytes
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default="int8", choices=["fp", "fake", "int8"])
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--n-requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    quant = {"fp": ql.FP, "fake": ql.W8A8_CROSSQUANT, "int8": ql.W8A8_INT8}[args.quant]

    if args.quant == "int8":
        print("calibrating static-c column stats on 2 batches ...")
        obs = calibration.Observer()
        batch_fn = make_train_batches(cfg.vocab, 16, 4, seed=1)
        for b in range(2):
            batch = {k: jnp.asarray(v) for k, v in batch_fn(b).items()}
            M.apply(params, batch, cfg, ctx=QuantContext(quant, observer=obs),
                    mode="train", unroll=True)
        before = quantized_bytes(params)
        params = quantize_tree(params, quant,
                               tables=calibration.stack_tables(obs.tables()))
        after = quantized_bytes(params)
        print(f"weights {before / 2**20:.1f} MiB -> {after / 2**20:.1f} MiB "
              f"({before / after:.2f}x smaller)")

    engine = ServeEngine(cfg, params, batch_size=4, max_len=48, quant=quant,
                         eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=12).astype(np.int32)
               for _ in range(args.n_requests)]
    engine.submit(prompts, max_new=12)
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.prompt[:4].tolist()}... -> {r.out[:6]}")


if __name__ == "__main__":
    main()
