"""Elastic restart: lose a host mid-training, continue on a smaller mesh.

Simulates an 8-device cluster (XLA host-device override — set BEFORE importing
jax). Training starts on a (4, 2) mesh; at the injected failure the supervisor
restores the last checkpoint and the rebuild hook re-lays-out the state on a (2, 2)
mesh (data parallelism absorbs the loss, TP degree is pinned by the weight layout —
runtime/elastic.py). Loss continues from where it left off.

    PYTHONPATH=src:. python examples/elastic_restart.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get
from repro.data import make_train_batches
from repro.models import model as M
from repro.runtime import Supervisor
from repro.runtime.elastic import make_elastic_mesh
from repro.sharding import planner
from repro.training import optimizer as opt_lib, trainer

import dataclasses

STEPS = 40
GLOBAL_BATCH = 8
SEQ = 64
TP = 2


def main() -> None:
    cfg = get("starcoder2-7b", smoke=True)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=SEQ,
                                global_batch=GLOBAL_BATCH)
    opt_cfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=4, total_steps=STEPS)
    batch_fn = make_train_batches(cfg.vocab, SEQ, GLOBAL_BATCH, seed=0)
    raw_step = trainer.make_train_step(cfg, opt_cfg)

    world = {"devices": list(jax.devices())}          # 8 "hosts"

    def build_mesh():
        return make_elastic_mesh(world["devices"], TP, global_batch=GLOBAL_BATCH)

    def shardings_for(mesh, state):
        plan = planner.make_plan(cfg, shape, mesh)
        return {
            "params": planner.param_shardings(state["params"], cfg, plan, mesh),
            "opt": opt_lib.OptState(
                planner.replicated(state["opt"].step, mesh),
                planner.param_shardings(state["opt"].m, cfg, plan, mesh),
                planner.param_shardings(state["opt"].v, cfg, plan, mesh)),
        }

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": opt_lib.init(params)}
    mesh_box = {"mesh": build_mesh()}
    print(f"starting on mesh {dict(mesh_box['mesh'].shape)}")

    def place(state, mesh):
        sh = shardings_for(mesh, state)
        return {
            "params": jax.tree_util.tree_map(jax.device_put, state["params"],
                                             sh["params"]),
            "opt": jax.tree_util.tree_map(jax.device_put, state["opt"], sh["opt"]),
        }

    state = place(state, mesh_box["mesh"])
    jit_step = jax.jit(raw_step)

    def step_fn(state, step):
        if step == STEPS // 2 and len(world["devices"]) == 8:
            # Out-of-band failure signal: 2 devices (one "host") die.
            raise_failure = True
        else:
            raise_failure = False
        if raise_failure:
            from repro.runtime import WorkerFailure
            world["devices"] = world["devices"][:6]
            raise WorkerFailure("host 3 lost (2 devices)")
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        with mesh_box["mesh"]:
            p, o, metrics = jit_step(state["params"], state["opt"], batch)
        if step % 8 == 0:
            print(f"  step {step:3d} loss={float(metrics['loss']):.3f} "
                  f"mesh={dict(mesh_box['mesh'].shape)}")
        return {"params": p, "opt": o}, {"loss": float(metrics["loss"])}

    def rebuild(state):
        mesh_box["mesh"] = build_mesh()
        print(f"  !! elastic rebuild -> mesh {dict(mesh_box['mesh'].shape)} "
              f"({len(world['devices'])} devices survive)")
        return place(state, mesh_box["mesh"])

    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="repro_elastic_"), keep_n=3)
    sup = Supervisor(ckpt, ckpt_every=8)
    result = sup.run(state, step_fn, STEPS, rebuild=rebuild)
    print(f"done: step={result.step} restarts={result.restarts} "
          f"final loss={result.metrics_history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
