"""Async streaming serving through ``AsyncServer`` (DESIGN.md §3.11).

Spins up ``--replicas`` ServeEngine replicas behind the asyncio front end and
streams a mixed-length workload through ``submit()``: per-request TTFT/TPOT,
queue wait, prefix reuse and (with ``--kernel-stats``) the paper's §4.1
quantization-kernel proportion print as each request finishes, followed by the
fleet ``metrics()`` snapshot.

Engine knobs are derived from the :class:`EngineConfig` dataclass fields — any
new config field shows up here automatically — and ``--config path.json``
loads a JSON EngineConfig first, with explicit flags layered on top::

    PYTHONPATH=src:. python examples/serve.py --replicas 2 \
        --cache-layout paged --shared-prefix 16 --router affinity
    PYTHONPATH=src:. python examples/serve.py --config engine.json \
        --quant int8 --kv-cache int8

``--stagger`` spaces submissions out (offered-load shaping); with
``--max-queue``/``--admission-timeout`` you can watch backpressure reject the
overflow instead of thrashing the radix cache.
"""
import argparse
import asyncio
import json
import pathlib

import jax
import numpy as np

from repro.configs import get
from repro.core import qlinear as ql
from repro.models import model as M
from repro.models.quantize import quantize_tree
from repro.serving.api import AdmissionError, Request
from repro.serving.config import EngineConfig, add_config_args, config_from_args
from repro.serving.server import AsyncServer


def workload(cfg, n_requests, prompt_lens, shared_prefix=0, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab, size=shared_prefix).astype(np.int32)
    return [np.concatenate([
        shared, rng.integers(1, cfg.vocab,
                             size=prompt_lens[i % len(prompt_lens)])
        .astype(np.int32)]) for i in range(n_requests)]


async def drive(srv, prompts, max_new, stagger):
    async def one(i, p):
        await asyncio.sleep(i * stagger)
        toks, fin = [], None
        try:
            async for ev in srv.submit(Request(prompt=p.tolist(),
                                               max_new=max_new)):
                if ev.kind == "token":
                    toks.append(ev.token)
                elif ev.kind == "finished":
                    fin = ev
                else:
                    print(f"  req {i}: ERROR {ev.error}")
                    return
        except AdmissionError as e:
            print(f"  req {i}: REJECTED after {e.queue_wait_s * 1e3:.0f}ms "
                  f"({e})")
            return
        m = fin.metrics
        kp = (f" kernel_prop={m.kernel_proportion:.2%}"
              if m.kernel_proportion is not None else "")
        print(f"  req {i}: {len(toks)} toks [{fin.finish_reason}] "
              f"replica={m.replica} ttft={m.ttft_s * 1e3:.0f}ms "
              f"tpot={m.tpot_s * 1e3:.1f}ms queue={m.queue_wait_s * 1e3:.0f}ms "
              f"prefix_reused={m.prefix_reused} requeues={m.requeues}{kp}")

    await asyncio.gather(*(one(i, p) for i, p in enumerate(prompts)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="PATH.json",
                    help="load an EngineConfig from JSON; explicit engine "
                         "flags below override its fields")
    add_config_args(ap)
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--quant", default="fp", choices=["fp", "fake", "int8"])
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--router", default="affinity",
                    choices=["affinity", "least-loaded", "random"])
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission: max in-flight requests "
                         "(default 2*replicas*batch_size)")
    ap.add_argument("--admission-timeout", type=float, default=1.0,
                    help="seconds a submit may wait for capacity before the "
                         "typed AdmissionError")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--prompt-lens", default="6,10,14", metavar="L1,L2,...")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="N-token shared system prompt (prefix affinity + "
                         "paged radix reuse)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--stagger", type=float, default=0.0, metavar="S",
                    help="seconds between submissions (offered-load shaping)")
    ap.add_argument("--kernel-stats", action="store_true",
                    help="per-request §4.1 quantization-kernel proportion")
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    base = (EngineConfig.from_json(pathlib.Path(args.config).read_text())
            if args.config else None)
    quant = {"fp": ql.FP, "fake": ql.W8A8_CROSSQUANT,
             "int8": ql.W8A8_INT8}[args.quant]
    defaults = dict(batch_size=4, max_len=48)
    if args.quant == "int8":
        params = quantize_tree(params, quant)
        defaults["path"] = "fused-int8"
    config = config_from_args(args, base=base, **defaults)
    prompt_lens = [int(x) for x in args.prompt_lens.split(",")]
    prompts = workload(cfg, args.n_requests, prompt_lens,
                       shared_prefix=args.shared_prefix)

    async def run():
        async with AsyncServer(cfg, params, config=config,
                               replicas=args.replicas, quant=quant,
                               router=args.router, max_queue=args.max_queue,
                               admission_timeout=args.admission_timeout,
                               kernel_stats=args.kernel_stats) as srv:
            print(f"serving {len(prompts)} requests on {args.replicas} "
                  f"replica(s), router={args.router}, config={config.to_json()}")
            await drive(srv, prompts, args.max_new, args.stagger)
            print("fleet metrics:")
            print(json.dumps(srv.metrics(), indent=2))

    asyncio.run(run())


if __name__ == "__main__":
    main()
