"""End-to-end training driver: data pipeline → sharded fault-tolerant train loop →
checkpoints → post-training quantization of the result.

Runs a reduced config end-to-end on CPU (same control flow as the pod launcher; on a
real (16,16) v5e pod, pass --production to repro.launch.train instead and the
planner shards everything). Injects a worker failure mid-run to demonstrate the
checkpoint/restart path, then PTQ-quantizes the trained model with CrossQuant and
compares held-out perplexity.

    PYTHONPATH=src:. python examples/train_lm.py [--steps 120]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.core import qlinear as ql
from repro.data import make_train_batches
from repro.models import model as M
from repro.models.layers import QuantContext
from repro.runtime import FailureInjector, Supervisor
from repro.training import optimizer as opt_lib, trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="mamba2-130m")
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    seq, batch_size = 64, 8
    opt_cfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    step_jit = jax.jit(trainer.make_train_step(cfg, opt_cfg, n_micro=2))

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": opt_lib.init(params)}
    batch_fn = make_train_batches(cfg.vocab, seq, batch_size, seed=0)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_example_")
    ckpt = CheckpointManager(ckpt_dir, keep_n=2)

    def step_fn(state, step):
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        p, o, metrics = step_jit(state["params"], state["opt"], batch)
        if step % 20 == 0:
            print(f"  step {step:4d} loss={float(metrics['loss']):.3f}")
        return {"params": p, "opt": o}, {"loss": float(metrics["loss"])}

    print(f"training {args.arch} (reduced) for {args.steps} steps with an injected "
          f"failure at step {args.steps // 2} ...")
    sup = Supervisor(ckpt, ckpt_every=20)
    result = sup.run(state, step_fn, args.steps,
                     injector=FailureInjector(fail_at_steps=(args.steps // 2,)))
    print(f"finished at step {result.step} after {result.restarts} restart(s); "
          f"final loss {result.metrics_history[-1]['loss']:.3f}")

    # Post-training quantization of the trained model (the paper's deployment).
    trained = result.state["params"]
    eval_batch = {k: jnp.asarray(v) for k, v in batch_fn(10_001).items()}
    for name, qc in [("fp", ql.FP), ("per-token W8A8", ql.W8A8_PER_TOKEN),
                     ("CrossQuant W8A8", ql.W8A8_CROSSQUANT)]:
        loss, m = M.loss_fn(trained, eval_batch, cfg, ctx=QuantContext(qc),
                            remat=False)
        print(f"  eval {name:18s} ppl={float(jnp.exp(m['ce'])):.3f}")


if __name__ == "__main__":
    main()
